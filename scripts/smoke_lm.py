import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs as C
from repro.lm.model import TransformerLM

rng = np.random.default_rng(0)


def frontend_for(cfg, b):
    if cfg.encoder_layers:
        return jnp.asarray(rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)),
                           jnp.float32)
    if cfg.frontend_tokens:
        return jnp.asarray(
            rng.normal(size=(b, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    return None


for arch in C.ARCHS:
    t0 = time.time()
    full = C.get_config(arch)
    cfg = C.get_reduced(arch)
    n_full = full.param_count()
    n_active = full.active_param_count()
    model = TransformerLM(cfg, remat=False)
    params = model.init(jax.random.key(0))
    b, s = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "targets": targets}
    fe = frontend_for(cfg, b)
    if fe is not None:
        batch["frontend"] = fe

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = jax.tree.reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), grads, 0.0)
    assert jnp.isfinite(gnorm) and gnorm > 0, arch

    # prefill + decode one token
    lg, caches = jax.jit(
        lambda p, t: model.prefill(p, t, frontend=fe, cache_len=s + 4)
    )(params, tokens)
    assert lg.shape == (b, 1, model.vp) and jnp.all(jnp.isfinite(lg)), arch
    nxt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    lg2, caches = jax.jit(
        lambda p, t, c: model.decode_step(p, t, s, c, frontend=fe)
    )(params, nxt, caches)
    assert lg2.shape == (b, 1, model.vp) and jnp.all(jnp.isfinite(lg2)), arch

    print(f"{arch:24s} full={n_full/1e9:7.2f}B active={n_active/1e9:7.2f}B "
          f"loss={float(loss):.3f} ok ({time.time()-t0:.1f}s)")

print("ALL LM SMOKE TESTS PASSED")
