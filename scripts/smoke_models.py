import numpy as np
import jax
import jax.numpy as jnp

from repro.core.graph import synthetic_heterograph
from repro.core.module import HectorModule
from repro.models import rgcn_program, rgat_program, hgt_program
from repro.models import baselines

hg = synthetic_heterograph(num_nodes=200, num_edges=1500, num_ntypes=4,
                           num_etypes=7, seed=0)
gt = hg.to_tensors()
print(f"graph: N={hg.num_nodes} E={hg.num_edges} U={hg.num_unique} "
      f"compaction={hg.entity_compaction_ratio:.2f}")

d_in, d_out = 16, 24
x = jnp.asarray(np.random.default_rng(1).normal(size=(hg.num_nodes, d_in)),
                jnp.float32)

for name, prog_fn, vanilla in [
    ("rgcn", rgcn_program, baselines.rgcn_vanilla),
    ("rgat", rgat_program, baselines.rgat_vanilla),
    ("hgt", hgt_program, baselines.hgt_vanilla),
]:
    prog = prog_fn(d_in, d_out)
    ref_out = None
    for reorder in (False, True):
        for compact in (False, True):
            for backend in ("xla", "pallas_interpret"):
                mod = HectorModule(prog, hg, reorder=reorder, compact=compact,
                                   backend=backend, tile=8, node_block=8)
                params = mod.init(jax.random.key(0))
                out = mod.apply(params, {"feature": x})["h_out"]
                assert out.shape == (hg.num_nodes, d_out), out.shape
                assert not bool(jnp.any(jnp.isnan(out)))
                van = vanilla(params, gt, {"feature": x})["h_out"]
                err = float(jnp.max(jnp.abs(out - van)))
                rel = err / float(jnp.max(jnp.abs(van)) + 1e-9)
                tag = f"{name} R={int(reorder)} C={int(compact)} {backend}"
                print(f"{tag:42s} maxerr={err:.2e} rel={rel:.2e}")
                assert rel < 2e-4, tag
    # gradient check on one config
    mod = HectorModule(prog, hg, reorder=True, compact=True,
                       backend="pallas_interpret", tile=8, node_block=8)
    params = mod.init(jax.random.key(0))
    g = jax.grad(lambda p: jnp.sum(mod.apply(p, {"feature": x})["h_out"] ** 2))(params)
    gv = jax.grad(lambda p: jnp.sum(vanilla(p, gt, {"feature": x})["h_out"] ** 2))(params)
    for k in g:
        err = float(jnp.max(jnp.abs(g[k] - gv[k])))
        denom = float(jnp.max(jnp.abs(gv[k])) + 1e-9)
        print(f"  grad[{k}] rel={err/denom:.2e}")
        assert err / denom < 5e-4, (name, k)
    print(mod.describe())
print("ALL MODEL SMOKE TESTS PASSED")
