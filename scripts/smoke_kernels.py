import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import layout as L, ops, ref as R

rng = np.random.default_rng(0)

# --- segment MM ---
R_groups, k, n = 5, 16, 24
sizes = np.array([7, 0, 13, 3, 9])
M = int(sizes.sum())
seg_ptr = np.zeros(R_groups + 1, np.int64)
np.cumsum(sizes, out=seg_ptr[1:])
seg_ids = np.repeat(np.arange(R_groups), sizes)

x = jnp.asarray(rng.normal(size=(M, k)), jnp.float32)
w = jnp.asarray(rng.normal(size=(R_groups, k, n)), jnp.float32)
scale = jnp.asarray(rng.normal(size=(M,)), jnp.float32)

ps = L.pad_segments(seg_ptr, tile=8)
lay = ops.padded_segments_dev(ps)
y_ref = R.segment_mm_ref(x, w, jnp.asarray(seg_ids), scale)

for backend in ["xla", "pallas_interpret"]:
    y = ops.segment_mm(x, w, lay, row_scale=scale, backend=backend)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    print(f"segment_mm[{backend}] max err {err:.2e}")
    assert err < 1e-4, backend

# grads
def loss_op(backend):
    def f(x, w, scale):
        y = ops.segment_mm(x, w, lay, row_scale=scale, backend=backend)
        return jnp.sum(jnp.sin(y))
    return jax.grad(f, argnums=(0, 1, 2))(x, w, scale)

def loss_ref(x, w, scale):
    return jnp.sum(jnp.sin(R.segment_mm_ref(x, w, jnp.asarray(seg_ids), scale)))
g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, scale)
for backend in ["xla", "pallas_interpret"]:
    g = loss_op(backend)
    for a, b, name in zip(g, g_ref, "xws"):
        err = float(jnp.max(jnp.abs(a - b)))
        print(f"grad[{backend}][{name}] max err {err:.2e}")
        assert err < 1e-3, (backend, name)

# --- traversal: edge softmax + aggregate ---
N, E, d = 37, 211, 12
dst = np.sort(rng.integers(0, N, size=E)).astype(np.int32)
# canonical order: pretend etype-sorted == random order; build perm_dst
canon_dst = rng.permutation(dst)
perm_dst = np.argsort(canon_dst, kind="stable").astype(np.int32)
dst_ptr = np.zeros(N + 1, np.int64)
np.cumsum(np.bincount(canon_dst[perm_dst], minlength=N), out=dst_ptr[1:])
bcsr = L.block_csr(dst_ptr, edge_tile=8, node_block=8)
bc = ops.blocked_csr_dev(bcsr, perm_dst)

scores = jnp.asarray(rng.normal(size=(E,)), jnp.float32)
msg = jnp.asarray(rng.normal(size=(E, d)), jnp.float32)
dstj = jnp.asarray(canon_dst)

out_ref = R.softmax_agg_ref(scores, msg, dstj, N)
for backend in ["xla", "pallas_interpret"]:
    out = ops.edge_softmax_agg(scores, msg, dstj, N, bc=bc, backend=backend)
    err = float(jnp.max(jnp.abs(out - out_ref)))
    print(f"softmax_agg[{backend}] max err {err:.2e}")
    assert err < 1e-4, backend

def sloss(f):
    return lambda s, m: jnp.sum(jnp.cos(f(s, m)))
g_ref = jax.grad(sloss(lambda s, m: R.softmax_agg_ref(s, m, dstj, N)),
                 argnums=(0, 1))(scores, msg)
for backend in ["xla", "pallas_interpret"]:
    g = jax.grad(
        sloss(lambda s, m: ops.edge_softmax_agg(s, m, dstj, N, bc=bc, backend=backend)),
        argnums=(0, 1))(scores, msg)
    for a, b, name in zip(g, g_ref, "sm"):
        err = float(jnp.max(jnp.abs(a - b)))
        print(f"softmax_agg grad[{backend}][{name}] max err {err:.2e}")
        assert err < 1e-3

wscale = jnp.asarray(rng.normal(size=(E,)), jnp.float32)
out_ref = R.weighted_agg_ref(wscale, msg, dstj, N)
for backend in ["xla", "pallas_interpret"]:
    out = ops.weighted_agg(wscale, msg, dstj, N, bc=bc, backend=backend)
    err = float(jnp.max(jnp.abs(out - out_ref)))
    print(f"weighted_agg[{backend}] max err {err:.2e}")
    assert err < 1e-4

print("ALL KERNEL SMOKE TESTS PASSED")
