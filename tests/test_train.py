"""RGNN training subsystem: compiled train-step executors (sampled +
full-graph), full-fanout gradient parity with the dense step, epoch-aware
seed streams, mid-epoch checkpoint/resume bit-determinism, the sampled
trainer's zero-retrace steady state, and the CLI driver."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import executor
from repro.core.graph import synthetic_heterograph
from repro.optim import AdamW
from repro.sampling import EpochSeedStream, build_minibatch
from repro.train import (EngineConfig, FullGraphTrainer, RGNNEngine,
                         SampledTrainer)

SEEDS = np.array([3, 50, 7, 3, 119, 0, 88, 12], dtype=np.int32)  # dupes


@pytest.fixture(scope="module")
def graph():
    return synthetic_heterograph(num_nodes=120, num_edges=900, num_ntypes=4,
                                 num_etypes=7, seed=0)


@pytest.fixture(scope="module")
def task(graph):
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.normal(size=(graph.num_nodes, 16)), jnp.float32)
    labels = np.asarray(rng.integers(0, 6, graph.num_nodes))
    return feats, labels


def _engine(graph, fanouts):
    return RGNNEngine(graph, EngineConfig(
        model="rgat", layers=2, dim=16, hidden=12, classes=6,
        fanouts=fanouts, tile=8, node_block=8, seed=0))


# ---------------------------------------------------------------------------
# epoch-aware seed stream
# ---------------------------------------------------------------------------
def test_epoch_seed_stream_shuffles_without_replacement():
    ids = np.arange(50, dtype=np.int32) * 2    # non-trivial id values
    s = EpochSeedStream(ids, batch_size=16, seed=3)
    assert s.batches_per_epoch == 3            # drop_last: 48 of 50 used
    assert s.steps_for(4) == 12
    # one epoch = disjoint batches drawn from ids without replacement
    epoch0 = [s.batch(k) for k in range(3)]
    flat = np.concatenate(epoch0)
    assert len(np.unique(flat)) == len(flat) == 48
    assert set(flat.tolist()) <= set(ids.tolist())
    # a later epoch reshuffles (different batch content, same contract)
    epoch2 = [s.batch(6 + k) for k in range(3)]
    assert s.epoch_of(6) == 2
    assert not all(np.array_equal(a, b) for a, b in zip(epoch0, epoch2))
    flat2 = np.concatenate(epoch2)
    assert len(np.unique(flat2)) == 48
    # pure function of step: restart-determinism for mid-epoch resume
    np.testing.assert_array_equal(s.batch(7), EpochSeedStream(
        ids, batch_size=16, seed=3).batch(7))


# ---------------------------------------------------------------------------
# compiled train-step executors
# ---------------------------------------------------------------------------
def test_full_fanout_train_step_matches_full_graph(graph, task):
    """Tentpole parity invariant: a full-neighborhood sampled grad_and_update
    reproduces the dense full-graph step — same loss, same gradients (hence
    bit-comparable updated params and moments)."""
    feats, labels = task
    eng = _engine(graph, [-1, -1])
    opt = AdamW(learning_rate=1e-2, weight_decay=0.01)
    params = eng.init_params(jax.random.key(0))

    full_ex = executor.StackTrainExecutor(eng.plans, opt)
    s_full, m_full = full_ex.grad_and_update(
        opt.init(params), eng.gt, eng.layouts, jnp.asarray(SEEDS),
        jnp.asarray(labels[SEEDS]), {"feature": feats})

    blk_ex = executor.BlockTrainExecutor(eng.plans, opt)
    seq = eng.sampler.sample(SEEDS)
    mb = build_minibatch(seq, tile=8, node_block=8, bucket=True)
    s_blk, m_blk = blk_ex.grad_and_update(
        opt.init(params), mb, jnp.asarray(seq.slice_labels(labels)),
        {"feature": feats[mb.input_ids]})

    np.testing.assert_allclose(m_full["loss"], m_blk["loss"], rtol=1e-5)
    np.testing.assert_allclose(m_full["accuracy"], m_blk["accuracy"])
    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_blk.params)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(s_full.mu), jax.tree.leaves(s_blk.mu)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)


def test_train_step_compile_cache(graph, task):
    """Same-bucket batches reuse one compiled train step (no retrace)."""
    feats, labels = task
    eng = _engine(graph, [3, 3])
    opt = AdamW(learning_rate=1e-2)
    ex = executor.BlockTrainExecutor(eng.plans, opt)
    state = opt.init(eng.init_params(jax.random.key(0)))

    def batch(batch_index):
        seq = eng.sampler.sample(SEEDS, batch_index=batch_index, epoch=0)
        return seq, build_minibatch(seq, tile=8, node_block=8, bucket=True)

    def step(state, seq, mb):
        return ex.grad_and_update(state, mb,
                                  jnp.asarray(seq.slice_labels(labels)),
                                  {"feature": feats[mb.input_ids]})

    seq0, mb0 = batch(0)
    sig0 = executor.signature((mb0.tensors, mb0.layouts))
    state, m0 = step(state, seq0, mb0)
    assert (ex.trace_count, ex.cache_misses, ex.cache_hits) == (1, 1, 0)
    # a *fresh* sample landing in the same buckets: pow2 bucketing makes
    # most batch indices collide; probe for one rather than hardcoding it
    seq1, mb1 = next(
        (s, m) for s, m in map(batch, range(1, 40))
        if executor.signature((m.tensors, m.layouts)) == sig0)
    state, m1 = step(state, seq1, mb1)
    assert ex.trace_count == 1 and ex.cache_hits == 1
    assert float(state.step) == 2
    assert np.isfinite(float(m0["loss"])) and np.isfinite(float(m1["loss"]))


def test_full_graph_trainer_reduces_loss(graph, task):
    feats, labels = task
    eng = _engine(graph, [3, 3])
    tr = FullGraphTrainer(eng, feats, labels, np.arange(graph.num_nodes),
                          opt=AdamW(learning_rate=1e-2, weight_decay=0.0),
                          log=None)
    state = tr.init_state(eng.init_params(jax.random.key(0)))
    state, losses = tr.train(state, steps=6)
    assert tr.step_exec.trace_count == 1          # one bucket: one trace
    assert losses[-1] < losses[0]
    m = tr.evaluate(state.params)
    assert 0 <= m["accuracy"] <= 1 and np.isfinite(m["loss"])


# ---------------------------------------------------------------------------
# sampled trainer
# ---------------------------------------------------------------------------
def test_sampled_trainer_zero_retraces_after_warmup(graph, task):
    feats, labels = task
    eng = _engine(graph, [3, 3])
    ids = np.arange(graph.num_nodes, dtype=np.int32)
    tr = SampledTrainer(eng, feats, labels, ids[:96], ids[96:],
                        opt=AdamW(learning_rate=1e-2), log=None)
    state = tr.init_state(eng.init_params(jax.random.key(0)))
    state, stats = tr.train(state, epochs=3, batch_size=32,
                            warmup_epochs=2, eval_every_epochs=3)
    assert stats["steps"] == 9 and stats["batches_per_epoch"] == 3
    assert stats["retraces_after_warmup"] == 0
    assert stats["executor_traces"] == stats["executor_compiled"]
    # the loss moves and the periodic eval ran both paths
    assert stats["losses"][-1] != stats["losses"][0]
    assert len(stats["evals"]) == 1
    ev = stats["evals"][0]
    assert {"full_val", "sampled_val"} <= set(ev)
    # sampled eval and full-graph eval agree on ballpark (same params)
    assert abs(ev["full_val"]["loss"] - ev["sampled_val"]["loss"]) < 1.0


def test_checkpoint_resume_mid_epoch_bit_deterministic(graph, task, tmp_path):
    """Saving at a mid-epoch step and resuming replays the exact remaining
    batches: the resumed run's final state is bit-identical to the
    uninterrupted run (streams and sampler rng are pure functions of the
    global step)."""
    feats, labels = task
    ids = np.arange(graph.num_nodes, dtype=np.int32)
    opt = AdamW(learning_rate=1e-2)

    def make_trainer():
        eng = _engine(graph, [3, 3])   # fresh engine: fresh compile caches
        tr = SampledTrainer(eng, feats, labels, ids, opt=opt,
                            ckpt_dir=str(tmp_path / "ckpt"), log=None)
        state = tr.init_state(eng.init_params(jax.random.key(0)))
        return tr, state

    # uninterrupted run: 2 epochs x 3 batches; checkpoint at step 4 (the
    # 1st batch of epoch 2 -> mid-epoch)
    tr_a, state_a = make_trainer()
    state_a, stats_a = tr_a.train(state_a, epochs=2, batch_size=40,
                                  ckpt_every=4)
    assert stats_a["steps"] == 6

    # fresh trainer (fresh executors/compile caches), resume from step 4
    tr_b, state_b = make_trainer()
    state_b, start = tr_b.resume(state_b)
    assert start == 4
    state_b, stats_b = tr_b.train(state_b, epochs=2, batch_size=40,
                                  start_step=start)
    assert stats_b["steps"] == 2
    np.testing.assert_array_equal(stats_a["losses"][4:],
                                  stats_b["losses"])
    for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------
def test_train_rgnn_driver_end_to_end(tmp_path):
    from repro.launch import train_rgnn
    stats = train_rgnn.train(
        model="rgat", dataset="synthetic", scale=0.05, layers=2, dim=16,
        hidden=16, classes=6, fanouts=[3, 3], batch_size=32, epochs=2,
        lr=1e-2, tile=8, node_block=8, seed=0, val_frac=0.2,
        ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=2,
        eval_every_epochs=2, log=lambda *a, **k: None)
    assert stats["steps"] == stats["epochs"] * stats["batches_per_epoch"]
    assert stats["losses"][-1] < stats["losses"][0]
    assert stats["retraces_after_warmup"] == 0
    assert np.isfinite(stats["full_val_loss"])
    # checkpoints landed
    from repro.checkpoint import Checkpointer
    assert Checkpointer(str(tmp_path / "ckpt")).latest_step() is not None
