"""Hetero mini-batch sampling subsystem: determinism, block layout
invariants, full-fanout equivalence with the full-graph forward, bucketing,
the prefetching loader, the layout/block caches, the whole-plan compiled
executor, and the serving driver."""
import collections

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.executor import signature as executor_signature
from repro.core.graph import HeteroGraph, synthetic_heterograph
from repro.core.module import HectorStack
from repro.models import hgt_program, rgat_program, rgcn_program
from repro.sampling import (FanoutSampler, LRUCache, MiniBatchLoader,
                            SeedStream, block_signature, build_minibatch)
from repro.sampling.bucketing import pad_block_graph


@pytest.fixture(scope="module")
def graph():
    return synthetic_heterograph(num_nodes=120, num_edges=900, num_ntypes=4,
                                 num_etypes=7, seed=0)


@pytest.fixture(scope="module")
def feats(graph):
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.normal(size=(graph.num_nodes, 16)), jnp.float32)


SEEDS = np.array([3, 50, 7, 3, 119, 0], dtype=np.int32)  # dupes on purpose


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_sampler_deterministic_under_seed(graph):
    a = FanoutSampler(graph, [3, 5], seed=11).sample(SEEDS, batch_index=4)
    b = FanoutSampler(graph, [3, 5], seed=11).sample(SEEDS, batch_index=4)
    for ba, bb in zip(a.blocks, b.blocks):
        np.testing.assert_array_equal(ba.graph.src, bb.graph.src)
        np.testing.assert_array_equal(ba.graph.dst, bb.graph.dst)
        np.testing.assert_array_equal(ba.graph.etype, bb.graph.etype)
        np.testing.assert_array_equal(ba.node_ids, bb.node_ids)
    np.testing.assert_array_equal(a.seed_perm, b.seed_perm)


def test_sampler_varies_with_batch_index(graph):
    s = FanoutSampler(graph, [2, 2], seed=0)
    a = s.sample(SEEDS, batch_index=0)
    b = s.sample(SEEDS, batch_index=1)
    same = (a.blocks[0].graph.num_edges == b.blocks[0].graph.num_edges
            and np.array_equal(a.blocks[0].node_ids, b.blocks[0].node_ids))
    assert not same


# ---------------------------------------------------------------------------
# block invariants
# ---------------------------------------------------------------------------
def _check_block_graph(bg: HeteroGraph):
    # etype-sorted canonical edges + consistent segment pointers
    assert np.all(np.diff(bg.etype) >= 0)
    np.testing.assert_array_equal(
        bg.etype_ptr,
        np.concatenate([[0], np.cumsum(np.bincount(
            bg.etype, minlength=bg.num_etypes))]))
    # dst CSR is a valid partition of the dst-sorted edges
    assert bg.dst_ptr[0] == 0 and bg.dst_ptr[-1] == bg.num_edges
    assert np.all(np.diff(bg.dst_ptr) >= 0)
    np.testing.assert_array_equal(bg.dst[bg.perm_dst], bg.dst_sorted)
    assert np.all(np.diff(bg.dst_sorted) >= 0)
    # compact materialization map resolves to the original (src, etype)
    np.testing.assert_array_equal(bg.unique_src[bg.edge_to_unique], bg.src)
    np.testing.assert_array_equal(bg.unique_etype[bg.edge_to_unique], bg.etype)
    assert np.all(np.diff(bg.unique_etype) >= 0)
    assert bg.num_unique <= max(1, bg.num_edges)
    # nodes presorted by type
    assert np.all(np.diff(bg.node_type) >= 0)


def test_block_layout_invariants(graph):
    seq = FanoutSampler(graph, [4, 2, 3], seed=3).sample(SEEDS)
    assert seq.num_hops == 3
    for i, blk in enumerate(seq.blocks):
        _check_block_graph(blk.graph)
        # local/global ID mapping is consistent
        assert blk.node_ids.shape[0] == blk.graph.num_nodes
        assert np.all(np.diff(blk.node_ids) > 0)
        np.testing.assert_array_equal(
            graph.node_type[blk.node_ids], blk.graph.node_type)
        # every sampled edge exists in the parent graph
        full = set(zip(graph.src.tolist(), graph.dst.tolist(),
                       graph.etype.tolist()))
        for s, d, t in zip(blk.node_ids[blk.graph.src],
                           blk.node_ids[blk.graph.dst], blk.graph.etype):
            assert (s, d, t) in full
        # chaining: this hop's dst frontier is the next hop's node set
        if i + 1 < seq.num_hops:
            np.testing.assert_array_equal(
                blk.dst_ids, seq.blocks[i + 1].node_ids)
    np.testing.assert_array_equal(
        seq.blocks[-1].dst_ids[seq.seed_perm], SEEDS)


def test_fanout_cap_respected(graph):
    fanouts = [2, 4]
    seq = FanoutSampler(graph, fanouts, seed=9).sample(
        np.arange(30, dtype=np.int32), batch_index=1)
    for blk, cap in zip(seq.blocks, fanouts):
        per_pair = collections.Counter(
            zip(blk.graph.dst.tolist(), blk.graph.etype.tolist()))
        assert max(per_pair.values(), default=0) <= cap


def test_full_fanout_keeps_entire_neighborhood(graph):
    seq = FanoutSampler(graph, [-1], seed=0).sample(SEEDS)
    blk = seq.blocks[0]
    sampled_in_deg = np.bincount(blk.node_ids[blk.graph.dst],
                                 minlength=graph.num_nodes)
    full_in_deg = np.diff(graph.dst_ptr)
    for v in np.unique(SEEDS):
        assert sampled_in_deg[v] == full_in_deg[v]


def test_bucketed_block_graph_is_padded_superset(graph):
    seq = FanoutSampler(graph, [3, 3], seed=5).sample(SEEDS)
    for blk in seq.blocks:
        bg = blk.graph
        padded = pad_block_graph(bg)
        _check_block_graph(padded)
        for dim in (padded.num_nodes, padded.num_edges, padded.num_unique):
            assert dim & (dim - 1) == 0  # power of two
        # real edges survive: pad edges all point at pad nodes
        real = (padded.src < bg.num_nodes) & (padded.dst < bg.num_nodes)
        assert int(real.sum()) == bg.num_edges
        key = lambda s, d, t: set(zip(s.tolist(), d.tolist(), t.tolist()))
        assert key(padded.src[real], padded.dst[real], padded.etype[real]) \
            == key(bg.src, bg.dst, bg.etype)


# ---------------------------------------------------------------------------
# sampled forward == full-graph forward at full fanout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prog_fn", [rgcn_program, rgat_program, hgt_program])
@pytest.mark.parametrize("bucket", [False, True])
def test_full_fanout_matches_full_graph(graph, feats, prog_fn, bucket):
    stack = HectorStack([prog_fn(16, 12), prog_fn(12, 6)], graph,
                        tile=8, node_block=8, jit=False)
    params = stack.init(jax.random.key(0))
    full = stack.apply(params, {"feature": feats})
    seq = FanoutSampler(graph, [-1, -1], seed=0).sample(SEEDS)
    mb = build_minibatch(seq, tile=8, node_block=8, bucket=bucket)
    out = stack.apply_blocks(params, mb, feats)
    assert out.shape == (len(SEEDS), 6)
    np.testing.assert_allclose(out, full[SEEDS], rtol=2e-4, atol=2e-4)


def test_full_fanout_matches_full_graph_pallas(graph, feats):
    stack = HectorStack([rgat_program(16, 12), rgat_program(12, 6)], graph,
                        tile=8, node_block=8, backend="pallas_interpret",
                        jit=False)
    params = stack.init(jax.random.key(0))
    full = stack.apply(params, {"feature": feats})
    mb = build_minibatch(FanoutSampler(graph, [-1, -1]).sample(SEEDS),
                         tile=8, node_block=8, bucket=True)
    out = stack.apply_blocks(params, mb, feats)
    np.testing.assert_allclose(out, full[SEEDS], rtol=2e-4, atol=2e-4)


def test_partial_fanout_runs_and_is_finite(graph, feats):
    stack = HectorStack([rgat_program(16, 12), rgat_program(12, 6)], graph,
                        tile=8, node_block=8, jit=False)
    params = stack.init(jax.random.key(0))
    mb = build_minibatch(FanoutSampler(graph, [2, 3], seed=1).sample(SEEDS),
                         tile=8, node_block=8, bucket=True)
    out = stack.apply_blocks(params, mb, feats)
    assert out.shape == (len(SEEDS), 6)
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# loader
# ---------------------------------------------------------------------------
def test_loader_prefetch_deterministic_and_bounded(graph):
    sampler = FanoutSampler(graph, [3, 3], seed=2)
    stream = SeedStream(graph.num_nodes, 8, seed=5)
    a = MiniBatchLoader(sampler, stream, tile=8, node_block=8, num_batches=3)
    b = MiniBatchLoader(sampler, stream, tile=8, node_block=8, num_batches=3)
    try:
        batches_a, batches_b = list(a), list(b)
    finally:
        a.close()
        b.close()
    assert [mb.step for mb in batches_a] == [0, 1, 2]
    for ma, mb_ in zip(batches_a, batches_b):
        np.testing.assert_array_equal(ma.seq.blocks[0].graph.src,
                                      mb_.seq.blocks[0].graph.src)
        np.testing.assert_array_equal(np.asarray(ma.input_ids),
                                      np.asarray(mb_.input_ids))
    # exhausted loader keeps raising StopIteration
    with pytest.raises(StopIteration):
        next(a)


def test_loader_close_mid_stream(graph):
    sampler = FanoutSampler(graph, [2], seed=0)
    loader = MiniBatchLoader(sampler, SeedStream(graph.num_nodes, 4, seed=0),
                             tile=8, node_block=8)
    next(loader)
    loader.close()
    assert not loader._thread.is_alive()


# ---------------------------------------------------------------------------
# compile cache / layout cache / sampled-block cache
# ---------------------------------------------------------------------------
def test_block_executor_compile_cache_hits_same_bucket(graph, feats):
    """Same-bucket blocks -> one trace + cache hit; a new bucket -> miss."""
    stack = HectorStack([rgat_program(16, 12), rgat_program(12, 6)], graph,
                        tile=8, node_block=8, jit=False)
    params = stack.init(jax.random.key(0))
    ex = stack.block_executor
    sampler = FanoutSampler(graph, [2, 2], seed=0)
    mb0 = build_minibatch(sampler.sample(SEEDS, batch_index=0),
                          tile=8, node_block=8, bucket=True)
    mb1 = build_minibatch(sampler.sample(SEEDS, batch_index=1),
                          tile=8, node_block=8, bucket=True)
    out0 = stack.apply_blocks(params, mb0, feats, compiled=True)
    assert (ex.trace_count, ex.cache_misses, ex.cache_hits) == (1, 1, 0)
    stack.apply_blocks(params, mb0, feats, compiled=True)
    assert (ex.trace_count, ex.cache_hits) == (1, 1)
    # eager path agrees with the compiled one
    np.testing.assert_allclose(
        out0, stack.apply_blocks(params, mb0, feats, compiled=False),
        rtol=2e-4, atol=2e-4)
    # a different sample in the same buckets: still zero retraces
    if executor_signature((mb1.tensors, mb1.layouts)) == \
            executor_signature((mb0.tensors, mb0.layouts)):
        stack.apply_blocks(params, mb1, feats, compiled=True)
        assert ex.trace_count == 1
    # a structurally different batch (more seeds -> larger buckets): miss
    big = build_minibatch(
        sampler.sample(np.arange(60, dtype=np.int32), batch_index=2),
        tile=8, node_block=8, bucket=True)
    stack.apply_blocks(params, big, feats, compiled=True)
    assert ex.cache_misses == 2 and ex.trace_count == 2


def test_lru_cache_eviction_and_counters():
    c = LRUCache(maxsize=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # refresh 'a': now 'b' is LRU
    c.put("c", 3)                   # evicts 'b'
    assert c.evictions == 1
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert c.hits == 3 and c.misses == 1
    assert 0 < c.hit_rate < 1


def test_kernel_layouts_cache_by_block_signature(graph):
    sampler = FanoutSampler(graph, [3, 3], seed=7)
    seq = sampler.sample(SEEDS, batch_index=0)
    cache = LRUCache(maxsize=16)
    mb_a = build_minibatch(seq, tile=8, node_block=8, bucket=True,
                           layout_cache=cache)
    assert cache.misses == mb_a.num_hops and cache.hits == 0
    # identical sample again: all hops hit, layouts are the same objects
    mb_b = build_minibatch(seq, tile=8, node_block=8, bucket=True,
                           layout_cache=cache)
    assert cache.hits == mb_a.num_hops
    for la, lb in zip(mb_a.layouts, mb_b.layouts):
        assert la is lb
    # the signature really is content-based: a different sample differs
    other = sampler.sample(SEEDS, batch_index=1)
    keys = {block_signature(b.graph, 8, 8, True) for b in seq.blocks}
    keys_other = {block_signature(b.graph, 8, 8, True) for b in other.blocks}
    assert keys != keys_other


def test_loader_block_cache_zero_rebuilds_on_repeats(graph, feats):
    """Repeated seed batches: served from the block cache (no sampling, no
    host-side KernelLayouts rebuilds) and with zero executor retraces."""
    distinct, total = 2, 8
    stack = HectorStack([rgat_program(16, 12), rgat_program(12, 6)], graph,
                        tile=8, node_block=8, jit=False)
    params = stack.init(jax.random.key(1))
    ex = stack.block_executor
    loader = MiniBatchLoader(
        FanoutSampler(graph, [3, 3], seed=2),
        SeedStream(graph.num_nodes, 6, seed=5, num_distinct=distinct),
        tile=8, node_block=8, bucket=True, num_batches=total,
        cache_blocks=8, cache_layouts=32,
    )
    outs = []
    try:
        for mb in loader:
            outs.append(np.asarray(
                stack.apply_blocks(params, mb, feats, compiled=True)))
    finally:
        loader.close()
    assert len(outs) == total
    stats = loader.cache_stats()
    assert stats["block_cache"]["misses"] == distinct
    assert stats["block_cache"]["hits"] == total - distinct
    # layout builds happened only for the distinct batches
    assert stats["layout_cache"]["misses"] <= distinct * 2  # hops per batch
    # compiled executor: traced at most once per distinct bucket, and every
    # repeat was a compile-cache hit
    assert ex.trace_count <= distinct
    assert ex.cache_hits >= total - distinct
    # repeats reproduce the first occurrence bit-for-bit
    for i in range(distinct, total):
        np.testing.assert_array_equal(outs[i], outs[i % distinct])


def test_loader_block_cache_epoch_keyed_for_training_streams(graph):
    """Regression (ISSUE 3 satellite): the sampled-block LRU used to be
    keyed by (seeds, fanout) only, so a training stream revisiting the same
    seed batch in a later epoch would silently replay the *identical*
    cached blocks — destroying neighbor-sampling stochasticity. With an
    epoch-aware seed source the key (and the sampler rng) includes the
    epoch: same seeds, later epoch -> fresh sample, zero cache hits."""
    from benchmarks.train_sampled import check_fresh_blocks_per_epoch

    failures = []
    check_fresh_blocks_per_epoch(failures)   # shared with the CI gate
    assert failures == []

    # serving streams (no epoch_of) keep the replay semantics: same seeds
    # at a later step return the cached block
    seeds = np.arange(24, dtype=np.int32)
    sampler = FanoutSampler(graph, [3, 3], seed=2)
    loader = MiniBatchLoader(sampler, lambda step: seeds, tile=8,
                             node_block=8, bucket=True, num_batches=3,
                             cache_blocks=8)
    try:
        batches = list(loader)
        stats = loader.cache_stats()["block_cache"]
    finally:
        loader.close()

    def edges(mb):
        b = mb.seq.blocks[0]
        return set(zip(b.node_ids[b.graph.src].tolist(),
                       b.node_ids[b.graph.dst].tolist(),
                       b.graph.etype.tolist()))

    assert stats["hits"] == 2 and stats["misses"] == 1
    assert edges(batches[0]) == edges(batches[1]) == edges(batches[2])


# ---------------------------------------------------------------------------
# serving driver
# ---------------------------------------------------------------------------
def test_serve_rgnn_end_to_end():
    from repro.launch import serve_rgnn
    stats = serve_rgnn.serve(
        model="rgat", dataset="aifb", scale=0.05, layers=2, dim=8, hidden=8,
        classes=4, fanouts=[3, 3], batch_size=8, num_batches=3,
        tile=8, node_block=8, log=lambda *a, **k: None,
    )
    assert stats["batches"] == 3
    assert stats["latency_ms_p50"] > 0
    assert stats["seeds_per_s"] > 0
    assert stats["last_preds"].shape == (8,)


# ---------------------------------------------------------------------------
# fanout normalization
# ---------------------------------------------------------------------------
def test_dict_fanout_warns_on_unlisted_etypes(graph):
    """Pin the (surprising) dict-fanout default: etypes absent from the dict
    sample zero edges, and the sampler now says so out loud."""
    from repro.sampling.sampler import normalize_fanout

    with pytest.warns(UserWarning, match="unlisted"):
        f = normalize_fanout({0: 3, 2: 5}, graph.num_etypes)
    np.testing.assert_array_equal(
        f, [3, 0, 5] + [0] * (graph.num_etypes - 3))
    # sampling with it really draws no edges of the unlisted etypes
    with pytest.warns(UserWarning):
        seq = FanoutSampler(graph, [{0: 3, 2: 5}], seed=0).sample(SEEDS)
    assert set(np.unique(seq.blocks[0].graph.etype)) <= {0, 2}
    # a complete dict stays silent
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        normalize_fanout({e: 2 for e in range(graph.num_etypes)},
                         graph.num_etypes)


# ---------------------------------------------------------------------------
# device-native sampling (ISSUE 7): host/device parity + retrace-freeness
# ---------------------------------------------------------------------------
from repro.sampling import DeviceSampler  # noqa: E402


def _device_block_edges(mb, hop, num_nodes):
    """Global (src, dst, etype) multiset of one device block's real edges."""
    nid = np.asarray(mb.seq.blocks[hop].node_ids)
    gt = mb.tensors[hop]
    src_g = nid[np.asarray(gt.src)]
    dst_g = nid[np.asarray(gt.dst)]
    et = np.asarray(gt.etype)
    valid = (src_g < num_nodes) & (dst_g < num_nodes)
    return sorted(zip(src_g[valid].tolist(), dst_g[valid].tolist(),
                      et[valid].tolist()))


@pytest.mark.parametrize("fanouts", [[3, 3], [2, 4], [1]])
def test_device_sampler_matches_host_blocks(graph, fanouts):
    """The determinism contract: for the same (seed, batch_index, epoch)
    stream position both pipelines select the same edge multisets per
    (dst, etype) and produce the same frontier node sets."""
    host = FanoutSampler(graph, fanouts, seed=11)
    dev = DeviceSampler(graph, fanouts, seed=11, tile=8, node_block=8)
    for bi in (0, 1, 5):
        seq = host.sample(SEEDS, batch_index=bi)
        mb = dev.sample_minibatch(SEEDS, batch_index=bi)
        for hop in range(len(fanouts)):
            hb = seq.blocks[hop]
            host_edges = sorted(zip(
                hb.node_ids[hb.graph.src].tolist(),
                hb.node_ids[hb.graph.dst].tolist(),
                hb.graph.etype.tolist()))
            assert _device_block_edges(mb, hop, graph.num_nodes) \
                == host_edges
            nid = np.asarray(mb.seq.blocks[hop].node_ids)
            np.testing.assert_array_equal(nid[nid < graph.num_nodes],
                                          hb.node_ids)
        np.testing.assert_array_equal(np.asarray(mb.seed_perm),
                                      seq.seed_perm)


def test_device_sampler_epoch_rekeys_stream(graph):
    dev = DeviceSampler(graph, [3, 3], seed=0, tile=8, node_block=8)
    a = _device_block_edges(dev.sample_minibatch(SEEDS, epoch=0), 0,
                            graph.num_nodes)
    b = _device_block_edges(dev.sample_minibatch(SEEDS, epoch=1), 0,
                            graph.num_nodes)
    host = FanoutSampler(graph, [3, 3], seed=0)
    ha = host.sample(SEEDS, epoch=0).blocks[0]
    assert a != b
    assert a == sorted(zip(ha.node_ids[ha.graph.src].tolist(),
                           ha.node_ids[ha.graph.dst].tolist(),
                           ha.graph.etype.tolist()))


@pytest.mark.parametrize("prog_fn", [rgcn_program, rgat_program,
                                     hgt_program])
def test_device_minibatch_forward_matches_host(graph, feats, prog_fn):
    """A device-built MiniBatch is a drop-in: same per-seed outputs as the
    host-built one for the same stream position."""
    stack = HectorStack([prog_fn(16, 12), prog_fn(12, 6)], graph,
                        tile=8, node_block=8, jit=False)
    params = stack.init(jax.random.key(0))
    mb_h = build_minibatch(
        FanoutSampler(graph, [3, 3], seed=11).sample(SEEDS, batch_index=2),
        tile=8, node_block=8, bucket=True)
    mb_d = DeviceSampler(graph, [3, 3], seed=11, tile=8, node_block=8) \
        .sample_minibatch(SEEDS, batch_index=2)
    out_h = stack.apply_blocks(params, mb_h, feats)
    out_d = stack.apply_blocks(params, mb_d, feats)
    np.testing.assert_allclose(out_d, out_h, rtol=2e-4, atol=2e-4)


def test_device_full_fanout_matches_full_graph(graph, feats):
    stack = HectorStack([rgat_program(16, 12), rgat_program(12, 6)], graph,
                        tile=8, node_block=8, jit=False)
    params = stack.init(jax.random.key(0))
    full = stack.apply(params, {"feature": feats})
    mb = DeviceSampler(graph, [-1, -1], seed=0, tile=8, node_block=8) \
        .sample_minibatch(SEEDS)
    out = stack.apply_blocks(params, mb, feats)
    assert out.shape == (len(SEEDS), 6)
    np.testing.assert_allclose(out, full[SEEDS], rtol=2e-4, atol=2e-4)


def test_device_sampler_retrace_free_in_steady_state(graph):
    """Fixed-shape bucketing: recurring stream positions (the power-law
    serving assumption — same seeds at the same batch_index resample the
    same buckets) replay already-traced programs, zero jit retraces — and
    the sampling loop itself never blocks on a count readback."""
    dev = DeviceSampler(graph, [3, 3], seed=2, tile=8, node_block=8)
    stream = SeedStream(graph.num_nodes, 6, seed=5, num_distinct=3)
    # warmup cycle 1 traces the worst-case buckets; the drain barrier lands
    # every count inspection; cycle 2 traces the shrunken buckets
    for step in range(3):
        dev.sample_minibatch(stream.batch(step), batch_index=step % 3)
    dev.drain(block=True)
    assert dev.bucket_shrinks > 0
    for step in range(3, 6):
        dev.sample_minibatch(stream.batch(step), batch_index=step % 3)
    dev.drain(block=True)
    warm = dev.trace_count
    syncs = dev.count_syncs
    assert warm == dev.cache_misses
    for step in range(6, 12):
        dev.sample_minibatch(stream.batch(step), batch_index=step % 3)
    assert dev.trace_count == warm
    assert dev.cache_hits > 0
    assert dev.count_syncs == syncs   # steady state issued zero readbacks
    dev.drain(block=True)
    assert dev.bucket_overflows == 0  # no shrunken bucket truncated a batch


def test_device_loader_threadless_prefetch(graph, feats):
    """MiniBatchLoader in device mode: same iteration/StopIteration contract
    and block-cache semantics, zero host pipeline builds."""
    dev = DeviceSampler(graph, [3, 3], seed=2, tile=8, node_block=8)
    distinct, total = 2, 6
    loader = MiniBatchLoader(
        dev, SeedStream(graph.num_nodes, 6, seed=5, num_distinct=distinct),
        tile=8, node_block=8, bucket=True, num_batches=total,
        cache_blocks=8)
    try:
        batches = list(loader)
    finally:
        loader.close()
    assert loader.mode == "device"
    assert [mb.step for mb in batches] == list(range(total))
    assert loader.host_builds == 0
    assert loader.device_builds == distinct   # repeats hit the block cache
    assert loader.cache_stats()["block_cache"]["hits"] == total - distinct
    with pytest.raises(StopIteration):
        next(loader)
    # repeated batches reference the same device-built blocks
    np.testing.assert_array_equal(
        np.asarray(batches[0].tensors[0].src),
        np.asarray(batches[distinct].tensors[0].src))


def test_device_graph_csc_consistent(graph):
    """The uploaded CSC is exactly the (dst-major, etype-minor) view of the
    host graph's dst-sorted edges."""
    dg = graph.to_device_graph()
    indptr = np.asarray(dg.csc_indptr)
    csc_src = np.asarray(dg.csc_src)
    assert indptr[-1] == graph.num_edges
    r = graph.num_etypes
    et_dst_sorted = graph.etype[graph.perm_dst]
    for v, t in [(3, 0), (50, 2), (119, r - 1)]:
        lo, hi = indptr[v * r + t], indptr[v * r + t + 1]
        mask = (graph.dst_sorted == v) & (et_dst_sorted == t)
        np.testing.assert_array_equal(csc_src[lo:hi],
                                      graph.src[graph.perm_dst][mask])


# ---------------------------------------------------------------------------
# zipf-skewed seed stream + loader cache-rate reporting (ISSUE 9 satellites)
# ---------------------------------------------------------------------------
def test_seed_stream_zipf_deterministic_and_pinned():
    a = SeedStream(200, 64, seed=9, zipf_alpha=1.2)
    b = SeedStream(200, 64, seed=9, zipf_alpha=1.2)
    np.testing.assert_array_equal(a.batch(3), b.batch(3))
    # pure function of (seed, step): replaying a step yields the same batch
    np.testing.assert_array_equal(a.batch(3), a.batch(3))
    assert a.batch(0).dtype == np.int32
    # the distribution is *pinned*: inverse-CDF draws over rank
    # probabilities (r+1)^-alpha mapped through the seed-keyed rank
    # permutation, reproduced here from the documented spec
    rng = np.random.default_rng((9, 3))
    p = np.arange(1, 201, dtype=np.float64) ** -1.2
    cdf = np.cumsum(p / p.sum())
    ranks = np.searchsorted(cdf, rng.random(64), side="right")
    r2i = np.random.default_rng((9, 0x5eed)).permutation(200).astype(np.int64)
    np.testing.assert_array_equal(
        a.batch(3), r2i[np.minimum(ranks, 199)].astype(np.int32))


def test_seed_stream_zipf_skews_traffic():
    n = 500
    s = SeedStream(n, 256, seed=1, zipf_alpha=1.2)
    draws = np.concatenate([s.batch(t) for t in range(40)])
    counts = np.bincount(draws, minlength=n)
    top = np.sort(counts)[::-1]
    # power law: the top 10% of nodes absorb the majority of traffic,
    # which a uniform stream cannot produce at this sample size
    assert top[: n // 10].sum() / counts.sum() > 0.5
    # the hottest node is the permuted rank-0 id, not simply id 0
    assert np.argmax(counts) == s._rank2idx[0]


def test_seed_stream_uniform_path_bitwise_unchanged():
    # adding the skew knob must not perturb existing uniform streams: the
    # draw is pinned to the exact pre-knob Generator call (incl. dtype)
    s = SeedStream(120, 16, seed=4)
    expected = np.random.default_rng((4, 7)).integers(
        0, 120, size=16, dtype=np.int32)
    np.testing.assert_array_equal(s.batch(7), expected)


def test_seed_stream_ids_population():
    ids = np.array([5, 17, 40, 99], dtype=np.int32)
    s = SeedStream(ids=ids, batch_size=32, seed=0, zipf_alpha=1.5)
    assert s.num_nodes == 4
    assert set(s.batch(0).tolist()) <= set(ids.tolist())
    u = SeedStream(ids=ids, batch_size=32, seed=0)
    assert set(u.batch(0).tolist()) <= set(ids.tolist())
    with pytest.raises(ValueError):
        SeedStream(ids=np.empty(0, np.int32))
    with pytest.raises(ValueError):
        SeedStream(100, zipf_alpha=0.0)


def test_loader_stats_report_cache_hit_rates(graph):
    """build_stats()/cache_stats() carry per-cache hit *rates* and the
    LRU mirrors them into the metrics registry."""
    from repro import obs
    distinct, total = 2, 8
    with obs.scope(metrics=True) as sc:
        loader = MiniBatchLoader(
            FanoutSampler(graph, [3, 3], seed=2),
            SeedStream(graph.num_nodes, 6, seed=5, num_distinct=distinct),
            tile=8, node_block=8, bucket=True, num_batches=total,
            cache_blocks=8, cache_layouts=32,
        )
        try:
            for _ in loader:
                pass
        finally:
            loader.close()
        bs = loader.build_stats()
        want = (total - distinct) / total
        assert bs["block_cache_hit_rate"] == pytest.approx(want)
        assert 0.0 <= bs["layout_cache_hit_rate"] <= 1.0
        cs = loader.cache_stats()
        assert cs["block_cache"]["hit_rate"] == pytest.approx(want)
        snap = sc.registry.snapshot()
        rates = [m for m in snap["gauges"]
                 if m["name"] == "loader_cache_hit_rate"
                 and m["labels"].get("cache") == "block_cache"]
        assert rates and rates[0]["value"] == pytest.approx(want)


# ---------------------------------------------------------------------------
# loader failure / end-of-stream contracts (the serving runtime's hooks)
# ---------------------------------------------------------------------------
def test_loader_worker_exception_propagates(graph):
    """Regression: a crash anywhere in the producer pipeline must re-raise
    in the consumer (it used to sit in the prefetch queue behind built
    batches with the consumer eventually stalling), with the worker thread
    stopped and joined first."""
    sampler = FanoutSampler(graph, [2], seed=0)

    def bad_source(step):
        if step == 2:
            raise ValueError("seed source exploded at step 2")
        return np.arange(4, dtype=np.int32)

    loader = MiniBatchLoader(sampler, bad_source, tile=8, node_block=8)
    try:
        assert next(loader).step == 0
        assert next(loader).step == 1
        with pytest.raises(ValueError, match="exploded at step 2"):
            next(loader)
        # terminal after the failure: no hang, no resurrected worker
        with pytest.raises(StopIteration):
            next(loader)
    finally:
        loader.close()
    assert not loader._thread.is_alive()


def test_loader_callable_source_none_ends_stream(graph):
    """A callable seed source may return None to end an unbounded stream
    (how the serving runtime drains its loader at shutdown)."""
    sampler = FanoutSampler(graph, [2], seed=0)

    def source(step):
        return np.arange(4, dtype=np.int32) if step < 3 else None

    loader = MiniBatchLoader(sampler, source, tile=8, node_block=8)
    try:
        assert [mb.step for mb in loader] == [0, 1, 2]
        with pytest.raises(StopIteration):
            next(loader)
    finally:
        loader.close()
    assert not loader._thread.is_alive()


def test_shape_floors_converge_to_one_shape_set(graph):
    """Grow-only floors: batches at one seed count converge to a single
    signature (floors absorb per-hop bucket jitter, including the
    layout-internal segment-row buckets)."""
    from repro.sampling.bucketing import ShapeFloors
    from repro.sampling.loader import build_minibatch

    sampler = FanoutSampler(graph, [3, 3], seed=7)
    floors = ShapeFloors()
    sigs = []
    for i in range(12):
        seeds = np.random.default_rng(i).integers(
            0, graph.num_nodes, 8).astype(np.int32)
        seq = sampler.sample(seeds, batch_index=i)
        mb = build_minibatch(seq, step=i, tile=8, node_block=8, bucket=True,
                             shape_floors=floors)
        sigs.append(executor_signature(
            (mb.tensors, mb.layouts, mb.input_ids, mb.dst_locals,
             mb.seed_perm)))
        if i == 5:
            floors.bump(1)   # calibration-style headroom
    # after the floors saturate (probe + bump), signatures are constant
    tail = sigs[6:]
    assert all(s == tail[0] for s in tail)
    assert floors.growths >= 0
