"""Pipeline parallelism: numerics == sequential stages; differentiability."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code, devices=4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_matches_sequential():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_forward, bubble_fraction
        mesh = jax.make_mesh((4,), ('pod',))
        P_stages, M, mb, d = 4, 8, 2, 16
        rng = np.random.default_rng(0)
        params = {'w': jnp.asarray(rng.normal(size=(P_stages, d, d)) * 0.3,
                                   jnp.float32)}
        x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

        def stage(p, h):
            return jnp.tanh(h @ p['w'])

        out = pipeline_forward(stage, params, x, mesh)
        # sequential reference
        ref = x
        for i in range(P_stages):
            ref = jnp.tanh(ref @ params['w'][i])
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        # differentiable end to end
        g = jax.grad(lambda p: jnp.sum(
            pipeline_forward(stage, p, x, mesh) ** 2))(params)
        assert float(jnp.max(jnp.abs(g['w']))) > 0
        assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
        print('ok')
        """)
