"""§Perf optimization variants: numerical equivalence of the optimized
execution paths (EP-MoE, sequence-sharded decode, cross-KV caching) to the
baseline paths, on small multi-device meshes (subprocess isolation)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code, devices=8, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_ep_moe_equals_dense_dispatch():
    """v-B: shard_map EP all-to-all MoE == the GSPMD dense formulation."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs as C
        from repro.launch.mesh import make_mesh
        from repro.launch.partitioning import Partitioner
        from repro.nn.common import sharding_context
        from repro.nn import moe as MOE
        mesh = make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(0)
        # E=8 (plain EP, e_local=2) and E=2 (expert-replicated EP, dup=2)
        for e in (8, 2):
            k, d, f = 2, 16, 32
            params = MOE.init_moe(jax.random.key(e), d, f, e, jnp.float32)
            x = jnp.asarray(rng.normal(size=(4, 16, d)), jnp.float32)
            out_d, _ = MOE.moe_ffn(params, x, e, k, capacity_factor=8.0)
            part = Partitioner(mesh, C.get_reduced('moonshot-v1-16b-a3b'),
                               moe_ep=True)
            with sharding_context(part.logical_resolver()):
                out_e, aux = jax.jit(
                    lambda p, x: MOE.moe_ffn(p, x, e, k, capacity_factor=8.0)
                )(params, x)
            err = float(jnp.max(jnp.abs(out_d - out_e)))
            assert err < 1e-4, (e, err)
        e, k = 8, 2
        params = MOE.init_moe(jax.random.key(0), 16, 32, e, jnp.float32)
        x = jnp.asarray(rng.normal(size=(4, 16, 16)), jnp.float32)
        # gradient parity through the a2a path
        def loss(fn_ctx):
            def l(p):
                if fn_ctx:
                    with sharding_context(part.logical_resolver()):
                        o, _ = MOE.moe_ffn(p, x, e, k, capacity_factor=8.0)
                else:
                    o, _ = MOE.moe_ffn(p, x, e, k, capacity_factor=8.0)
                return jnp.sum(o ** 2)
            return l
        g_d = jax.grad(loss(False))(params)
        g_e = jax.jit(jax.grad(loss(True)))(params)
        for kk in g_d:
            ge = float(jnp.max(jnp.abs(g_d[kk] - g_e[kk])))
            assert ge < 1e-3, (kk, ge)
        print('ok')
        """)


def test_seqshard_decode_equals_baseline():
    """v-C: sequence-sharded partial-softmax decode == unsharded decode,
    for both full and sliding-window attention."""
    run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs as C
        from repro.launch.mesh import make_mesh
        from repro.launch.partitioning import Partitioner
        from repro.nn.common import sharding_context
        from repro.lm.model import TransformerLM
        mesh = make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(0)
        for arch in ('qwen3-4b', 'gemma2-2b'):
            cfg = dataclasses.replace(C.get_reduced(arch), num_kv_heads=2)
            model = TransformerLM(cfg, remat=False)
            p = model.init(jax.random.key(1))
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 17)),
                               jnp.int32)
            _, caches = model.prefill(p, toks[:, :16], cache_len=24)
            ref, _ = model.decode_step(p, toks[:, 16:], 16, caches)
            part = Partitioner(mesh, cfg, mode='decode',
                               seq_shard_kv_decode=True)
            with sharding_context(part.logical_resolver()):
                got, _ = jax.jit(lambda p, t, c: model.decode_step(
                    p, t, 16, c))(p, toks[:, 16:], caches)
            err = float(jnp.max(jnp.abs(ref - got)))
            assert err < 1e-2, (arch, err)
        print('ok')
        """)


def test_cross_kv_cache_consistency():
    """v-G: decode with cached cross K/V == full forward (enc-dec + VLM)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs as C
        from repro.lm.model import TransformerLM
        rng = np.random.default_rng(0)
        for arch in ('whisper-medium', 'llama-3.2-vision-11b'):
            cfg = C.get_reduced(arch)
            model = TransformerLM(cfg, remat=False)
            p = model.init(jax.random.key(0))
            b, s = 2, 12
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)),
                               jnp.int32)
            if cfg.encoder_layers:
                fe = jnp.asarray(rng.normal(
                    size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
            else:
                fe = jnp.asarray(rng.normal(
                    size=(b, cfg.frontend_tokens, cfg.frontend_dim)),
                    jnp.float32)
            hidden, _, _ = model.backbone(p, toks, frontend=fe)
            want = model.logits(p, hidden[:, -1:])
            _, caches = model.prefill(p, toks[:, :s], frontend=fe,
                                      cache_len=s + 4)
            # decode WITHOUT passing the frontend: cross K/V must come from
            # the cache (the whole point of v-G)
            got, _ = model.decode_step(p, toks[:, s:], s, caches)
            err = float(jnp.max(jnp.abs(got - want)))
            assert err < 5e-2, (arch, err)
        print('ok')
        """, devices=1)
