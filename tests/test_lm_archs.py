"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs forward + one train step on CPU with correct shapes and
no NaNs; decode consistency for representative families."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs as C
from repro.lm.model import TransformerLM

RNG = np.random.default_rng(0)


def _frontend(cfg, b):
    if cfg.encoder_layers:
        return jnp.asarray(RNG.normal(size=(b, cfg.encoder_seq, cfg.d_model)),
                           jnp.float32)
    if cfg.frontend_tokens:
        return jnp.asarray(
            RNG.normal(size=(b, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    return None


@pytest.mark.parametrize("arch", C.ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = C.get_reduced(arch)
    model = TransformerLM(cfg, remat=False)
    params = model.init(jax.random.key(0))
    b, s = 2, 16
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    fe = _frontend(cfg, b)
    if fe is not None:
        batch["frontend"] = fe

    hidden, _, _ = model.backbone(params, batch["tokens"], frontend=fe)
    assert hidden.shape == (b, s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss)

    # one SGD step changes params and keeps loss finite
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = jax.jit(model.loss)(params2, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", C.ARCHS)
def test_arch_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = C.get_config(arch)
    expected = {
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "mamba2-780m": (48, 1536, 1, 1, 0, 50280),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (got, expected)


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma2-2b", "mamba2-780m",
                                  "jamba-v0.1-52b", "whisper-medium"])
def test_decode_matches_full_forward(arch):
    """prefill(S) + decode(S) logits == forward(S+1) last logits."""
    cfg = C.get_reduced(arch)
    # avoid MoE token-dropping divergence between the two paths
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = TransformerLM(cfg, remat=False)
    params = model.init(jax.random.key(0))
    b, s = 2, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)
    fe = _frontend(cfg, b)

    hidden, _, _ = model.backbone(params, toks, frontend=fe)
    full_logits = model.logits(params, hidden[:, -2:-1])   # position s-1

    lg_pre, caches = model.prefill(params, toks[:, :s], frontend=fe,
                                   cache_len=s + 4)
    np.testing.assert_allclose(
        np.asarray(lg_pre, np.float32),
        np.asarray(model.logits(params, hidden[:, s - 1: s]), np.float32),
        rtol=2e-2, atol=2e-2)

    lg_dec, _ = model.decode_step(params, toks[:, s:s + 1], s, caches,
                                  frontend=fe)
    hidden2, _, _ = model.backbone(params, toks, frontend=fe)
    want = model.logits(params, hidden2[:, -1:])
    np.testing.assert_allclose(np.asarray(lg_dec, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_sliding_window_masks_differ():
    """Local vs global layers must produce different attention reach."""
    from repro.nn.attention import _mask
    q = jnp.arange(16, dtype=jnp.int32)
    k = jnp.arange(16, dtype=jnp.int32)
    full = _mask(q, k, None, True)
    local = _mask(q, k, 4, True)
    assert bool(full[15, 0]) and not bool(local[15, 0])
    assert bool(local[15, 13])
    # causality in both
    assert not bool(full[0, 5]) and not bool(local[0, 5])


def test_param_counts_match_published():
    published = {
        "jamba-v0.1-52b": 52e9, "qwen3-4b": 4.0e9, "gemma2-2b": 2.6e9,
        "qwen3-14b": 14.8e9, "gemma3-4b": 3.9e9, "mamba2-780m": 0.78e9,
        "grok-1-314b": 314e9, "whisper-medium": 0.96e9,
    }
    for arch, want in published.items():
        got = C.get_config(arch).param_count()
        assert abs(got - want) / want < 0.12, (arch, got, want)
