"""Deterministic stand-in for ``hypothesis`` when the real package is absent.

The tier-1 suite must collect (and keep its property tests meaningful) on
machines without optional dev deps. ``conftest.py`` installs this module as
``sys.modules["hypothesis"]`` only when the real library is missing; each
``@given`` test then runs ``max_examples`` seeded pseudo-random examples.
Seeding is per-test (CRC32 of the qualname), so runs are reproducible and
independent of execution order.

Only the strategy surface the repo's tests use is implemented:
``integers``, ``sampled_from``, ``lists``, ``floats``, ``booleans``.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw_fn(rng)


class strategies:  # noqa: N801 - mimics the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(values) -> _Strategy:
        vals = list(values)
        return _Strategy(lambda rng: vals[int(rng.integers(len(vals)))])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0,
               **_kw) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)))


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 20)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((base, i))
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        # hide the drawn parameters from pytest's fixture resolution (the
        # real hypothesis does the same); leave any genuine fixture args.
        params = [p for name, p in inspect.signature(fn).parameters.items()
                  if name not in strategy_kwargs]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        return wrapper
    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
