"""Optimizer, checkpointing, data pipeline, compression, fault-tolerance."""
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.data.pipeline import PrefetchIterator, SyntheticLMStream
from repro.lm.config import ShapeCell
from repro.optim import AdamW, TrainState, cosine_schedule
from repro.optim.compression import (
    ErrorFeedback, dequantize_int8, quantize_int8,
)
from repro.runtime.fault import (
    ElasticController, HeartbeatMonitor, StragglerPolicy,
)
from repro.launch.mesh import plan_elastic_mesh


# --------------------------------------------------------------- optimizer
def test_adamw_optimizes_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = opt.init(params)

    @jax.jit
    def step(state):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(state.params)
        return opt.update(g, state)

    for _ in range(120):
        state = step(state)
    assert float(jnp.max(jnp.abs(state.params["w"]))) < 0.15


def test_adamw_clips_global_norm():
    opt = AdamW(learning_rate=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    g = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    new = opt.update(g, state)
    # lr=0: params unchanged; moments reflect clipped gradient
    assert float(jnp.max(new.mu["w"])) <= 0.11


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 2e-4
    assert float(lr(jnp.int32(100))) >= 1e-4 - 1e-9   # floor


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    ck.save(5, tree, blocking=True)
    out = ck.restore(tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_async_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        ck.save(s, jax.tree.map(lambda x: x + s, tree))
    ck.wait()
    assert ck.steps() == [3, 4]
    out = ck.restore(tree)          # latest
    np.testing.assert_allclose(out["w"], np.full(4, 4.0))


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(7, {"w": jnp.ones(2)}, blocking=True)
    names = [p.name for p in tmp_path.iterdir()]
    assert "step_00000007" in names
    assert not any(n.endswith(".tmp") for n in names)


# --------------------------------------------------------------- data
def test_stream_deterministic_per_step():
    cfg = __import__("repro.configs", fromlist=["x"]).get_reduced("qwen3-4b")
    cell = ShapeCell("t", 16, 4, "train")
    s1 = SyntheticLMStream(cfg, cell, seed=3)
    s2 = SyntheticLMStream(cfg, cell, seed=3)
    b1, b2 = s1.batch(11), s2.batch(11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch(12)["tokens"], b1["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_prefetch_iterator_order_and_restart():
    cfg = __import__("repro.configs", fromlist=["x"]).get_reduced("qwen3-4b")
    cell = ShapeCell("t", 8, 2, "train")
    stream = SyntheticLMStream(cfg, cell)
    it = PrefetchIterator(stream, start_step=5)
    steps = [next(it)[0] for _ in range(4)]
    it.close()
    assert steps == [5, 6, 7, 8]


# --------------------------------------------------------------- compression
def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, s = quantize_int8(x)
    x2 = dequantize_int8(q, s, x.shape, x.dtype)
    # blockwise int8: error bounded by scale/2 per element
    max_err = float(jnp.max(jnp.abs(x - x2)))
    assert max_err <= float(jnp.max(s)) * 0.51


def test_error_feedback_removes_bias():
    """Accumulated EF-compressed gradients converge to the true sum."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)}
    res = ErrorFeedback.init(g)
    acc = jnp.zeros(256)
    n = 50
    for _ in range(n):
        comp, res = ErrorFeedback.compress(g, res)
        acc = acc + comp["w"]
    true = g["w"] * n
    # without EF the quantization bias would accumulate linearly
    np.testing.assert_allclose(acc, true, atol=2e-3)


def test_compressed_psum_single_member():
    from functools import partial
    from repro.optim.compression import compressed_psum
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    mesh = jax.make_mesh((1,), ("pod",))
    x = jnp.asarray(np.random.default_rng(2).normal(size=(64,)), jnp.float32)
    f = shard_map(partial(compressed_psum, axis_name="pod"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    y = f(x)
    np.testing.assert_allclose(y, x, atol=np.max(np.abs(x)) / 100)


# --------------------------------------------------------------- fault
def test_heartbeat_death_detection():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1"], timeout=10, clock=lambda: t[0])
    t[0] = 5.0
    mon.heartbeat("h0")
    t[0] = 12.0
    assert mon.dead_hosts() == ["h1"]
    assert mon.alive_hosts() == ["h0"]


def test_straggler_policy_escalation():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1", "h2", "h3"], clock=lambda: t[0])
    pol = StragglerPolicy(trigger_factor=1.5, persist_steps=3)
    for step in range(6):
        for h in mon.hosts:
            mon.heartbeat(h, step, step_time=2.0 if h == "h3" else 1.0)
        actions = pol.decide(mon, spares=0)
    assert actions.get("h3") == "evict"
    actions = pol.decide(mon, spares=1)
    assert actions.get("h3") == "hot_swap"


def test_elastic_plan_preserves_tp():
    plan = plan_elastic_mesh(512 - 16, model_parallel=16)
    assert plan.shape[-1] == 16
    assert plan.used_devices == 496
    assert plan.dropped_devices == 0
    plan2 = plan_elastic_mesh(509, model_parallel=16)
    assert plan2.used_devices == 496 and plan2.dropped_devices == 13
    with pytest.raises(ValueError):
        plan_elastic_mesh(15, model_parallel=16)


def test_elastic_controller_event_flow():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1"], timeout=5, clock=lambda: t[0])
    ctl = ElasticController(mon, devices_per_host=256, model_parallel=16)
    assert ctl.check(step=3) is None
    t[0] = 10.0
    mon.heartbeat("h0")
    t[0] = 12.0          # h0 heartbeat 2s ago (alive), h1 12s ago (dead)
    ev = ctl.check(step=7)
    assert ev is not None and ev.dead_hosts == ["h1"]
    plan = ctl.replan(ev)
    assert plan.used_devices == 256 and plan.shape[-1] == 16
