"""Online serving runtime tests: open-loop load determinism, deadline-aware
coalescing, the async request pipeline (graceful shutdown, no orphaned
threads), and multi-model tenancy isolation."""
import threading
import time

import numpy as np
import pytest
import jax

import hector
from repro.core.graph import synthetic_heterograph
from repro.serve import (LATE, OK, REJECTED_DEADLINE, REJECTED_OVERLOAD,
                         REJECTED_SHUTDOWN, Coalescer, LatencyModel,
                         MultiTenantRuntime, OpenLoopLoad, Request,
                         ServingRuntime, ladder)


# ---------------------------------------------------------------------------
# open-loop load generation
# ---------------------------------------------------------------------------
def test_ladder_rung_sets():
    assert ladder(16, "pow2") == [1, 2, 4, 8, 16]
    assert ladder(16, "fine") == [1, 2, 3, 4, 6, 8, 12, 16]
    assert ladder(5, "fine") == [1, 2, 3, 4, 6, 8]   # top rounds up to pow2
    with pytest.raises(ValueError):
        ladder(0)
    with pytest.raises(ValueError):
        ladder(8, "coarse")


@pytest.mark.parametrize("process", ["poisson", "burst", "uniform"])
def test_open_loop_schedule_deterministic(process):
    """The schedule is a pure function of the seed: same args -> identical
    requests (arrivals, seeds, sizes, SLOs); a different seed differs."""
    mk = lambda s: OpenLoopLoad(500, rate_rps=200.0, num_requests=24,
                                process=process, size_choices=(1, 2, 4),
                                slo_ms=(20.0, 50.0), seed=s)
    a, b = mk(3).requests(), mk(3).requests()
    assert len(a) == len(b) == 24
    for ra, rb in zip(a, b):
        assert ra.arrival_s == rb.arrival_s
        assert ra.slo_ms == rb.slo_ms
        np.testing.assert_array_equal(ra.seeds, rb.seeds)
    arr = np.array([r.arrival_s for r in a])
    assert np.all(np.diff(arr) >= 0)            # arrivals are sorted
    c = mk(4).requests()
    assert any(ra.arrival_s != rc.arrival_s or
               not np.array_equal(ra.seeds, rc.seeds)
               for ra, rc in zip(a, c))


def test_open_loop_burst_groups_and_tenant_routing():
    load = OpenLoopLoad(100, rate_rps=100.0, num_requests=12,
                        process="burst", burst_size=3, slo_ms=10.0,
                        models=("a", "b"), seed=0)
    reqs = load.requests()
    arr = [r.arrival_s for r in reqs]
    # bursts arrive back-to-back in groups of burst_size
    assert arr[0] == arr[1] == arr[2]
    assert arr[3] == arr[4] == arr[5] != arr[2]
    assert [r.model for r in reqs[:4]] == ["a", "b", "a", "b"]


# ---------------------------------------------------------------------------
# deadline-aware coalescing (unit level: no engine, synthetic clock)
# ---------------------------------------------------------------------------
def _req(rid, size=1, slo_ms=100.0, t_arrive=0.0):
    r = Request(rid=rid, seeds=np.arange(size, dtype=np.int32),
                arrival_s=0.0, slo_ms=slo_ms)
    r.t_arrive = t_arrive
    return r


def _model(table):
    lm = LatencyModel(headroom=1.0)
    for rung, ms in table.items():
        lm.calibrate(rung, ms)
    return lm


def test_coalescer_picks_largest_feasible_rung():
    """Admission merges into the largest rung whose *measured* latency
    meets the tightest in-batch deadline — not simply the largest rung."""
    lm = _model({1: 1.0, 2: 2.0, 4: 4.0, 8: 50.0})
    co = Coalescer([1, 2, 4, 8], lm, max_wait_ms=5.0)
    # 6 single-seed requests, 10 ms budget: rung 8 (50 ms) is infeasible,
    # rung 4 (4 ms) fits -> admit exactly 4 requests at rung 4
    pending = [_req(i, slo_ms=10.0) for i in range(6)]
    d = co.plan(pending, now=0.0)
    assert d.batch is not None and d.batch.rung == 4
    assert [r.rid for r in d.batch.requests] == [0, 1, 2, 3]
    assert len(pending) == 2 and not d.rejects
    assert d.batch.seeds.shape == (4,)
    assert d.batch.slices == [(0, 1), (1, 2), (2, 3), (3, 4)]


def test_coalescer_rejects_expired_never_serves_late_silently():
    lm = _model({1: 5.0, 8: 10.0})
    co = Coalescer([1, 8], lm, max_wait_ms=1.0)
    pending = [
        _req(0, slo_ms=100.0),                 # healthy
        _req(1, slo_ms=10.0, t_arrive=-1.0),   # deadline already passed
        _req(2, slo_ms=4.0),                   # slack < smallest-rung est
    ]
    d = co.plan(pending, now=0.0, drain=True)
    assert sorted(r.rid for r in d.rejects) == [1, 2]
    assert d.batch is not None
    assert [r.rid for r in d.batch.requests] == [0]


def test_coalescer_waits_for_fill_then_drain_flushes():
    """With loose deadlines and a part-filled rung the coalescer holds for
    more arrivals; drain (shutdown) admits immediately."""
    lm = _model({1: 1.0, 2: 1.5, 4: 2.0})
    co = Coalescer([1, 2, 4], lm, max_wait_ms=50.0)
    pending = [_req(0, slo_ms=10_000.0)]
    d = co.plan(pending, now=0.0)
    assert d.batch is None and not d.rejects and d.wait_s > 0
    assert len(pending) == 1
    d = co.plan(pending, now=0.0, drain=True)
    assert d.batch is not None and d.batch.requests[0].rid == 0
    assert d.batch.rung == 1                    # covering rung, minimal pad
    assert not pending


def test_coalescer_padding_repeats_first_seed():
    lm = _model({4: 1.0})
    co = Coalescer([4], lm, max_wait_ms=0.0)
    pending = [_req(0, size=3, slo_ms=100.0)]
    d = co.plan(pending, now=0.0, drain=True)
    np.testing.assert_array_equal(d.batch.seeds, np.array([0, 1, 2, 0]))


def test_latency_model_jumps_up_decays_down():
    lm = LatencyModel(alpha=0.5, headroom=1.0)
    lm.calibrate(4, 10.0)
    lm.observe(4, 40.0)
    assert lm.estimate(4) == 40.0               # spikes register instantly
    lm.observe(4, 10.0)
    assert 10.0 < lm.estimate(4) < 40.0         # recovery is gradual
    # unmeasured rung falls back to the nearest measured rung above
    lm.calibrate(16, 100.0)
    assert lm.estimate(8) == 100.0


# ---------------------------------------------------------------------------
# the async runtime end-to-end (small compiled engine)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    graph = synthetic_heterograph(num_nodes=160, num_edges=900, num_ntypes=3,
                                  num_etypes=4, seed=0)
    engine = hector.compile("rgat", graph, layers=1, dim=8, hidden=8,
                            classes=4, sample=2, tile=8, node_block=8,
                            bucket=True, seed=0)
    params = engine.init(jax.random.key(0))
    feats = np.random.default_rng(1).normal(
        size=(graph.num_nodes, 8)).astype(np.float32)
    store = engine.make_feature_store(feats)
    return graph, engine, params, store


def _runtime(served, **kw):
    graph, engine, params, store = served
    kw.setdefault("rungs", ladder(4, "fine"))
    kw.setdefault("max_wait_ms", 2.0)
    return ServingRuntime(engine, params, store, **kw)


def _calibrate(rt):
    rt.calibrate(batches_per_rung=1, validate=False, iters=1,
                 probe_batches=4, warm_rounds=2)


def test_runtime_end_to_end_all_ok_zero_retraces(served):
    graph = served[0]
    rt = _runtime(served)
    try:
        _calibrate(rt)
        load = OpenLoopLoad(graph.num_nodes, rate_rps=400.0,
                            num_requests=16, size_choices=(1, 2, 4),
                            slo_ms=30_000.0, seed=2)
        handles = [rt.submit(r) for r in load.requests()]
        rt.drain(timeout=60.0)
        for h in handles:
            resp = h.wait(timeout=10.0)
            assert resp is not None and resp.status == OK
            assert resp.logits.shape[1] == 4
            assert np.all(np.isfinite(resp.logits))
            assert resp.latency_ms >= resp.queue_ms >= 0.0
        s = rt.stats()
        assert s["requests"] == 16 and s["by_status"] == {OK: 16}
        assert s["slo_attainment"] == 1.0
        assert s["retraces_after_warmup"] == 0
        # a floor growth without a retrace is benign (the grown bucket was
        # already compiled); with the short probe pass used here allow one
        assert s["shape_floor_growths"] <= 1
    finally:
        rt.close()


def test_runtime_response_sizes_match_requests(served):
    graph = served[0]
    rt = _runtime(served)
    try:
        _calibrate(rt)
        sizes = [1, 3, 2, 4]
        handles = [
            rt.submit(Request(rid=i, seeds=np.arange(sz, dtype=np.int32),
                              arrival_s=0.0, slo_ms=30_000.0))
            for i, sz in enumerate(sizes)]
        rt.drain(timeout=60.0)
        for sz, h in zip(sizes, handles):
            resp = h.wait(timeout=10.0)
            assert resp.status == OK and resp.logits.shape == (sz, 4)
    finally:
        rt.close()


def test_runtime_rejects_unmeetable_deadline(served):
    rt = _runtime(served)
    try:
        _calibrate(rt)
        h = rt.submit(Request(rid=0, seeds=np.arange(2, dtype=np.int32),
                              arrival_s=0.0, slo_ms=1e-6))
        resp = h.wait(timeout=10.0)
        assert resp is not None and resp.status == REJECTED_DEADLINE
        assert resp.logits is None
    finally:
        rt.close()
    assert rt.stats()["deadline_misses"] == 1


def test_runtime_oversized_request_raises(served):
    rt = _runtime(served)
    try:
        with pytest.raises(ValueError, match="exceed the top"):
            rt.submit(Request(rid=0, seeds=np.arange(64, dtype=np.int32),
                              arrival_s=0.0, slo_ms=1000.0))
    finally:
        rt.close()


def test_runtime_close_is_graceful_and_leaves_no_threads(served):
    """close() drains: queued requests terminate (served or rejected with
    REJECTED_SHUTDOWN), every handle resolves, and no worker thread
    survives — including the loader's prefetch thread."""
    rt = _runtime(served)
    try:
        _calibrate(rt)
        rt.start()
        handles = [
            rt.submit(Request(rid=i, seeds=np.arange(1, dtype=np.int32),
                              arrival_s=0.0, slo_ms=30_000.0))
            for i in range(6)]
    finally:
        rt.close()
    for h in handles:
        resp = h.wait(timeout=5.0)
        assert resp is not None
        assert resp.status in (OK, LATE, REJECTED_SHUTDOWN)
    assert all(not t.is_alive() for t in rt.worker_threads() if t)
    # post-close submissions are rejected, not queued
    h = rt.submit(Request(rid=99, seeds=np.arange(1, dtype=np.int32),
                          arrival_s=0.0, slo_ms=1000.0))
    assert h.wait(timeout=1.0).status == REJECTED_SHUTDOWN
    rt.close()   # idempotent


def test_runtime_close_without_start(served):
    rt = _runtime(served)
    rt.close()
    assert all(not t.is_alive() for t in rt.worker_threads() if t)


# ---------------------------------------------------------------------------
# multi-model tenancy
# ---------------------------------------------------------------------------
def test_tenancy_routes_by_model_and_never_cross_retraces(served):
    """Two tenants share the process; traffic routed by Request.model.
    Serving one tenant must never retrace the other (isolation comes from
    per-plan compile-cache keys): after each tenant's own calibration,
    interleaved two-tenant traffic leaves both at zero retraces."""
    graph, engine_a, params_a, store_a = served
    engine_b = hector.compile("rgcn", graph, layers=1, dim=8, hidden=8,
                              classes=4, sample=2, tile=8, node_block=8,
                              bucket=True, seed=0)
    params_b = engine_b.init(jax.random.key(1))
    feats = np.random.default_rng(2).normal(
        size=(graph.num_nodes, 8)).astype(np.float32)
    store_b = engine_b.make_feature_store(feats)

    mt = MultiTenantRuntime()
    mt.add(ServingRuntime(engine_a, params_a, store_a, name="a",
                          rungs=ladder(4, "fine"), max_wait_ms=2.0))
    mt.add(ServingRuntime(engine_b, params_b, store_b, name="b",
                          rungs=ladder(4, "fine"), max_wait_ms=2.0))
    try:
        mt.calibrate(batches_per_rung=1, validate=False, iters=1,
                     probe_batches=4, warm_rounds=2)
        load = OpenLoopLoad(graph.num_nodes, rate_rps=400.0,
                            num_requests=16, size_choices=(1, 2),
                            slo_ms=30_000.0, models=("a", "b"), seed=5)
        handles = [mt.submit(r) for r in load.requests()]
        mt.drain(timeout=60.0)
        assert all(h.wait(timeout=10.0).status == OK for h in handles)
        s = mt.stats()
        assert s["tenants"]["a"]["requests"] == 8
        assert s["tenants"]["b"]["requests"] == 8
        assert s["tenants"]["a"]["retraces_after_warmup"] == 0
        assert s["tenants"]["b"]["retraces_after_warmup"] == 0
        assert s["retraces_after_warmup"] == 0
    finally:
        mt.close()
    assert all(not t.is_alive() for t in mt.worker_threads() if t)


def test_tenancy_routing_errors():
    mt = MultiTenantRuntime()
    with pytest.raises(RuntimeError):
        mt.start()
    req = Request(rid=0, seeds=np.arange(1, dtype=np.int32),
                  arrival_s=0.0, slo_ms=10.0, model="ghost")
    with pytest.raises(KeyError):
        mt.submit(req)
