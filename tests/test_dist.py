"""Data-parallel execution over a partitioned hetero graph (``repro.dist``).

Single-device tests pin the layer's parity contracts — the partitioner's
covering invariants, the sharded sampler drawing the *same* counter-based
key stream as the single-box ``FanoutSampler``, seed routing, batcher
caching, and the ``shard_map`` serve/train steps matching the plain block
executors. The subprocess tests force 4 CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count``) and pin device-count
invariance: dp=4 must be *bitwise* identical to dp=1 because every compiled
collective reduces over the stacked shard axis of length P, independent of
how the shards fold onto devices.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import executor
from repro.core.graph import synthetic_heterograph
from repro.dist import (ShardedBatcher, ShardedSampler, check_partition,
                        partition_graph)
from repro.dist.data import route_seeds
from repro.optim import AdamW
from repro.sampling import FanoutSampler, MiniBatchLoader, SeedStream, \
    build_minibatch
from repro.sampling.loader import LRUCache
from repro.train import EngineConfig, RGNNEngine

from test_distributed import run_sub

SEEDS = np.array([3, 50, 7, 3, 119, 0, 88, 12], dtype=np.int32)


@pytest.fixture(scope="module")
def graph():
    return synthetic_heterograph(120, 900, 4, 7, seed=0)


@pytest.fixture(scope="module")
def part(graph):
    return partition_graph(graph, 4)


@pytest.fixture(scope="module")
def dist_engine(graph):
    """Engine with the distributed surface on (4 shards, 1 device)."""
    return RGNNEngine(graph, EngineConfig(
        model="rgat", layers=2, dim=16, hidden=12, classes=6,
        fanouts=[3, 3], tile=8, node_block=8, seed=0, partitions=4))


@pytest.fixture(scope="module")
def feats(graph):
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.normal(size=(graph.num_nodes, 16)), jnp.float32)


@pytest.fixture(scope="module")
def labels(graph):
    return np.asarray(np.random.default_rng(2).integers(
        0, 6, graph.num_nodes))


# ---------------------------------------------------------------------------
# partitioner
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("num_parts", [1, 3, 4])
def test_partition_invariants(graph, num_parts):
    """Edge-cut-by-dst covering invariants: shards tile the node range,
    every edge lands in exactly its dst's owner with its global dst-sorted
    position preserved, halos are the out-of-shard sources."""
    assert check_partition(partition_graph(graph, num_parts))


def test_partition_explicit_bounds(graph):
    part = partition_graph(graph, 2, bounds=np.array([0, 30, 120]))
    assert check_partition(part)
    assert part.shards[0].num_owned == 30
    np.testing.assert_array_equal(part.owner_of(np.array([0, 29, 30, 119])),
                                  [0, 0, 1, 1])


def test_partition_errors(graph):
    with pytest.raises(ValueError):
        partition_graph(graph, 0)
    with pytest.raises(ValueError):
        partition_graph(graph, graph.num_nodes + 1)


def test_shard_features_zero_padded(part, feats):
    sf = part.shard_features(np.asarray(feats))
    assert sf.shape[0] == part.num_parts
    for p in range(part.num_parts):
        lo, hi = int(part.bounds[p]), int(part.bounds[p + 1])
        np.testing.assert_array_equal(sf[p, :hi - lo], np.asarray(feats)[lo:hi])
        assert not sf[p, hi - lo:].any()


# ---------------------------------------------------------------------------
# sharded sampler: same key stream as the single-box sampler
# ---------------------------------------------------------------------------
def test_sharded_sampler_bit_identical_to_fanout_sampler(graph, part):
    """Selection is keyed by full-graph dst-sorted edge positions, so a
    shard sampling its owned seeds draws exactly the blocks the single-box
    sampler draws for the same seeds at the same stream position."""
    ss = ShardedSampler(part, [3, 3], seed=0)
    host = FanoutSampler(graph, [3, 3], seed=0)
    for p in range(part.num_parts):
        lo, hi = int(part.bounds[p]), int(part.bounds[p + 1])
        mine = SEEDS[(SEEDS >= lo) & (SEEDS < hi)]
        if mine.size == 0:
            mine = np.array([lo], dtype=np.int32)
        a = ss.sample_for_shard(p, mine, batch_index=5, epoch=2)
        b = host.sample(mine, batch_index=5, epoch=2)
        assert len(a.blocks) == len(b.blocks)
        for ba, bb in zip(a.blocks, b.blocks):
            np.testing.assert_array_equal(ba.node_ids, bb.node_ids)
            np.testing.assert_array_equal(ba.dst_local, bb.dst_local)
            np.testing.assert_array_equal(ba.graph.src, bb.graph.src)
            np.testing.assert_array_equal(ba.graph.dst, bb.graph.dst)
            np.testing.assert_array_equal(ba.graph.etype, bb.graph.etype)
        np.testing.assert_array_equal(a.seed_perm, b.seed_perm)
    stats = ss.stats()
    assert stats["local_lookups"] + stats["halo_lookups"] > 0


# ---------------------------------------------------------------------------
# seed routing + batcher
# ---------------------------------------------------------------------------
def test_route_seeds_reconstructs_request_order(part):
    shard_seeds, mask, route = route_seeds(part, SEEDS)
    # the executor's gather: flat [P*b_max] outputs indexed by route must
    # give one row per request, dupes and order preserved
    np.testing.assert_array_equal(shard_seeds.reshape(-1)[route], SEEDS)
    assert mask.sum() == len(SEEDS)
    # pad slots are selection-inert stand-ins: the shard's first owned node
    owners = part.owner_of(SEEDS)
    for p in range(part.num_parts):
        n_owned_here = int((owners == p).sum())
        np.testing.assert_array_equal(
            shard_seeds[p, n_owned_here:], part.bounds[p])
        np.testing.assert_array_equal(mask[p], np.arange(
            shard_seeds.shape[1]) < n_owned_here)


def test_sharded_batcher_caches_recurring_batches(part):
    bat = ShardedBatcher(part, [3, 3], seed=0, tile=8, node_block=8)
    a = bat.build(SEEDS, step=0, epoch=0)
    b = bat.build(SEEDS, step=7, epoch=0)
    assert bat.host_builds == 1 and b.step == 7
    for ga, gb in zip(a.tensors, b.tensors):
        assert ga.src.shape == gb.src.shape
    # a new epoch re-keys the sampler stream: fresh neighborhoods, no replay
    bat.build(SEEDS, step=8, epoch=1)
    assert bat.host_builds == 2
    # stacked shard tensors: leading axis P, equal buckets across shards
    assert a.tensors[0].src.shape[0] == part.num_parts


# ---------------------------------------------------------------------------
# loader cache partitioning (satellite: shards sharing a process)
# ---------------------------------------------------------------------------
def test_loader_cache_keys_include_partition(graph, part):
    stream = SeedStream(graph.num_nodes, 8, seed=5, num_distinct=2)
    mk = lambda partition: MiniBatchLoader(  # noqa: E731
        FanoutSampler(graph, [3, 3], seed=0), stream, tile=8, node_block=8,
        bucket=True, num_batches=1, cache_blocks=4, partition=partition)
    l0, l1, l0b, ln = mk((part, 0)), mk((part, 1)), mk((part, 0)), mk(None)
    try:
        k0, k1 = l0._cache_key(SEEDS, None), l1._cache_key(SEEDS, None)
        assert k0 != k1, "two shards would replay each other's blocks"
        assert k0 == l0b._cache_key(SEEDS, None)
        assert ln._cache_key(SEEDS, None) != k0
    finally:
        for ld in (l0, l1, l0b, ln):
            ld.close()


def test_layout_cache_scoped_by_partition(graph):
    """A layout cache shared across shards must namespace entries: the same
    block signature under two scopes is two entries, not one replay."""
    seq = FanoutSampler(graph, [3, 3], seed=0).sample(SEEDS, batch_index=0)
    cache = LRUCache(16, name="shared")
    build_minibatch(seq, tile=8, node_block=8, bucket=True,
                    layout_cache=cache, layout_scope="shard0")
    misses_one_scope = cache.misses
    build_minibatch(seq, tile=8, node_block=8, bucket=True,
                    layout_cache=cache, layout_scope="shard0")
    assert cache.misses == misses_one_scope  # same scope: pure hits
    build_minibatch(seq, tile=8, node_block=8, bucket=True,
                    layout_cache=cache, layout_scope="shard1")
    assert cache.misses == 2 * misses_one_scope  # new scope: no replay


# ---------------------------------------------------------------------------
# engine config surface
# ---------------------------------------------------------------------------
def test_engine_config_dist_validation():
    with pytest.raises(ValueError):
        EngineConfig(model="rgat", dp=0)
    with pytest.raises(ValueError):
        EngineConfig(model="rgat", dp=2, partitions=3)
    cfg = EngineConfig(model="rgat", dp=2)
    assert cfg.num_partitions == 2 and cfg.distributed
    cfg = EngineConfig(model="rgat", dp=2, partitions=6)
    assert cfg.num_partitions == 6
    assert not EngineConfig(model="rgat").distributed


# ---------------------------------------------------------------------------
# dist executors vs the plain single-box executors (1 device, P=4)
# ---------------------------------------------------------------------------
def test_dist_serve_matches_plain_executor_bitwise(dist_engine, graph,
                                                   feats):
    eng = dist_engine
    params = eng.init_params(jax.random.key(0))
    seq = FanoutSampler(graph, [3, 3], seed=0).sample(SEEDS, batch_index=0,
                                                      epoch=0)
    mb = build_minibatch(seq, tile=8, node_block=8, bucket=True)
    ref = np.asarray(executor.BlockExecutor(eng.plans, backend="xla")
                     .run_minibatch(params, mb, feats))

    smb = eng.dist_batcher.build(SEEDS, step=0, epoch=0)
    got = np.asarray(eng.dist_serve_executor().run_minibatch(
        params, smb, eng.shard_features(np.asarray(feats))))
    np.testing.assert_array_equal(got, ref)   # bitwise, not approx


def test_dist_train_step_matches_plain_executor(dist_engine, graph, feats,
                                                labels):
    eng = dist_engine
    params = eng.init_params(jax.random.key(0))
    opt = AdamW(learning_rate=1e-2, weight_decay=0.01)
    seq = FanoutSampler(graph, [3, 3], seed=0).sample(SEEDS, batch_index=0,
                                                      epoch=0)
    mb = build_minibatch(seq, tile=8, node_block=8, bucket=True)
    s_ref, m_ref = executor.BlockTrainExecutor(eng.plans, opt) \
        .grad_and_update(opt.init(params), mb,
                         jnp.asarray(seq.slice_labels(labels)),
                         {"feature": feats[mb.input_ids]})

    smb = eng.dist_batcher.build(SEEDS, step=0, epoch=0)
    s_got, m_got = eng.dist_train_executor(opt).grad_and_update(
        opt.init(params), smb, labels,
        eng.shard_features(np.asarray(feats)))
    # the per-shard partial losses sum to the global mean exactly
    assert float(m_ref["loss"]) == float(m_got["loss"])
    assert float(m_ref["accuracy"]) == float(m_got["accuracy"])
    # gradients agree up to summation association (the all-reduce sums
    # per-shard partials; the plain step sums per-seed rows)
    for a, b in zip(jax.tree_util.tree_leaves(s_ref.params),
                    jax.tree_util.tree_leaves(s_got.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_dist_trainer_loop_and_eval(dist_engine, graph, feats, labels):
    from repro.dist import DistTrainer
    eng = dist_engine
    ids = np.arange(0, 64, dtype=np.int32)
    tr = DistTrainer(eng, feats, labels, ids, val_ids=ids[:16], opt=None,
                     log=None)
    state = tr.init_state(eng.init_params(jax.random.key(0)))
    state, stats = tr.train(state, epochs=2, batch_size=16,
                            warmup_epochs=1)
    assert stats["steps"] == 8 and len(stats["losses"]) == 8
    assert np.isfinite(stats["final_loss"])
    assert stats["retraces_after_warmup"] == 0
    assert stats["num_partitions"] == 4 and stats["dp"] == 1
    ev = tr.evaluate(state.params, ids[:16], batch_size=16)
    assert np.isfinite(ev["loss"]) and 0.0 <= ev["accuracy"] <= 1.0


# ---------------------------------------------------------------------------
# multi-device: dp=4 == dp=1 bitwise (forced 4-device CPU subprocess)
# ---------------------------------------------------------------------------
def test_dp4_matches_dp1_bitwise():
    """Device-count invariance: folding 4 shards onto 1 device or spreading
    them 1-per-device changes nothing — serve logits, train loss, and the
    whole updated optimizer state are bitwise identical, because every
    reduction runs over the stacked [P, ...] axis in the same order."""
    stdout = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        assert jax.device_count() == 4, jax.devices()
        from repro.core.graph import synthetic_heterograph
        from repro.dist import (partition_graph, ShardedBatcher,
                                ShardedServeExecutor, ShardedTrainExecutor)
        from repro.launch.mesh import make_data_mesh
        from repro.optim import AdamW
        from repro.train import EngineConfig, RGNNEngine

        g = synthetic_heterograph(120, 900, 4, 7, seed=0)
        part = partition_graph(g, 4)
        SEEDS = np.array([3, 50, 7, 3, 119, 0, 88, 12], dtype=np.int32)
        eng = RGNNEngine(g, EngineConfig(
            model="rgat", layers=2, dim=16, hidden=12, classes=6,
            fanouts=[3, 3], tile=8, node_block=8, seed=0))
        rng = np.random.default_rng(1)
        feats = np.asarray(rng.normal(size=(g.num_nodes, 16)), np.float32)
        labels = np.asarray(rng.integers(0, 6, g.num_nodes))
        params = eng.init_params(jax.random.key(0))
        own = jnp.asarray(part.shard_features(feats))
        smb = ShardedBatcher(part, [3, 3], seed=0, tile=8,
                             node_block=8).build(SEEDS, step=0, epoch=0)
        opt = AdamW(learning_rate=1e-2, weight_decay=0.01)
        out = {}
        for dp in (1, 4):
            mesh = make_data_mesh(dp)
            logits = np.asarray(ShardedServeExecutor(eng.plans, mesh)
                                .run_minibatch(params, smb, own))
            st, m = ShardedTrainExecutor(eng.plans, opt, mesh) \\
                .grad_and_update(opt.init(params), smb, labels, own)
            leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(
                (st.params, st.mu, st.nu))]
            out[dp] = (logits, float(m["loss"]), leaves)
        assert (out[1][0] == out[4][0]).all(), "serve logits differ"
        assert out[1][1] == out[4][1], "loss differs"
        assert all((a == b).all() for a, b in zip(out[1][2], out[4][2])), \\
            "optimizer state differs"
        print("bitwise-ok")
        """, devices=4)
    assert "bitwise-ok" in stdout


def test_data_mesh_and_elastic_shrink():
    """make_data_mesh over forced CPU devices + the data-only elastic
    branch: losing a device shrinks dp while logical shards refold."""
    stdout = run_sub("""
        import jax
        from repro.launch.mesh import make_data_mesh, plan_elastic_mesh
        m = make_data_mesh()
        assert m.devices.shape == (4,) and m.axis_names == ("data",)
        m2 = make_data_mesh(2)
        assert m2.devices.shape == (2,)
        plan = plan_elastic_mesh(3, model_parallel=1, data_only=True)
        assert plan.shape == (3,) and plan.axes == ("data",)
        assert plan.dp_degree == 3 and plan.dropped_devices == 0
        # the default (LM) planner keeps the trailing model axis alive
        lm = plan_elastic_mesh(3, model_parallel=1)
        assert lm.shape == (3, 1) and lm.axes == ("data", "model")
        m3 = make_data_mesh(plan.shape[0], devices=jax.devices()[:3])
        assert m3.devices.shape == (3,)
        print("mesh-ok")
        """, devices=4)
    assert "mesh-ok" in stdout
