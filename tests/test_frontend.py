"""Frontend DSL tests: traced programs are structurally identical to the
hand-built IR trees the models used to assemble, malformed models are
rejected at trace time with source-located diagnostics, and the unified
``hector.compile()`` facade drives every execution mode."""
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import hector
from repro.core.graph import synthetic_heterograph
from repro.core.ir import inter_op as I
from repro.core.ir.passes import lower_program
from repro.models import (baselines, hgt_program, rgat_program,
                          rgcn_cat_program, rgcn_program)


# ---------------------------------------------------------------------------
# hand-built reference trees (the pre-DSL model definitions, verbatim)
# ---------------------------------------------------------------------------
def rgcn_handbuilt(in_dim, out_dim, activation="relu"):
    W_r = I.Weight("W_rel", (in_dim, out_dim), indexed_by="etype")
    W_0 = I.Weight("W_self", (in_dim, out_dim), indexed_by=None)
    stmts = [
        I.EdgeCompute("msg", I.TypedLinear(I.SrcFeature("feature"), W_r)),
        I.NodeAggregate("h_agg", msg="msg", reduce="mean"),
        I.NodeCompute("h_self", I.Linear(I.NodeFeature("feature"), W_0)),
        I.NodeCompute(
            "h_out",
            I.Unary(activation,
                    I.Binary("add", I.NodeVar("h_agg"), I.NodeVar("h_self")))),
    ]
    return I.Program(stmts=stmts, outputs=["h_out"], name="rgcn")


def rgat_handbuilt(in_dim, out_dim, slope=0.01):
    W = I.Weight("W_rel", (in_dim, out_dim), indexed_by="etype")
    w_s = I.Weight("w_att_src", (out_dim,), indexed_by="etype")
    w_t = I.Weight("w_att_dst", (out_dim,), indexed_by="etype")
    stmts = [
        I.EdgeCompute("hs", I.TypedLinear(I.SrcFeature("feature"), W)),
        I.EdgeCompute("atts", I.DotProduct(I.EdgeVar("hs"), w_s)),
        I.EdgeCompute(
            "attt",
            I.DotProduct(I.TypedLinear(I.DstFeature("feature"), W), w_t)),
        I.EdgeCompute(
            "att_raw",
            I.Unary("leaky_relu",
                    I.Binary("add", I.EdgeVar("atts"), I.EdgeVar("attt")),
                    alpha=slope)),
        I.EdgeSoftmax("att", "att_raw"),
        I.NodeAggregate("h_out", msg="hs", scale="att"),
    ]
    return I.Program(stmts=stmts, outputs=["h_out"], name="rgat")


def hgt_handbuilt(in_dim, out_dim):
    W_K = I.Weight("W_K", (in_dim, out_dim), indexed_by="ntype")
    W_Q = I.Weight("W_Q", (in_dim, out_dim), indexed_by="ntype")
    W_V = I.Weight("W_V", (in_dim, out_dim), indexed_by="ntype")
    W_A = I.Weight("W_att", (out_dim, out_dim), indexed_by="etype")
    W_M = I.Weight("W_msg", (out_dim, out_dim), indexed_by="etype")
    inv_sqrt_d = 1.0 / math.sqrt(out_dim)
    stmts = [
        I.NodeCompute("kk", I.TypedLinear(I.NodeFeature("feature"), W_K)),
        I.NodeCompute("qq", I.TypedLinear(I.NodeFeature("feature"), W_Q)),
        I.NodeCompute("vv", I.TypedLinear(I.NodeFeature("feature"), W_V)),
        I.EdgeCompute("katt", I.TypedLinear(I.SrcFeature("kk"), W_A)),
        I.EdgeCompute("msg", I.TypedLinear(I.SrcFeature("vv"), W_M)),
        I.EdgeCompute(
            "att_raw",
            I.Binary("mul",
                     I.DotProduct(I.EdgeVar("katt"), I.DstFeature("qq")),
                     I.Scalar(inv_sqrt_d))),
        I.EdgeSoftmax("att", "att_raw"),
        I.NodeAggregate("h_out", msg="msg", scale="att"),
    ]
    return I.Program(stmts=stmts, outputs=["h_out"], name="hgt")


PAIRS = [
    ("rgcn", rgcn_program, rgcn_handbuilt),
    ("rgat", rgat_program, rgat_handbuilt),
    ("hgt", hgt_program, hgt_handbuilt),
]


# ---------------------------------------------------------------------------
# trace fidelity: DSL == hand-built IR, program and plan level
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,traced_fn,hand_fn", PAIRS)
def test_traced_program_identical_to_handbuilt(name, traced_fn, hand_fn):
    traced, hand = traced_fn(16, 24), hand_fn(16, 24)
    assert traced == hand                      # dataclass equality (source excluded)
    assert traced.fingerprint() == hand.fingerprint()
    assert traced.describe() == hand.describe()
    # the traced program carries authoring provenance, the hand-built not
    assert traced.source and all(
        loc.line > 0 for loc in traced.source.values())


@pytest.mark.parametrize("name,traced_fn,hand_fn", PAIRS)
@pytest.mark.parametrize("reorder", [False, True])
@pytest.mark.parametrize("compact", [False, True])
def test_traced_plan_identical_to_handbuilt(name, traced_fn, hand_fn,
                                            reorder, compact):
    pt = lower_program(traced_fn(16, 24), reorder=reorder, compact=compact)
    ph = lower_program(hand_fn(16, 24), reorder=reorder, compact=compact)
    assert pt.describe() == ph.describe()
    assert pt.fingerprint() == ph.fingerprint()


def test_model_spec_tracing_is_repeatable():
    a, b = rgat_program(8, 8), rgat_program(8, 8)
    assert a == b and a.fingerprint() == b.fingerprint()
    # hyperparameters flow into the trace
    c = rgat_program(8, 8, slope=0.3)
    assert c != a and c.fingerprint() != a.fingerprint()


def test_program_describe_golden():
    assert rgcn_program(8, 8).describe() == (
        "Program<rgcn>\n"
        "  for e: e[msg] = (e.src.feature @ W_rel[etype:8x8])\n"
        "  for n: n[h_agg] = mean_incoming(e[msg])\n"
        "  for n: n[h_self] = (n.feature @ W_self[shared:8x8])\n"
        "  for n: n[h_out] = relu((n[h_agg] + n[h_self]))\n"
        "  outputs: h_out"
    )
    assert rgat_program(8, 8).describe() == (
        "Program<rgat>\n"
        "  for e: e[hs] = (e.src.feature @ W_rel[etype:8x8])\n"
        "  for e: e[atts] = dot(e[hs], w_att_src[etype:8])\n"
        "  for e: e[attt] = dot((e.dst.feature @ W_rel[etype:8x8]), "
        "w_att_dst[etype:8])\n"
        "  for e: e[att_raw] = leaky_relu((e[atts] + e[attt]), 0.01)\n"
        "  for e: e[att] = edge_softmax(e[att_raw])\n"
        "  for n: n[h_out] = sum_incoming(e[hs] * e[att])\n"
        "  outputs: h_out"
    )


def test_fingerprint_ignores_source_but_not_structure():
    prog = rgcn_program(8, 8)
    stripped = prog.clone()
    stripped.source = None
    assert stripped == prog
    assert stripped.fingerprint() == prog.fingerprint()
    mutated = prog.clone()
    mutated.outputs = ["h_agg"]
    assert mutated != prog
    assert mutated.fingerprint() != prog.fingerprint()


# ---------------------------------------------------------------------------
# error paths: each diagnostic names the offending model line
# ---------------------------------------------------------------------------
def _trace_error(spec, *args, **kwargs) -> hector.ProgramValidationError:
    with pytest.raises(hector.ProgramValidationError) as ei:
        spec(*args, **kwargs)
    return ei.value


def test_error_undefined_edge_var():
    @hector.model
    def m(g, e, n, i, o):
        W = g.weight("W", (i, o), indexed_by="etype")
        e["hs"] = e.src["feature"] @ W
        e["att"] = hector.edge_softmax(e["scores"])
        n["h"] = hector.aggregate(e["hs"], scale=e["att"])
        return n["h"]

    err = _trace_error(m, 8, 8)
    msg = str(err)
    assert "undefined edge var 'scores'" in msg
    assert "test_frontend.py" in msg            # the offending model line
    assert 'hector.edge_softmax(e["scores"])' in msg


def test_error_wrong_weight_index():
    @hector.model
    def m(g, e, n, i, o):
        W = g.weight("W_n", (i, o), indexed_by="ntype")
        e["hs"] = e.src["feature"] @ W
        n["h"] = hector.aggregate(e["hs"])
        return n["h"]

    msg = str(_trace_error(m, 8, 8))
    assert "W_n" in msg and "indexed_by='ntype'" in msg
    assert "for-each-edge" in msg
    assert "test_frontend.py" in msg and 'e.src["feature"] @ W' in msg


def test_error_dim_mismatch_in_matmul():
    @hector.model
    def m(g, e, n, i, o):
        W1 = g.weight("W1", (i, 32), indexed_by="etype")
        W2 = g.weight("W2", (16, o), indexed_by="etype")
        e["hs"] = (e.src["feature"] @ W1) @ W2
        n["h"] = hector.aggregate(e["hs"])
        return n["h"]

    msg = str(_trace_error(m, 8, 8))
    assert "dim mismatch in '@'" in msg
    assert "has dim 32" in msg and "'W2' expects 16" in msg
    assert "test_frontend.py" in msg


def test_error_node_var_where_edge_var_required():
    @hector.model
    def m(g, e, n, i, o):
        W = g.weight("W", (i, o))
        n["hn"] = n["feature"] @ W
        n["h"] = hector.aggregate(n["hn"])
        return n["h"]

    msg = str(_trace_error(m, 8, 8))
    assert "requires an edge var" in msg and "n[hn] is a node var" in msg
    assert "test_frontend.py" in msg and 'hector.aggregate(n["hn"])' in msg


def test_error_edge_softmax_on_node_var():
    @hector.model
    def m(g, e, n, i, o):
        W = g.weight("W", (i, o))
        n["hn"] = n["feature"] @ W
        e["att"] = hector.edge_softmax(n["hn"])
        return n["hn"]

    msg = str(_trace_error(m, 8, 8))
    assert "edge_softmax requires an edge var" in msg


def test_error_aggregate_assigned_to_edge_var():
    @hector.model
    def m(g, e, n, i, o):
        W = g.weight("W", (i, o), indexed_by="etype")
        e["hs"] = e.src["feature"] @ W
        e["h"] = hector.aggregate(e["hs"])
        return e["h"]

    msg = str(_trace_error(m, 8, 8))
    assert "reduces edges into nodes" in msg and "n['h']" in msg


def test_error_input_dim_conflict_between_uses():
    """The first '@' binds the input feature's dim; a later use with a
    differently-shaped weight is a located mismatch."""
    @hector.model
    def m(g, e, n, i, o):
        W1 = g.weight("W1", (i, o), indexed_by="etype")
        W2 = g.weight("W2", (i + 1, o), indexed_by="etype")
        e["a"] = e.src["feature"] @ W1
        e["b"] = e.src["feature"] @ W2
        n["h"] = hector.aggregate(e["a"])
        return n["h"]

    msg = str(_trace_error(m, 8, 8))
    assert "dim mismatch in '@'" in msg
    assert "has dim 8" in msg and "'W2' expects 9" in msg
    assert "statement 1" in msg


def test_error_typoed_node_var_read():
    """Reading a near-miss of a produced node var must fail at trace time
    with the defined names, not surface later as an executor fallback."""
    @hector.model
    def m(g, e, n, i, o):
        W_r = g.weight("W_rel", (i, o), indexed_by="etype")
        W_0 = g.weight("W_self", (i, o))
        e["msg"] = e.src["feature"] @ W_r
        n["h_agg"] = hector.aggregate(e["msg"], reduce="mean")
        n["h_self"] = n["feature"] @ W_0
        n["h_out"] = hector.relu(n["h_agg"] + n["h_sefl"])   # typo
        return n["h_out"]

    msg = str(_trace_error(m, 8, 8))
    assert "n.h_sefl" in msg and "check the name" in msg
    assert "h_agg" in msg and "h_self" in msg   # lists the defined vars
    assert "test_frontend.py" in msg


def test_fingerprint_distinguishes_close_scalars():
    """Scalar constants render at full precision: programs differing below
    1e-6 relative must not fingerprint identically."""
    def prog(c):
        W = I.Weight("W", (8, 8), indexed_by="etype")
        return I.Program(
            stmts=[I.EdgeCompute("hs",
                                 I.TypedLinear(I.SrcFeature("feature"), W)),
                   I.EdgeCompute("s", I.Binary("mul", I.EdgeVar("hs"),
                                               I.Scalar(c))),
                   I.NodeAggregate("h", msg="s")],
            outputs=["h"], name="p")

    a, b = prog(0.12345678), prog(0.12345679)
    assert a.fingerprint() != b.fingerprint()


def test_aggregate_materializes_distinct_temps_for_msg_and_scale():
    """Expression-valued msg AND scale must land in distinct derived edge
    vars (regression: both used to collapse onto one temp name)."""
    @hector.model
    def m(g, e, n, i, o):
        W = g.weight("W", (i, o), indexed_by="etype")
        w = g.weight("w", (o,), indexed_by="etype")
        e["hs"] = e.src["feature"] @ W
        n["h"] = hector.aggregate(e["hs"] * 2.0,
                                  scale=hector.exp(hector.dot(e["hs"], w)))
        return n["h"]

    prog = m(8, 8)
    agg = [s for s in prog.stmts if isinstance(s, I.NodeAggregate)][0]
    assert agg.msg != agg.scale
    defs = [s.out for s in prog.stmts if isinstance(s, I.EdgeCompute)]
    assert len(defs) == len(set(defs))          # no shadowed definitions


def test_reflected_scalar_division_traces():
    """1.0 / expr must trace to Binary('div', Scalar, expr), not raise a
    bare TypeError outside the DSL's diagnostics."""
    @hector.model
    def m(g, e, n, i, o):
        W = g.weight("W", (i, o), indexed_by="etype")
        w = g.weight("w", (o,), indexed_by="etype")
        e["hs"] = e.src["feature"] @ W
        e["s"] = 1.0 / hector.exp(hector.dot(e["hs"], w))
        n["h"] = hector.aggregate(e["hs"], scale=e["s"])
        return n["h"]

    prog = m(8, 8)
    div = [s for s in prog.stmts if isinstance(s, I.EdgeCompute)
           and s.out == "s"][0].expr
    assert isinstance(div, I.Binary) and div.op == "div"
    assert isinstance(div.a, I.Scalar) and div.a.value == 1.0


def test_scalar_broadcast_keeps_input_dim_unknown():
    """x * 2.0 must not collapse the dim to 1 and reject a later '@'
    (regression: scalar broadcasts inferred dim 1)."""
    @hector.model
    def m(g, e, n, i, o):
        W = g.weight("W", (i, o), indexed_by="etype")
        e["s"] = e.src["feature"] * 2.0
        e["hs"] = e["s"] @ W
        n["h"] = hector.aggregate(e["hs"])
        return n["h"]

    prog = m(8, 8)                              # traces without error
    assert prog.outputs == ["h"]


# ---------------------------------------------------------------------------
# the compile() facade
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def graph():
    return synthetic_heterograph(num_nodes=160, num_edges=1200, num_ntypes=4,
                                 num_etypes=7, seed=0)


@pytest.fixture(scope="module")
def feats(graph):
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.normal(size=(graph.num_nodes, 16)), jnp.float32)


def test_compile_full_lifecycle(graph, feats):
    compiled = hector.compile("rgat", graph, layers=2, dim=16, hidden=16,
                              classes=8, sample=4, tile=8, node_block=8)
    params = compiled.init(0)
    out = compiled.apply(params, feats)
    assert out.shape == (graph.num_nodes, 8)
    assert bool(jnp.all(jnp.isfinite(out)))

    labels = np.random.default_rng(2).integers(0, 8, graph.num_nodes)
    loader = compiled.make_loader(
        lambda step: np.arange(24, dtype=np.int32), num_batches=3, depth=1)
    state = compiled.init_state(params)
    losses = []
    try:
        for mb in loader:
            logits = compiled.apply_blocks(params, mb, feats)
            assert logits.shape == (24, 8)
            state, metrics = compiled.train_step(
                state, mb, mb.seq.slice_labels(labels), feats)
            losses.append(float(metrics["loss"]))
    finally:
        loader.close()
    assert len(losses) == 3 and all(np.isfinite(losses))
    assert losses[-1] < losses[0]              # the compiled step learns

    # init_state accepts every key flavor init() does (int / typed key /
    # legacy PRNGKey) and never mistakes a key for a params pytree
    for key in (0, jax.random.key(0), jax.random.PRNGKey(0)):
        st = compiled.init_state(key)
        assert isinstance(st.params, list) and isinstance(st.params[0], dict)


def test_compile_accepts_model_spec_and_kwargs(graph, feats):
    from repro.models import rgcn_cat
    compiled = hector.compile(rgcn_cat, graph, layers=1, dim=16, classes=8,
                              tile=8, node_block=8,
                              model_args={"activation": "tanh"})
    assert "rgcn_cat" in repr(compiled)
    params = compiled.init(0)
    out = compiled.apply(params, feats)
    assert out.shape == (graph.num_nodes, 8)
    # activation kwarg reached the traced program
    layer_prog = compiled.engine.stack.layers[0].program
    assert any(
        isinstance(s, I.NodeCompute) and isinstance(s.expr, I.Unary)
        and s.expr.op == "tanh" for s in layer_prog.stmts)


def test_compile_rejects_unknown_model(graph):
    with pytest.raises(ValueError, match="unknown model"):
        hector.compile("nope", graph)
    # the model-kwargs path must produce the same diagnostic, not KeyError
    with pytest.raises(ValueError, match="unknown model"):
        hector.compile("rgta", graph, slope=0.2)


def test_compile_matches_direct_module(graph, feats):
    """The facade's full-graph forward equals a hand-wired HectorModule."""
    from repro.core.module import HectorModule
    compiled = hector.compile("rgat", graph, layers=1, dim=16, classes=24,
                              tile=8, node_block=8)
    params = compiled.init(0)
    mod = HectorModule(rgat_program(16, 24), graph, tile=8, node_block=8)
    ref = mod.apply(params[0], {"feature": feats})["h_out"]
    np.testing.assert_allclose(compiled.apply(params, feats), ref,
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# the new DSL-authored model generalizes the surface
# ---------------------------------------------------------------------------
def test_rgcn_cat_lowers_without_fallback():
    plan = lower_program(rgcn_cat_program(16, 24))
    assert plan.fallback_count() == 0
    assert plan.gemm_count() == 3              # msg, h_self, h_mix
    assert plan.traversal_count() >= 2         # mean-agg + concat (+ act)


@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_rgcn_cat_matches_vanilla(graph, feats, backend):
    from repro.core.module import HectorModule
    mod = HectorModule(rgcn_cat_program(16, 24), graph, backend=backend,
                       tile=8, node_block=8)
    params = mod.init(jax.random.key(0))
    out = mod.apply(params, {"feature": feats})["h_out"]
    van = baselines.rgcn_cat_vanilla(params, graph.to_tensors(),
                                     {"feature": feats})["h_out"]
    assert out.shape == (graph.num_nodes, 24)
    np.testing.assert_allclose(out, van, rtol=2e-4, atol=2e-4)


def test_rgcn_cat_gradients_match(graph, feats):
    from repro.core.module import HectorModule
    mod = HectorModule(rgcn_cat_program(16, 24), graph,
                       backend="pallas_interpret", tile=8, node_block=8)
    params = mod.init(jax.random.key(0))
    g = jax.grad(lambda p: jnp.sum(
        mod.apply(p, {"feature": feats})["h_out"] ** 2))(params)
    gv = jax.grad(lambda p: jnp.sum(
        baselines.rgcn_cat_vanilla(p, graph.to_tensors(),
                                   {"feature": feats})["h_out"] ** 2))(params)
    for k in g:
        denom = float(jnp.max(jnp.abs(gv[k]))) + 1e-9
        np.testing.assert_allclose(np.asarray(g[k]) / denom,
                                   np.asarray(gv[k]) / denom,
                                   rtol=0, atol=5e-4, err_msg=k)


def test_rgcn_cat_registered_in_engine():
    from repro.train.engine import MODEL_PROGRAMS
    assert "rgcn_cat" in MODEL_PROGRAMS


# ---------------------------------------------------------------------------
# paper-scale brevity (§4.1): the three models stay within 60 LoC total
# ---------------------------------------------------------------------------
def test_three_model_definitions_within_60_loc():
    from repro.models import DSL_MODELS
    per_model = {k: DSL_MODELS[k].definition_loc
                 for k in ("rgcn", "rgat", "hgt")}
    assert sum(per_model.values()) <= 60, per_model
