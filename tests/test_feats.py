"""Tiered feature storage (ISSUE 9): budget splitting, bitwise tier
parity, CLOCK eviction determinism, overflow under forced tiny budgets,
compile stability (zero retraces after warmup), the gather_input
precedence rule, the measured budget split, and end-to-end serve/train
parity through the engine."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.graph import synthetic_heterograph
from repro.feats import (CachedFeatureStore, DeviceFeatureStore,
                         HostFeatureStore, gather_input, is_feature_store,
                         make_feature_store, split_budget)
from repro.sampling import SeedStream
from repro.train import EngineConfig, RGNNEngine
from repro.tune.feature_budget import measured_split


@pytest.fixture(scope="module")
def graph():
    return synthetic_heterograph(num_nodes=120, num_edges=900, num_ntypes=4,
                                 num_etypes=7, seed=0)


@pytest.fixture(scope="module")
def feats(graph):
    rng = np.random.default_rng(1)
    return rng.normal(size=(graph.num_nodes, 16)).astype(np.float32)


def id_batches(graph, n_batches=10, batch=24, seed=3, alpha=1.2):
    s = SeedStream(graph.num_nodes, batch, seed=seed, zipf_alpha=alpha)
    return [s.batch(t) for t in range(n_batches)]


# ---------------------------------------------------------------------------
# budget splitting
# ---------------------------------------------------------------------------
def test_split_budget_proportional_and_capped(graph):
    sizes = np.diff(graph.ntype_ptr)
    slots = split_budget(graph, 40)
    assert slots.sum() == 40
    assert (slots <= sizes).all()
    # proportional-ish to populations
    assert abs(slots / 40 - sizes / sizes.sum()).max() < 0.15

    # capping + redistribution: budget above one type's table size spills
    # to the others; total budget above N clamps to N
    full = split_budget(graph, graph.num_nodes + 50)
    np.testing.assert_array_equal(full, sizes)
    assert split_budget(graph, 0).sum() == 0

    # explicit weights steer the split; a zero-weight type gets no slots
    w = np.zeros(graph.num_ntypes)
    w[1] = 1.0
    focused = split_budget(graph, 10, weights=w)
    assert focused[1] == min(10, sizes[1])
    assert focused.sum() == min(10, sizes[1])
    with pytest.raises(ValueError):
        split_budget(graph, 10, weights=[1.0])


def test_measured_split_follows_traffic(graph):
    from repro.sampling import FanoutSampler
    # fanout 0 -> input rows are exactly the seeds, so traffic restricted
    # to one ntype must hand that type the whole (capped) budget while
    # zero-traffic types get nothing
    sampler = FanoutSampler(graph, [0], seed=0)
    sizes = np.diff(graph.ntype_ptr)
    lo, hi = int(graph.ntype_ptr[2]), int(graph.ntype_ptr[3])
    stream = SeedStream(ids=np.arange(lo, hi, dtype=np.int32),
                        batch_size=8, seed=1)
    slots, report = measured_split(graph, sampler, stream, budget=30,
                                   probe_batches=3)
    assert slots[2] == min(30, sizes[2])
    assert slots.sum() == slots[2]      # zero-weight types stay empty
    assert report["budget"] == 30 and len(report["row_counts"]) == 4
    assert report["row_counts"][2] > 0 == sum(
        report["row_counts"][t] for t in (0, 1, 3))

    # multi-hop traffic spreads over neighbor types: the split must follow
    # the *measured* counts, not populations
    deep = FanoutSampler(graph, [3, 3], seed=0)
    slots2, rep2 = measured_split(graph, deep, stream, budget=30,
                                  probe_batches=3)
    assert slots2.sum() == 30
    w = np.asarray(rep2["row_counts"], np.float64)
    np.testing.assert_array_equal(slots2, split_budget(graph, 30, weights=w))


# ---------------------------------------------------------------------------
# bitwise tier parity
# ---------------------------------------------------------------------------
def test_three_tiers_bitwise_identical_gathers(graph, feats):
    stores = [make_feature_store(feats, graph, kind=k, budget=30)
              for k in ("device", "host", "cached")]
    for step, ids in enumerate(id_batches(graph)):
        ref = feats[ids]
        for st in stores:
            got = np.asarray(st.gather(ids, step=step)["feature"])
            np.testing.assert_array_equal(got, ref), st.kind
    # host tier: per-ntype tables reconstruct the original rows exactly
    host = stores[1]
    all_ids = np.arange(graph.num_nodes)
    np.testing.assert_array_equal(host.host_rows(all_ids), feats)
    np.testing.assert_array_equal(np.asarray(host.full_table()), feats)
    # the cached tier really cached something along the way
    assert stores[2].hits > 0 and stores[2].misses > 0


def test_cached_eviction_deterministic(graph, feats):
    a = CachedFeatureStore(feats, graph, budget=24)
    b = CachedFeatureStore(feats, graph, budget=24)
    for step, ids in enumerate(id_batches(graph, n_batches=12)):
        fa = np.asarray(a.gather(ids, step=step)["feature"])
        fb = np.asarray(b.gather(ids, step=step)["feature"])
        np.testing.assert_array_equal(fa, fb)
    # identical streams -> identical counters AND identical residency state
    sa, sb = a.stats(), b.stats()
    assert {k: sa[k] for k in ("hits", "misses", "evictions", "overflows")} \
        == {k: sb[k] for k in ("hits", "misses", "evictions", "overflows")}
    np.testing.assert_array_equal(a._slot_gid, b._slot_gid)
    np.testing.assert_array_equal(a._gid2slot, b._gid2slot)
    np.testing.assert_array_equal(np.asarray(a.slots), np.asarray(b.slots))
    assert a.evictions > 0   # the budget is small enough to churn


def test_cached_tiny_budget_overflow_and_bounded_memory(graph, feats):
    """Forced tiny budget: batches larger than the cache overflow (ship
    uninserted) but stay bitwise-correct, and the device footprint stays
    strictly below the full table's."""
    st = CachedFeatureStore(feats, graph, budget=4)
    for step, ids in enumerate(id_batches(graph, n_batches=6, batch=32)):
        np.testing.assert_array_equal(
            np.asarray(st.gather(ids, step=step)["feature"]), feats[ids])
    assert st.overflows > 0
    assert st.device_bytes() < st.table_bytes
    assert st.device_bytes() == st.slots.shape[0] * st.dim * st.itemsize


def test_cached_zero_budget_type_still_correct(graph, feats):
    # a type with zero slots ships every row uncached, still bitwise-exact
    split = np.zeros(graph.num_ntypes, dtype=np.int64)
    split[0] = 8
    st = CachedFeatureStore(feats, graph, budget=8, split=split)
    ids = np.arange(graph.num_nodes, dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(st.gather(ids)["feature"]), feats)


# ---------------------------------------------------------------------------
# compile stability / cache-state threading
# ---------------------------------------------------------------------------
def test_cached_zero_retraces_after_warmup(graph, feats):
    st = CachedFeatureStore(feats, graph, budget=40)
    batches = id_batches(graph, n_batches=16, batch=16, alpha=1.4)
    # warmup: the miss path (both pow2 buckets a 16-id batch can produce)
    # and the fully-hot path (an immediately repeated batch)
    for step, ids in enumerate(batches[:6]):
        st.gather(ids, step=step)
    st.gather(batches[5], step=6)        # fully hot -> warms the hot program
    warm = st.trace_count
    misses_before = st.misses
    slots_before = st.slots
    for step, ids in enumerate(batches[6:], start=7):
        st.gather(ids, step=step)
    # fixed batch size + pow2 miss bucketing => a fixed compiled program
    # set after warmup; cache state is threaded functionally (the slab
    # object is rebound, not mutated in place on CPU)
    assert st.trace_count == warm
    assert st.stats()["trace_count"] == st.trace_count
    if st.misses > misses_before:
        assert st.slots is not slots_before


def test_cached_hot_batch_does_no_host_work(graph, feats):
    st = CachedFeatureStore(feats, graph, budget=graph.num_nodes)
    ids = np.array([3, 50, 7, 3, 99, 0], dtype=np.int32)
    st.gather(ids, step=0)
    gathers_after_warm = st.host_gathers
    moved = st.bytes_moved
    out = st.gather(ids, step=1)          # fully hot: zero host gathers
    np.testing.assert_array_equal(np.asarray(out["feature"]), feats[ids])
    assert st.host_gathers == gathers_after_warm
    assert st.bytes_moved == moved
    assert st.hit_rate > 0.0


# ---------------------------------------------------------------------------
# the consumption rule + store construction
# ---------------------------------------------------------------------------
def test_gather_input_precedence(graph, feats):
    class MB:  # minimal MiniBatch stand-in
        def __init__(self, ids, pre=None, step=0):
            self.input_ids = ids
            self.feats = pre
            self.step = step

    ids = np.array([5, 1, 5, 80], dtype=np.int32)
    store = make_feature_store(feats, graph, kind="host")
    # 1) loader-attached feats win unconditionally
    pre = {"feature": jnp.zeros((4, 16))}
    assert gather_input(store, MB(ids, pre=pre)) is pre
    # 2) a store gathers through its tier
    out = gather_input(store, MB(ids))
    np.testing.assert_array_equal(np.asarray(out["feature"]), feats[ids])
    # 3) a raw table falls back to the classic device-side gather
    out = gather_input(feats, MB(ids))
    np.testing.assert_array_equal(np.asarray(out["feature"]), feats[ids])
    assert is_feature_store(store) and not is_feature_store(feats)


def test_make_feature_store_kinds_and_validation(graph, feats):
    assert isinstance(make_feature_store(feats, graph), DeviceFeatureStore)
    assert isinstance(make_feature_store(feats, graph, kind="host"),
                      HostFeatureStore)
    cached = make_feature_store(feats, graph, kind="cached")
    assert isinstance(cached, CachedFeatureStore)
    assert cached.capacity == graph.num_nodes // 4   # default budget
    with pytest.raises(ValueError):
        make_feature_store(feats, graph, kind="nvme")
    with pytest.raises(ValueError):
        make_feature_store(feats[:10], graph)        # wrong row count
    with pytest.raises(ValueError):
        CachedFeatureStore(feats, graph, budget=8, split=[1, 2])
    with pytest.raises(ValueError):                  # slots > table size
        split = np.diff(graph.ntype_ptr).astype(np.int64)
        split[0] += 1
        CachedFeatureStore(feats, graph, budget=8, split=split)
    with pytest.raises(ValueError):
        EngineConfig(model="rgcn", feature_store="nvme")


# ---------------------------------------------------------------------------
# end-to-end: serve + train parity across tiers through the engine
# ---------------------------------------------------------------------------
def _engine(graph, fs, budget=40):
    cfg = EngineConfig(model="rgcn", layers=2, dim=16, hidden=16, classes=4,
                       fanouts=[3, 3], tile=8, node_block=8, seed=0,
                       feature_store=fs, feature_budget=budget)
    return RGNNEngine(graph, cfg)


def test_engine_serve_and_train_parity_across_tiers(graph, feats):
    from repro.optim import AdamW
    logits_by, losses_by = {}, {}
    for fs in ("device", "host", "cached"):
        engine = _engine(graph, fs)
        params = engine.init_params(jax.random.key(0))
        stream = SeedStream(graph.num_nodes, 12, seed=3, zipf_alpha=1.2)
        store = engine.make_feature_store(feats, seed_source=stream)
        assert store.kind == fs
        # serve: loader-attached gathers through the prefetch overlap
        loader = engine.make_loader(stream, num_batches=5,
                                    feature_store=store)
        outs = []
        try:
            for mb in loader:
                assert mb.feats is not None
                outs.append(np.asarray(
                    engine.forward_minibatch(params, mb, store)))
        finally:
            loader.close()
        logits_by[fs] = np.concatenate(outs)

        # train: a few compiled SGD steps through the same store
        ex = engine.train_executor(AdamW(learning_rate=1e-2))
        state = ex.opt.init(engine.init_params(jax.random.key(1)))
        labels = np.arange(graph.num_nodes) % 4
        tl = engine.make_loader(stream, num_batches=4, feature_store=store)
        ls = []
        try:
            for mb in tl:
                state, metrics = ex.grad_and_update(
                    state, mb, jnp.asarray(mb.seq.slice_labels(labels)),
                    gather_input(store, mb))
                ls.append(float(metrics["loss"]))
        finally:
            tl.close()
        losses_by[fs] = ls

    np.testing.assert_array_equal(logits_by["device"], logits_by["host"])
    np.testing.assert_array_equal(logits_by["device"], logits_by["cached"])
    assert losses_by["device"] == losses_by["host"] == losses_by["cached"]


def test_engine_make_feature_store_measured_split(graph, feats):
    engine = _engine(graph, "cached", budget=20)
    stream = SeedStream(graph.num_nodes, 8, seed=2, zipf_alpha=1.0)
    store = engine.make_feature_store(feats, seed_source=stream)
    assert isinstance(store, CachedFeatureStore)
    assert store.capacity == 20
    # no seed source -> population-proportional fallback, same capacity
    fallback = engine.make_feature_store(feats)
    assert fallback.capacity == 20
