"""Hector-generated code vs vanilla baselines: numerics + gradients for all
(reorder x compact x backend) combos, plus end-to-end RGNN training."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.graph import synthetic_heterograph
from repro.core.module import HectorModule
from repro.models import baselines, hgt_program, rgat_program, rgcn_program

MODELS = [
    ("rgcn", rgcn_program, baselines.rgcn_vanilla),
    ("rgat", rgat_program, baselines.rgat_vanilla),
    ("hgt", hgt_program, baselines.hgt_vanilla),
]


@pytest.fixture(scope="module")
def graph():
    return synthetic_heterograph(num_nodes=120, num_edges=900, num_ntypes=4,
                                 num_etypes=7, seed=0)


@pytest.fixture(scope="module")
def feats(graph):
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.normal(size=(graph.num_nodes, 16)), jnp.float32)


@pytest.mark.parametrize("name,prog_fn,vanilla", MODELS)
@pytest.mark.parametrize("reorder", [False, True])
@pytest.mark.parametrize("compact", [False, True])
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_hector_matches_vanilla(graph, feats, name, prog_fn, vanilla,
                                reorder, compact, backend):
    prog = prog_fn(16, 24)
    mod = HectorModule(prog, graph, reorder=reorder, compact=compact,
                       backend=backend, tile=8, node_block=8)
    params = mod.init(jax.random.key(0))
    out = mod.apply(params, {"feature": feats})["h_out"]
    van = vanilla(params, graph.to_tensors(), {"feature": feats})["h_out"]
    assert out.shape == (graph.num_nodes, 24)
    assert not bool(jnp.any(jnp.isnan(out)))
    np.testing.assert_allclose(out, van, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name,prog_fn,vanilla", MODELS)
def test_hector_gradients_match(graph, feats, name, prog_fn, vanilla):
    prog = prog_fn(16, 24)
    mod = HectorModule(prog, graph, reorder=True, compact=True,
                       backend="pallas_interpret", tile=8, node_block=8)
    params = mod.init(jax.random.key(0))
    g = jax.grad(lambda p: jnp.sum(mod.apply(p, {"feature": feats})["h_out"] ** 2))(params)
    gv = jax.grad(lambda p: jnp.sum(
        vanilla(p, graph.to_tensors(), {"feature": feats})["h_out"] ** 2))(params)
    for k in g:
        denom = float(jnp.max(jnp.abs(gv[k]))) + 1e-9
        np.testing.assert_allclose(np.asarray(g[k]) / denom,
                                   np.asarray(gv[k]) / denom,
                                   rtol=0, atol=5e-4, err_msg=k)


def test_rgnn_training_reduces_loss(graph, feats):
    """End-to-end: train an RGAT layer against a fixed random target."""
    prog = rgat_program(16, 8)
    mod = HectorModule(prog, graph, backend="xla", tile=8, node_block=8)
    params = mod.init(jax.random.key(0))
    rng = np.random.default_rng(2)
    target = jnp.asarray(rng.normal(size=(graph.num_nodes, 8)), jnp.float32)

    def loss_fn(p):
        out = mod.apply(p, {"feature": feats})["h_out"]
        return jnp.mean((out - target) ** 2)

    loss0 = float(loss_fn(params))
    lr = 1e-1
    grad_fn = jax.jit(jax.grad(loss_fn))
    losses = [loss0]
    for _ in range(60):
        g = grad_fn(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        losses.append(float(loss_fn(params)))
    # random-target MSE has a high irreducible floor; require a steady,
    # monotone-ish descent rather than a large absolute drop
    assert losses[-1] < 0.92 * loss0, (loss0, losses[-1])
    assert losses[-1] < losses[len(losses) // 2] < losses[0]


def test_compaction_reduces_gemm_rows(graph):
    """Compact materialization computes over unique rows (< edges)."""
    from repro.core.ir.passes import lower_program
    from repro.core.ir import intra_op as O
    plan = lower_program(hgt_program(16, 16), compact=True)
    gemm = [op for op in plan.ops if isinstance(op, O.GemmSpec)
            and op.gather == O.GatherScheme.BY_UNIQUE_SRC]
    assert gemm and graph.num_unique < graph.num_edges
