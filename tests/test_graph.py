"""HeteroGraph preprocessing invariants (unit + hypothesis property)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import HeteroGraph, synthetic_heterograph
from repro.kernels import layout as L


def make_graph(n_nodes=50, n_edges=300, n_nt=3, n_et=6, seed=0):
    return synthetic_heterograph(n_nodes, n_edges, n_nt, n_et, seed=seed)


def test_etype_sorted_and_ptr():
    hg = make_graph()
    assert np.all(np.diff(hg.etype) >= 0)
    for r in range(hg.num_etypes):
        seg = hg.etype[hg.etype_ptr[r]:hg.etype_ptr[r + 1]]
        assert np.all(seg == r)


def test_dst_csr_consistent():
    hg = make_graph()
    dst_sorted = hg.dst[hg.perm_dst]
    assert np.all(np.diff(dst_sorted) >= 0)
    assert np.array_equal(dst_sorted, hg.dst_sorted)
    deg = np.diff(hg.dst_ptr)
    assert deg.sum() == hg.num_edges
    assert np.array_equal(np.bincount(hg.dst, minlength=hg.num_nodes), deg)


def test_compaction_map_roundtrip():
    hg = make_graph()
    # every edge's (src, etype) equals its unique row's (src, etype)
    assert np.array_equal(hg.unique_src[hg.edge_to_unique], hg.src)
    assert np.array_equal(hg.unique_etype[hg.edge_to_unique], hg.etype)
    # unique table is etype-sorted and deduplicated
    assert np.all(np.diff(hg.unique_etype) >= 0)
    key = hg.unique_etype.astype(np.int64) * hg.num_nodes + hg.unique_src
    assert len(np.unique(key)) == len(key)
    assert 0 < hg.entity_compaction_ratio <= 1.0


@settings(max_examples=25, deadline=None)
@given(
    n_nodes=st.integers(2, 40),
    n_edges=st.integers(1, 200),
    n_et=st.integers(1, 12),
    seed=st.integers(0, 5),
)
def test_property_graph_invariants(n_nodes, n_edges, n_et, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    et = rng.integers(0, n_et, n_edges)
    hg = HeteroGraph.from_edges(src, dst, et, num_nodes=n_nodes,
                                num_etypes=n_et)
    assert hg.num_edges == n_edges
    assert hg.etype_ptr[-1] == n_edges
    assert hg.num_unique <= n_edges
    assert np.array_equal(hg.unique_src[hg.edge_to_unique], hg.src)
    # dst CSR covers all edges exactly once
    assert sorted(hg.perm_dst.tolist()) == list(range(n_edges))


@settings(max_examples=25, deadline=None)
@given(
    sizes=st.lists(st.integers(0, 37), min_size=1, max_size=9),
    tile=st.sampled_from([4, 8, 16]),
)
def test_property_padded_segments(sizes, tile):
    sizes = np.array(sizes)
    ptr = np.zeros(len(sizes) + 1, np.int64)
    np.cumsum(sizes, out=ptr[1:])
    ps = L.pad_segments(ptr, tile)
    assert ps.padded_rows % tile == 0
    # row_map covers all original rows exactly once
    valid = ps.row_map[ps.row_map >= 0]
    assert sorted(valid.tolist()) == list(range(int(sizes.sum())))
    # inv_map inverts row_map
    for orig, pos in enumerate(ps.inv_map):
        assert ps.row_map[pos] == orig
    # every tile belongs to exactly one group; group ordering non-decreasing
    assert np.all(np.diff(ps.tile_to_group) >= 0)


@settings(max_examples=20, deadline=None)
@given(
    degs=st.lists(st.integers(0, 9), min_size=1, max_size=40),
    tile=st.sampled_from([4, 8]),
    nb=st.sampled_from([4, 8]),
)
def test_property_blocked_csr(degs, tile, nb):
    degs = np.array(degs)
    ptr = np.zeros(len(degs) + 1, np.int64)
    np.cumsum(degs, out=ptr[1:])
    bc = L.block_csr(ptr, edge_tile=tile, node_block=nb)
    assert bc.padded_edges % tile == 0
    valid = bc.edge_map[bc.edge_map >= 0]
    assert sorted(valid.tolist()) == list(range(int(degs.sum())))
    # no edge tile spans two node blocks
    t2b = bc.tile_to_block
    assert np.all(np.diff(t2b) >= 0)
    for t in range(bc.num_tiles):
        ld = bc.local_dst[t * tile:(t + 1) * tile]
        em = bc.edge_map[t * tile:(t + 1) * tile]
        mask = em >= 0
        if mask.any():
            # all valid edges in a tile map into block t2b[t]
            assert np.all(ld[mask] < nb)
