"""Unified observability layer: metrics registry correctness (counters,
streaming histograms, percentile edge cases, scope merging), span tracer
nesting + Chrome-trace schema, the per-op plan profiler's telescoping-sum
invariant, and the disabled-mode zero-recording / zero-retrace contract."""
import json
import math
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import hector
from repro import obs
from repro.core.graph import synthetic_heterograph
from repro.obs import schema
from repro.obs.registry import (MetricsRegistry, NULL_REGISTRY,
                                snapshot_counter_total, snapshot_histogram,
                                snapshot_value)
from repro.obs.tracing import NULL_SPAN, SpanTracer
from repro.optim import AdamW
from repro.sampling import build_minibatch


# ---------------------------------------------------------------------------
# registry: counters / gauges / labels
# ---------------------------------------------------------------------------
def test_counter_identity_by_name_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("hits", cache="block")
    b = reg.counter("hits", cache="block")
    c = reg.counter("hits", cache="layout")
    assert a is b and a is not c
    a.inc()
    b.inc(4)
    assert reg.value("hits", cache="block") == 5
    assert reg.value("hits", cache="layout") == 0
    assert reg.value("hits", cache="nope") is None
    assert reg.counter_total("hits") == 5


def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    reg.gauge("depth").set(3)
    reg.gauge("depth").set(7)
    assert reg.value("depth") == 7.0


# ---------------------------------------------------------------------------
# registry: histogram percentiles, edge cases, reservoir
# ---------------------------------------------------------------------------
def test_histogram_empty_and_single_sample():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    s = h.summary()
    assert s["count"] == 0
    assert math.isnan(s["p50"]) and math.isnan(s["min"])
    h.observe(4.5)
    s = h.summary()
    assert s["count"] == 1
    # a single sample IS every percentile
    assert s["p50"] == s["p99"] == s["min"] == s["max"] == 4.5


def test_histogram_linear_interpolation_matches_numpy():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    vals = [5.0, 1.0, 9.0, 3.0, 7.0]
    for v in vals:
        h.observe(v)
    for q in (50, 90, 95, 99):
        assert h.percentile(q) == pytest.approx(np.percentile(vals, q))
    s = h.summary()
    assert s["mean"] == pytest.approx(5.0)
    assert s["min"] == 1.0 and s["max"] == 9.0 and s["sum"] == 25.0


def test_histogram_reservoir_exact_aggregates_and_determinism():
    def fill():
        reg = MetricsRegistry()
        h = reg.histogram("lat", max_samples=128)
        for i in range(5000):
            h.observe(float(i))
        return h

    a, b = fill(), fill()
    # count/sum/min/max stay exact past the reservoir bound
    assert a.count == 5000 and a.min == 0.0 and a.max == 4999.0
    assert a.total == pytest.approx(sum(range(5000)))
    # the LCG reservoir is deterministic: identical streams -> identical
    # samples -> identical percentiles
    assert a.summary() == b.summary()
    # and the sampled p50 is in the right neighborhood
    assert 1500 < a.percentile(50) < 3500


def test_histogram_absorb_merges_distributions():
    a, b = MetricsRegistry(), MetricsRegistry()
    for v in (1.0, 2.0):
        a.histogram("lat").observe(v)
    for v in (3.0, 4.0):
        b.histogram("lat").observe(v)
    a.absorb(b)
    s = a.histogram_summary("lat")
    assert s["count"] == 4 and s["min"] == 1.0 and s["max"] == 4.0
    assert s["sum"] == 10.0


def test_snapshot_readers_round_trip():
    reg = MetricsRegistry()
    reg.counter("traces", executor="BlockExecutor").inc(3)
    reg.counter("traces", executor="StackExecutor").inc(2)
    reg.gauge("tile").set(16)
    reg.histogram("lat").observe(2.0)
    snap = json.loads(json.dumps(reg.snapshot()))  # through-JSON fidelity
    assert snap["schema_version"] == obs.SCHEMA_VERSION
    assert snapshot_value(snap, "traces", executor="BlockExecutor") == 3
    assert snapshot_counter_total(snap, "traces") == 5
    assert snapshot_value(snap, "tile") == 16.0
    assert snapshot_histogram(snap, "lat")["count"] == 1
    assert snapshot_value(snap, "absent") is None
    assert schema.validate_metrics(snap) == []


# ---------------------------------------------------------------------------
# scopes: activation, nesting, absorb-on-exit, disabled mode
# ---------------------------------------------------------------------------
def test_metrics_null_outside_scope_and_live_inside():
    assert obs.metrics() is NULL_REGISTRY
    assert obs.span("x") is NULL_SPAN
    assert not obs.enabled()
    with obs.scope(metrics=True) as sc:
        assert obs.metrics() is sc.registry
        obs.metrics().counter("c").inc()
        assert sc.registry.value("c") == 1
    assert obs.metrics() is NULL_REGISTRY
    # nothing leaked into the null registry
    assert NULL_REGISTRY.counter("c").value == 0


def test_nested_scope_folds_into_parent():
    with obs.scope(metrics=True, tracing=True) as outer:
        obs.metrics().counter("c").inc()
        with obs.scope(metrics=True, tracing=True) as inner:
            obs.metrics().counter("c").inc(10)
            with obs.span("phase"):
                pass
            assert inner.registry.value("c") == 10
        # child absorbed: counters add, spans land on the parent tracer
        assert outer.registry.value("c") == 11
        assert len(outer.tracer.events("phase")) == 1


def test_disabled_forces_null_even_inside_scope():
    with obs.scope(metrics=True, tracing=True):
        with obs.disabled():
            assert obs.metrics() is NULL_REGISTRY
            assert obs.span("x") is NULL_SPAN
            assert not obs.enabled()
        assert obs.metrics() is not NULL_REGISTRY


# ---------------------------------------------------------------------------
# tracer: nesting, threads, Chrome-trace schema
# ---------------------------------------------------------------------------
def test_span_nesting_depth_and_containment():
    tr = SpanTracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    outer, = tr.events("outer")
    inner, = tr.events("inner")
    assert outer["depth"] == 0 and inner["depth"] == 1
    # the inner interval nests inside the outer one
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3


def test_chrome_trace_schema_and_thread_tracks():
    tr = SpanTracer()
    with tr.span("execute", step=0):
        pass

    def worker():
        with tr.span("sample"):
            pass
    t = threading.Thread(target=worker, name="prefetch")
    t.start()
    t.join()

    doc = json.loads(json.dumps(tr.chrome_trace()))
    assert schema.validate_trace(doc) == []
    assert schema.require_phases(doc, ["execute", "sample"]) == []
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M" and e["name"] == "thread_name"]
    spans = [e for e in evs if e["ph"] == "X"]
    # two threads -> two named tracks, spans on distinct tids
    assert {m["args"]["name"] for m in meta} >= {"prefetch"}
    tids = {e["tid"] for e in spans}
    assert len(tids) == 2
    for e in spans:
        assert e["pid"] == 0 and e["dur"] >= 0 and e["cat"] == "phase"
    # a missing phase is reported, not silently passed
    assert schema.require_phases(doc, ["backward"]) != []


def test_tracer_absorb_rebases_and_merges_tracks():
    parent, child = SpanTracer(), SpanTracer()
    with parent.span("a"):
        pass
    with child.span("b"):
        pass
    parent.absorb(child)
    assert parent.num_events == 2
    names = {e["name"] for e in parent.events()}
    assert names == {"a", "b"}
    # both main-thread spans share one re-mapped track
    assert len({e["tid"] for e in parent.events()}) == 1


def test_tracer_bounded_drops_not_grows():
    tr = SpanTracer(max_events=2)
    for _ in range(5):
        with tr.span("x"):
            pass
    assert tr.num_events == 2 and tr.dropped == 3


# ---------------------------------------------------------------------------
# profiler: telescoping-sum invariant on a real compiled model
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def profiled():
    graph = synthetic_heterograph(num_nodes=120, num_edges=900,
                                  num_ntypes=4, num_etypes=7, seed=0)
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.normal(size=(graph.num_nodes, 8)), jnp.float32)
    eng = hector.compile("rgat", graph, layers=2, dim=8, hidden=8,
                         classes=4, sample=[3, 3], tile=8, node_block=8,
                         log=None)
    params = eng.init(0)
    seq = eng.sampler.sample(np.arange(8, dtype=np.int32), batch_index=0,
                             epoch=0)
    mb = build_minibatch(seq, step=0, tile=8, node_block=8, bucket=True)
    return eng, params, mb, feats


def test_profile_minibatch_structure_and_coverage(profiled):
    eng, params, mb, feats = profiled
    p = eng.profile(params, mb, feats, warmup=1, iters=3)
    n_plan_ops = sum(len(pl.ops) for pl in eng.plans)
    # every op instance appears, plus one glue row per hop
    assert len(p.ops) == n_plan_ops + len(eng.plans)
    assert {o.hop for o in p.ops} == {0, 1}
    assert {o.category for o in p.ops} <= {"gemm", "traversal", "wprod",
                                           "glue"}
    assert all(o.seconds >= 0 for o in p.ops)
    assert p.total_seconds > 0
    # prefix differences telescope: the attributed sum must land near the
    # whole-plan time (generous band: CI boxes are noisy, and the invariant
    # being tested is structural consistency, not machine quietness)
    assert 0.5 < p.coverage < 1.6, p.table()
    # category rollup and JSON export agree with the rows
    assert sum(p.by_category().values()) == pytest.approx(p.sum_op_seconds)
    doc = json.loads(json.dumps(p.to_json()))
    assert doc["total_us"] > 0 and len(doc["ops"]) == len(p.ops)
    assert p.table().count("\n") >= len(p.ops)


def test_profile_train_step_phases(profiled):
    from repro.obs.profile import profile_train_step
    eng, params, mb, feats = profiled
    opt = AdamW(learning_rate=1e-3)
    state = opt.init(params)
    labels = np.zeros(8, dtype=np.int32)
    ph = profile_train_step(
        eng.plans, opt, state, mb, labels,
        {"feature": jnp.asarray(feats)[mb.input_ids]},
        backend=eng.cfg.backend, activation=eng.cfg.activation,
        decisions=eng.decisions, warmup=1, iters=3)
    assert set(ph) == {"forward", "backward", "optimizer", "total"}
    assert ph["forward"] > 0 and ph["total"] > 0
    assert all(v >= 0 for v in ph.values())
    # the fused step can't be faster than its forward pass
    assert ph["total"] >= ph["forward"] * 0.5


def test_isotonic_fit_is_monotone_and_mass_preserving():
    from repro.obs.profile import _isotonic
    xs = [1.0, 3.0, 2.0, 2.0, 5.0, 4.0]
    fit = _isotonic(xs)
    assert all(b >= a for a, b in zip(fit, fit[1:]))
    assert sum(fit) == pytest.approx(sum(xs))
    # already-monotone input passes through untouched
    assert _isotonic([1.0, 2.0, 3.0]) == [1.0, 2.0, 3.0]


# ---------------------------------------------------------------------------
# disabled mode: zero recording, no trace-behavior side effects
# ---------------------------------------------------------------------------
def test_serve_disabled_records_nothing_and_keeps_zero_retraces():
    from repro.launch.serve_rgnn import serve
    kwargs = dict(model="rgat", dataset="aifb", scale=0.05, layers=2,
                  dim=8, hidden=8, classes=4, fanouts=[3, 3], batch_size=8,
                  num_batches=6, tile=8, node_block=8, repeat_after=2,
                  cache_blocks=8, cache_layouts=32,
                  log=lambda *a, **k: None)
    off = serve(obs_mode="off", **kwargs)
    # no registry snapshot, nothing recorded anywhere
    assert "metrics" not in off
    assert off["retraces_after_warmup"] == 0
    assert NULL_REGISTRY.counter("executor_traces").value == 0

    on = serve(obs_mode="on", **kwargs)
    assert "metrics" in on
    # enabling observability must not change compile/trace behavior
    assert on["retraces_after_warmup"] == 0
    assert on["executor_traces"] == off["executor_traces"]
    assert snapshot_counter_total(on["metrics"], "executor_traces") \
        == on["executor_traces"]
    # registry-sourced latency percentiles are present and sane
    hs = snapshot_histogram(on["metrics"], "serve_batch_ms")
    assert hs["count"] == on["batches"]
    assert hs["p50"] <= hs["p99"]
