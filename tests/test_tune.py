"""Autotuned operator variants (ISSUE 4): every point of the tuning space
must match the ``kernels/ref.py`` oracles (outputs AND gradients); the
persistent cache must replay decisions with zero measurements; per-var
materialization, the device-derived VMEM budget, and the decision-table
fingerprint in the executor compile cache all get pinned here."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.graph import synthetic_heterograph
from repro.core.ir import passes
from repro.core.ir import inter_op as I
from repro.core.module import HectorModule
from repro.kernels import layout as L, ops, ref as R
from repro.models import rgat_program
from repro.tune import cost, space
from repro.tune.cache import TuneCache
from repro.tune.decisions import TuningDecisions
from repro.tune.device import BUDGET_ENV, fused_gather_budget_bytes
from repro.tune.tuner import Tuner, _KeyRecorder

BACKENDS = ["xla", "pallas_interpret"]


# ---------------------------------------------------------------------------
# op-level: the full variant space vs the ref oracles
# ---------------------------------------------------------------------------
def _segments(rng, n_groups, max_size):
    sizes = rng.integers(1, max_size, n_groups)
    ptr = np.zeros(n_groups + 1, np.int64)
    np.cumsum(sizes, out=ptr[1:])
    seg_ids = np.repeat(np.arange(n_groups), sizes)
    return ptr, seg_ids, int(sizes.sum())


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("tile_rows", [None, 8])     # None = layout tile (16)
@pytest.mark.parametrize("tile_n", [128, 8])
def test_segment_mm_variant_space(rng, backend, tile_rows, tile_n):
    """Row sub-tiling x column tiling x backend == ref, values and grads."""
    ptr, seg_ids, m = _segments(rng, 4, 19)
    x = jnp.asarray(rng.normal(size=(m, 12)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 12, 24)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, 16))

    def f(x, w, s):
        return jnp.sum(jnp.sin(ops.segment_mm(
            x, w, lay, row_scale=s, backend=backend, tile_n=tile_n,
            tile_rows=tile_rows)))

    def f_ref(x, w, s):
        return jnp.sum(jnp.sin(R.segment_mm_ref(x, w, jnp.asarray(seg_ids),
                                                s)))

    y = ops.segment_mm(x, w, lay, row_scale=s, backend=backend,
                       tile_n=tile_n, tile_rows=tile_rows)
    np.testing.assert_allclose(y, R.segment_mm_ref(x, w, jnp.asarray(seg_ids),
                                                   s), rtol=1e-4, atol=1e-4)
    g = jax.grad(f, argnums=(0, 1, 2))(x, w, s)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, s)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("tile_rows", [None, 8])
@pytest.mark.parametrize("tile_n", [128, 8])
def test_segment_mm_gather_variant_space(rng, backend, tile_rows, tile_n):
    """The in-kernel-gather GEMM across the tile space == ref."""
    ptr, seg_ids, m = _segments(rng, 4, 17)
    n_src = 11
    gidx = rng.integers(0, n_src, m)
    feats = jnp.asarray(rng.normal(size=(n_src, 12)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 12, 24)), jnp.float32)
    ps = L.pad_segments(ptr, 16)
    lay = ops.padded_segments_dev(ps)
    gmap = jnp.asarray(L.compose_gather_rows(ps, gidx))

    def f(feats, w):
        return jnp.sum(jnp.sin(ops.segment_mm_gather(
            feats, w, lay, gmap, backend=backend, tile_n=tile_n,
            tile_rows=tile_rows)))

    def f_ref(feats, w):
        return jnp.sum(jnp.sin(R.gather_mm_ref(
            feats, w, jnp.asarray(gidx), jnp.asarray(seg_ids))))

    y = ops.segment_mm_gather(feats, w, lay, gmap, backend=backend,
                              tile_n=tile_n, tile_rows=tile_rows)
    np.testing.assert_allclose(
        y, R.gather_mm_ref(feats, w, jnp.asarray(gidx),
                           jnp.asarray(seg_ids)), rtol=1e-4, atol=1e-4)
    g = jax.grad(f, argnums=(0, 1))(feats, w)
    g_ref = jax.grad(f_ref, argnums=(0, 1))(feats, w)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# plan-level: forced decisions over the whole space == the default lowering
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def graph():
    return synthetic_heterograph(num_nodes=96, num_edges=700, num_ntypes=3,
                                 num_etypes=5, seed=0,
                                 target_compaction=0.5)


@pytest.fixture(scope="module")
def feats(graph):
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.normal(size=(graph.num_nodes, 16)), jnp.float32)


def _recorded_keys(mod, params, feats):
    rec = _KeyRecorder()
    from repro.core import codegen
    jax.eval_shape(lambda p, f: codegen.execute_plan(
        mod.plan, p, mod.gt, f, mod.layouts, mod.backend, rec),
        params, {"feature": feats})
    return rec.keys


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("compact_vars", [frozenset(), None])  # none/all
@pytest.mark.parametrize("variant_kw", [
    {},                                            # defaults
    {"tile_rows": 8},
    {"fuse_gather": True},
    {"fuse_gather": False},
    {"tile_rows": 8, "tile_n": 8, "fuse_gather": True},
])
def test_plan_decisions_match_reference(graph, feats, backend, compact_vars,
                                        variant_kw):
    """Force one variant onto EVERY op of an RGAT plan (each materialization
    choice) and check outputs + gradients against the default xla lowering
    (itself pinned to the vanilla baselines in test_models_rgnn)."""
    prog = rgat_program(16, 24)
    ref_mod = HectorModule(prog, graph, backend="xla", tile=16, node_block=16)
    params = ref_mod.init(jax.random.key(0))
    want = ref_mod.apply(params, {"feature": feats})["h_out"]
    g_ref = jax.grad(lambda p: jnp.sum(
        ref_mod.apply(p, {"feature": feats})["h_out"] ** 2))(params)

    mod = HectorModule(prog, graph, backend=backend, tile=16, node_block=16,
                       compact_vars=compact_vars, jit=False)
    decisions = TuningDecisions()
    for key in _recorded_keys(mod, params, feats):
        if key.startswith("gemm"):
            decisions.set_op(key, space.GemmVariant(**variant_kw))
        else:
            decisions.set_op(key, space.TravVariant(
                fuse_gather=variant_kw.get("fuse_gather")))
    mod.decisions = decisions

    got = mod.apply(params, {"feature": feats})["h_out"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    g = jax.grad(lambda p: jnp.sum(
        mod.apply(p, {"feature": feats})["h_out"] ** 2))(params)
    for k in g_ref:
        denom = float(jnp.max(jnp.abs(g_ref[k]))) + 1e-9
        np.testing.assert_allclose(np.asarray(g[k]) / denom,
                                   np.asarray(g_ref[k]) / denom,
                                   rtol=5e-4, atol=5e-4)


def test_op_backend_override_dispatches(graph, feats):
    """A per-op backend decision actually changes the executed kernel: an
    'xla'-planned module with every op forced to 'pallas_interpret' still
    matches, and vice versa."""
    prog = rgat_program(16, 24)
    mod = HectorModule(prog, graph, backend="xla", tile=16, node_block=16,
                       jit=False)
    params = mod.init(jax.random.key(0))
    want = mod.apply(params, {"feature": feats})["h_out"]
    decisions = TuningDecisions()
    for key in _recorded_keys(mod, params, feats):
        if key.startswith("gemm"):
            decisions.set_op(key, space.GemmVariant(
                backend="pallas_interpret"))
        else:
            decisions.set_op(key, space.TravVariant(
                backend="pallas_interpret"))
    mod.decisions = decisions
    got = mod.apply(params, {"feature": feats})["h_out"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# per-var materialization
# ---------------------------------------------------------------------------
def test_lower_program_per_var_materialization():
    prog = rgat_program(16, 24)
    cands = passes.compactable_edge_vars(prog)
    assert cands, "rgat must expose at least one compactable edge var"
    # subset: only the first var compact
    plan = passes.lower_program(prog, compact_vars=frozenset(cands[:1]))
    compact = {v for v, l in plan.layouts.items() if l == I.Layout.COMPACT}
    assert compact <= set(cands[:1])
    # empty set == vanilla everywhere, even with compact=True default
    plan_v = passes.lower_program(prog, compact=True,
                                  compact_vars=frozenset())
    assert not any(l == I.Layout.COMPACT for l in plan_v.layouts.values())
    # None keeps the static all-eligible policy
    plan_c = passes.lower_program(prog, compact=True, compact_vars=None)
    assert any(l == I.Layout.COMPACT for l in plan_c.layouts.values())


# ---------------------------------------------------------------------------
# VMEM budget (satellite: index bytes counted, device-derived budget)
# ---------------------------------------------------------------------------
def test_fits_vmem_counts_index_bytes(monkeypatch):
    from repro.core import codegen
    src = jnp.zeros((100, 10), jnp.float32)      # 4000 bytes
    gmap = jnp.zeros((300,), jnp.int32)          # 1200 bytes
    monkeypatch.setenv(BUDGET_ENV, "5000")
    assert codegen._fits_vmem(src)               # 4000 <= 5000
    assert not codegen._fits_vmem(src, gmap)     # 5200 > 5000: maps count
    monkeypatch.setenv(BUDGET_ENV, "6000")
    assert codegen._fits_vmem(src, gmap)
    assert codegen._fits_vmem(src, None)         # absent maps are free


def test_vmem_budget_is_device_derived(monkeypatch):
    monkeypatch.delenv(BUDGET_ENV, raising=False)
    monkeypatch.delenv("REPRO_VMEM_BYTES", raising=False)
    budget = fused_gather_budget_bytes()
    assert 0 < budget < 16 * 1024 * 1024         # a fraction of VMEM, not 0
    monkeypatch.setenv("REPRO_VMEM_BYTES", str(8 * 1024 * 1024))
    assert fused_gather_budget_bytes() == 2 * 1024 * 1024


# ---------------------------------------------------------------------------
# keys / cost model
# ---------------------------------------------------------------------------
def test_key_roundtrip_and_candidates(graph, feats):
    prog = rgat_program(16, 24)
    mod = HectorModule(prog, graph, backend="xla", tile=16, node_block=16,
                       jit=False)
    params = mod.init(jax.random.key(0))
    keys = _recorded_keys(mod, params, feats)
    assert any(k.startswith("gemm") for k in keys)
    assert any(k.startswith("trav") for k in keys)
    for key in keys:
        info = space.parse_key(key)
        assert info["kind"] in ("gemm", "trav")
        cands = space.candidates_for_key(key, "xla")
        assert cands[0] in (space.GEMM_DEFAULT, space.TRAV_DEFAULT)
        pruned = cost.prune(key, cands, "xla", k=3)
        assert pruned[0] == cands[0] and len(pruned) <= 3
        for v in pruned:
            assert cost.score(key, v, "xla") < 1e9


# ---------------------------------------------------------------------------
# persistent cache: cold run measures, warm run replays
# ---------------------------------------------------------------------------
def test_tuner_persistent_cache_zero_remeasure(graph, tmp_path):
    cache = str(tmp_path / "tune.json")
    progs = [rgat_program(16, 24)]
    t1 = Tuner(mode="full", cache_path=cache, iters=1, warmup=0)
    rep1 = t1.tune_stack(progs, graph, backend="xla", tile=16, node_block=16,
                         feat_dims=[16])
    assert t1.stats["measurements"] > 0
    assert os.path.exists(cache)

    t2 = Tuner(mode="full", cache_path=cache, iters=1, warmup=0)
    rep2 = t2.tune_stack(progs, graph, backend="xla", tile=16, node_block=16,
                         feat_dims=[16])
    assert t2.stats["measurements"] == 0
    assert t2.stats["cache_hits"] > 0
    assert rep2.decisions.fingerprint() == rep1.decisions.fingerprint()
    assert (rep2.tile, rep2.node_block) == (rep1.tile, rep1.node_block)
    assert rep2.compact_vars == rep1.compact_vars

    # cached mode replays without measuring too
    t3 = Tuner(mode="cached", cache_path=cache)
    rep3 = t3.tune_stack(progs, graph, backend="xla", tile=16, node_block=16,
                         feat_dims=[16])
    assert t3.stats["measurements"] == 0
    assert rep3.decisions.fingerprint() == rep1.decisions.fingerprint()


def test_decisions_fingerprint_keys_executor_cache(graph, feats):
    """Swapping the decision table recompiles instead of reusing the stale
    executable (the fingerprint is part of the compile-cache key)."""
    prog = rgat_program(16, 24)
    mod = HectorModule(prog, graph, backend="xla", tile=16, node_block=16)
    params = mod.init(jax.random.key(0))
    mod.apply(params, {"feature": feats})
    assert mod.executor.num_compiled == 1
    d = TuningDecisions()
    for key in _recorded_keys(mod, params, feats):
        if key.startswith("gemm"):
            d.set_op(key, space.GemmVariant(tile_rows=8))
    mod.executor.set_decisions(d)
    mod.apply(params, {"feature": feats})
    assert mod.executor.num_compiled == 2      # new entry, not a stale hit
    mod.apply(params, {"feature": feats})
    assert mod.executor.num_compiled == 2      # stable under the new table


def test_tune_cache_schema_and_atomicity(tmp_path):
    path = str(tmp_path / "c.json")
    c = TuneCache(path)
    c.put("k1", {"kind": "gemm", "backend": "default", "tile_rows": 8,
                 "tile_n": None, "fuse_gather": None})
    c.save()
    c2 = TuneCache(path)
    assert space.variant_from_json(c2.get("k1")) == \
        space.GemmVariant(tile_rows=8)
    # incompatible schema versions are ignored, not misread
    with open(path, "w") as f:
        f.write('{"version": 999, "entries": {"k1": 1}}')
    assert TuneCache(path).get("k1") is None
    # corrupt files are ignored
    with open(path, "w") as f:
        f.write("not json")
    assert TuneCache(path).get("k1") is None


def test_tune_cache_invalidated_by_kernel_code_change(tmp_path):
    """Decisions measured against different kernel/codegen sources must not
    replay (warm caches never re-measure, so staleness would be forever)."""
    import json
    from repro.tune.cache import code_fingerprint
    path = str(tmp_path / "c.json")
    c = TuneCache(path)
    c.put("k1", {"kind": "trav", "backend": "default", "fuse_gather": False})
    c.save()
    with open(path) as f:
        payload = json.load(f)
    assert payload["code"] == code_fingerprint()
    payload["code"] = "0" * 12              # cache from "other" kernel code
    with open(path, "w") as f:
        json.dump(payload, f)
    assert TuneCache(path).get("k1") is None
