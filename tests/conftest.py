import importlib.util
import pathlib
import sys

import numpy as np
import pytest

import jax

# NOTE: no XLA_FLAGS here — smoke tests and benches must see ONE device.
# Multi-device tests spawn subprocesses (test_distributed.py).

jax.config.update("jax_enable_x64", False)

# Optional-dep shim: the property tests use `hypothesis`, but the tier-1
# suite must collect (and the properties still run, deterministically)
# without it. Install tests/_hypothesis_stub.py under the real name only
# when the library is absent.
if importlib.util.find_spec("hypothesis") is None:
    _stub_path = pathlib.Path(__file__).with_name("_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
