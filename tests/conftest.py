import numpy as np
import pytest

import jax

# NOTE: no XLA_FLAGS here — smoke tests and benches must see ONE device.
# Multi-device tests spawn subprocesses (test_distributed.py).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
