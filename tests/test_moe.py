"""MoE dispatch properties: equivalence to the dense-gather reference at
high capacity, drop accounting, FLOP scaling (the E/k saving vs dense)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.nn.moe import capacity, init_moe, moe_ffn

RNG = np.random.default_rng(0)


def dense_reference(params, x, e, k):
    """Per-token top-k expert mix computed densely (oracle)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / gate.sum(-1, keepdims=True)
    # all experts on all tokens (the inefficient formulation)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, params["w_gate"]))
    h = h * jnp.einsum("td,edf->tef", xf, params["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])
    y = jnp.take_along_axis(y_all, idx[..., None], axis=1)      # [t,k,d]
    return (y * gate[..., None]).sum(1).reshape(b, s, d)


@pytest.mark.parametrize("e,k", [(4, 2), (8, 2), (8, 4)])
def test_matches_dense_reference_when_no_drops(e, k):
    d, f = 16, 32
    params = init_moe(jax.random.key(0), d, f, e, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 24, d)), jnp.float32)
    out, aux = moe_ffn(params, x, e, k, capacity_factor=float(e))  # no drops
    ref = dense_reference(params, x, e, k)
    assert float(aux["dropped"]) == 0.0
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_capacity_drops_bounded():
    d, f, e, k = 8, 16, 4, 2
    params = init_moe(jax.random.key(1), d, f, e, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 32, d)), jnp.float32)
    out, aux = moe_ffn(params, x, e, k, capacity_factor=0.5)
    assert 0.0 <= float(aux["dropped"]) < 1.0
    assert bool(jnp.all(jnp.isfinite(out)))


def test_load_balance_loss_range():
    d, f, e, k = 8, 16, 8, 2
    params = init_moe(jax.random.key(2), d, f, e, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(1, 64, d)), jnp.float32)
    _, aux = moe_ffn(params, x, e, k, capacity_factor=2.0)
    # Switch aux loss is >= k for top-k-normalized one-hot assignment
    assert 0.0 < float(aux["lb_loss"]) < 6 * e


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([8, 64, 1000]), e=st.sampled_from([4, 16, 64]),
       k=st.sampled_from([1, 2, 6]), cf=st.sampled_from([1.0, 1.25, 2.0]))
def test_property_capacity_flops_scaling(t, e, k, cf):
    """capacity-bucketed compute = O(T·k·cf), NOT O(T·E) — the compact-
    materialization-style saving (DESIGN.md §4)."""
    c = capacity(t, e, k, cf)
    routed_rows = e * c
    assert routed_rows >= t * k * cf * 0.99       # enough room
    dense_rows = t * e
    if e > k * cf * 2 and t >= 64:                # above the capacity floor
        assert routed_rows < dense_rows           # strictly cheaper than dense
    assert c % 8 == 0


def test_moe_gradients_flow_to_all_param_groups():
    d, f, e, k = 8, 16, 4, 2
    params = init_moe(jax.random.key(3), d, f, e, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(1, 16, d)), jnp.float32)

    def loss(p):
        out, aux = moe_ffn(p, x, e, k, capacity_factor=4.0)
        return jnp.sum(out ** 2) + 0.01 * aux["lb_loss"]

    g = jax.grad(loss)(params)
    for name, gv in g.items():
        assert float(jnp.max(jnp.abs(gv))) > 0, name
