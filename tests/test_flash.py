"""Flash-attention kernel vs pure-jnp oracle: shape/dtype/feature sweep."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention

RNG = np.random.default_rng(0)


def ref_attention(q, k, v, causal=True, window=None, softcap=None,
                  q_offset=0):
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask = k_pos[None] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def make(b, sq, sk, h, kv, hd, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(b, sq, h, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, sk, kv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, sk, kv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,sq,sk,h,kv,hd,qt,kt", [
    (1, 32, 32, 2, 2, 8, 8, 8),
    (2, 64, 64, 4, 2, 16, 16, 16),     # GQA g=2
    (1, 16, 64, 8, 2, 8, 16, 32),      # g=4, long K
    (2, 128, 128, 2, 1, 32, 128, 64),  # MQA
])
def test_flash_matches_ref_sweep(b, sq, sk, h, kv, hd, qt, kt):
    q, k, v = make(b, sq, sk, h, kv, hd)
    out = flash_attention(q, k, v, q_tile=qt, k_tile=kt, interpret=True,
                          q_offset=sk - sq)
    want = ref_attention(q, k, v, q_offset=sk - sq)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [8, 16])
def test_flash_sliding_window(window):
    q, k, v = make(1, 64, 64, 2, 2, 8)
    out = flash_attention(q, k, v, window=window, q_tile=16, k_tile=16,
                          interpret=True)
    want = ref_attention(q, k, v, window=window)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_flash_softcap():
    q, k, v = make(1, 32, 32, 2, 2, 8)
    out = flash_attention(q, k, v, softcap=5.0, q_tile=8, k_tile=8,
                          interpret=True)
    want = ref_attention(q, k, v, softcap=5.0)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = make(1, 32, 32, 4, 4, 16, jnp.bfloat16)
    out = flash_attention(q, k, v, q_tile=16, k_tile=16, interpret=True)
    want = ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_decode_single_query():
    """q_len=1 with offset = decode step semantics."""
    q, k, v = make(2, 1, 64, 4, 2, 8)
    out = flash_attention(q, k, v, q_offset=40, q_tile=1, k_tile=16,
                          interpret=True)
    want = ref_attention(q, k, v, q_offset=40)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)
