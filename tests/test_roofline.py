"""Roofline HLO analyzer: loop-multiplier correctness, collective tallies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (
    HW_V5E, analyze_hlo, parse_hlo, roofline_terms, _shape_bytes,
)


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]{1,0}") == 128
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[]") == 1


def _flops_of(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(txt)["flops"]


def test_scan_trip_count_multiplier():
    """HLO flops must scale with scan length (cost_analysis does NOT)."""
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def f(steps):
        def g(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y
        return g, jax.ShapeDtypeStruct((steps, 128, 128), jnp.float32)

    g4, w4 = f(4)
    g8, w8 = f(8)
    f4 = _flops_of(g4, x, w4)
    f8 = _flops_of(g8, x, w8)
    analytic4 = 4 * 2 * 64 * 128 * 128
    assert abs(f4 - analytic4) / analytic4 < 0.05, (f4, analytic4)
    assert abs(f8 - 2 * f4) / f8 < 0.05


def test_nested_scan_multipliers():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)

    def g(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    flops = _flops_of(g, x, w)
    analytic = 3 * 5 * 2 * 32 * 32 * 32
    assert abs(flops - analytic) / analytic < 0.05, (flops, analytic)


def test_dominant_term_selection():
    terms = roofline_terms(
        {}, {"flops": 1e12, "mem_bytes_proxy": 1e9,
             "collective_bytes": 1e12}, 256, HW_V5E)
    assert terms["dominant"] == "collective"
    assert terms["t_collective_s"] == pytest.approx(1e12 / 50e9)
    terms2 = roofline_terms(
        {}, {"flops": 1e15, "mem_bytes_proxy": 1e9, "collective_bytes": 0},
        256, HW_V5E)
    assert terms2["dominant"] == "compute"


def test_parse_synthetic_hlo_with_tuple_types():
    txt = """HloModule test, num_partitions=4

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,8]{1,0} all-gather(%d), channel_id=1, replica_groups=[1,4]<=[4], dimensions={1}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ag)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %x)
  %wh = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%wh), index=1
}
"""
    res = analyze_hlo(txt)
    assert res["flops"] == 7 * 2 * 8 * 8 * 8          # trip count 7
    assert res["coll_all-gather"] == 7 * 8 * 8 * 4    # per-iteration AG
