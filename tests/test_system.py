"""End-to-end behaviour tests: the train and serve drivers run on CPU with
checkpoint/restart and failure simulation (deliverable b wiring)."""
import numpy as np
import pytest


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main
    losses = main([
        "--arch", "qwen3-4b", "--reduced", "--steps", "10",
        "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "4",
    ])
    assert len(losses) == 10
    assert all(np.isfinite(l) for l in losses)


def test_train_driver_failure_recovery(tmp_path):
    from repro.launch.train import main
    losses = main([
        "--arch", "qwen3-4b", "--reduced", "--steps", "10",
        "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "3",
        "--simulate-failure", "6",
    ])
    assert all(np.isfinite(l) for l in losses)


def test_train_driver_resume(tmp_path):
    from repro.launch.train import main
    main(["--arch", "gemma2-2b", "--reduced", "--steps", "6",
          "--batch", "2", "--seq", "16",
          "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "3"])
    losses = main(["--arch", "gemma2-2b", "--reduced", "--steps", "9",
                   "--batch", "2", "--seq", "16",
                   "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "3",
                   "--resume"])
    assert len(losses) == 3     # resumed from step 6


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-780m"])
def test_serve_driver_generates(arch):
    from repro.launch.serve import main
    gen = main(["--arch", arch, "--reduced", "--batch", "2",
                "--prompt-len", "8", "--gen", "6"])
    assert gen.shape == (2, 6)
    assert gen.dtype == np.int32


def test_loc_report_paper_parity():
    """§4.1: the paper expressed the three models in 51 LoC; the DSL
    definitions must stay at paper-scale brevity (gate shared with
    benchmarks/loc_report.py --ci)."""
    from benchmarks.loc_report import MAX_MODEL_LOC, PAPER_MODELS
    from repro.models import DSL_MODELS
    per_model = {m: DSL_MODELS[m].definition_loc for m in PAPER_MODELS}
    assert sum(per_model.values()) <= MAX_MODEL_LOC, per_model
