"""Multi-device integration tests.

These spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
so the main pytest process keeps seeing ONE device (per the dry-run contract).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_train_step_runs_on_2x4_mesh():
    stdout = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro import configs as C
        from repro.lm.config import ShapeCell
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_step
        from repro.optim import AdamW
        cfg = C.get_reduced('qwen3-4b')
        cell = ShapeCell('t', 32, 8, 'train')
        mesh = make_mesh((2, 4), ('data', 'model'))
        bundle = build_step(cfg, cell, mesh, remat=False)
        model = bundle.model
        opt = AdamW(learning_rate=1e-3)
        state = opt.init(model.init(jax.random.key(0)))
        sh = bundle.partitioner.state_shardings(jax.eval_shape(lambda: state))
        state = jax.tree.map(jax.device_put, state, sh)
        rng = np.random.default_rng(0)
        losses = []
        for step in range(3):
            batch = {
              'tokens': jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
              'targets': jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
            }
            state, m = bundle.fn(state, batch)
            losses.append(float(m['loss']))
        print(json.dumps(losses))
        """)
    losses = json.loads(stdout.strip().splitlines()[-1])
    assert len(losses) == 3 and all(l == l and l < 20 for l in losses)


def test_sharded_equals_single_device():
    """The same train step on a (2,4) mesh and a (1,1) mesh must agree."""
    code_tpl = """
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs as C
        from repro.lm.config import ShapeCell
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_step
        from repro.optim import AdamW
        cfg = C.get_reduced('gemma2-2b')
        cell = ShapeCell('t', 16, 8, 'train')
        mesh = make_mesh({mesh_shape}, {axes})
        bundle = build_step(cfg, cell, mesh, remat=False)
        opt = AdamW(learning_rate=1e-3)
        state = opt.init(bundle.model.init(jax.random.key(0)))
        rng = np.random.default_rng(0)
        batch = {{
          'tokens': jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
          'targets': jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
        }}
        state, m = bundle.fn(state, batch)
        print(float(m['loss']))
        """
    l_multi = float(run_sub(code_tpl.format(mesh_shape="(2, 4)",
                                            axes="('data','model')"))
                    .strip().splitlines()[-1])
    l_single = float(run_sub(code_tpl.format(mesh_shape="(1, 1)",
                                             axes="('data','model')"),
                             devices=1).strip().splitlines()[-1])
    assert abs(l_multi - l_single) < 5e-2, (l_multi, l_single)


def test_multipod_mesh_axes_and_compile():
    """(pod, data, model) mesh: lower + compile a decode step (proves the
    'pod' axis shards; mini version of the 512-device dry-run)."""
    run_sub("""
        import jax
        from repro import configs as C
        from repro.lm.config import ShapeCell
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_step
        cfg = C.get_reduced('gemma2-2b')
        cell = ShapeCell('d', 64, 8, 'decode')
        mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        bundle = build_step(cfg, cell, mesh)
        compiled = bundle.lower().compile()
        assert compiled.memory_analysis() is not None
        print('ok')
        """)


def test_compressed_psum_across_pods():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.optim.compression import compressed_psum
        mesh = jax.make_mesh((4,), ('pod',))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
        f = shard_map(partial(compressed_psum, axis_name='pod'),
                      mesh=mesh, in_specs=P('pod'), out_specs=P('pod'))
        y = f(x)
        want = x.sum(0, keepdims=True).repeat(4, 0)
        err = float(jnp.max(jnp.abs(y - want)))
        scale = float(jnp.max(jnp.abs(want)))
        assert err < 0.05 * scale + 1e-3, (err, scale)
        print('ok')
        """)


def test_elastic_restart_subprocess(tmp_path):
    """Save on an 8-device mesh, restore onto a 4-device mesh (re-shard)."""
    run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs as C
        from repro.checkpoint import Checkpointer
        from repro.launch.mesh import make_mesh, plan_elastic_mesh
        from repro.launch.partitioning import Partitioner
        from repro.lm.model import TransformerLM
        cfg = C.get_reduced('qwen3-4b')
        model = TransformerLM(cfg, remat=False)
        params = model.init(jax.random.key(0))
        mesh8 = make_mesh((2, 4), ('data', 'model'))
        p8 = Partitioner(mesh8, cfg)
        sh8 = p8.param_shardings(jax.eval_shape(lambda: params))
        params8 = jax.tree.map(jax.device_put, params, sh8)
        ck = Checkpointer('{tmp_path}')
        ck.save(3, params8, blocking=True)
        # failure: only 4 devices survive -> new mesh (1,4)
        plan = plan_elastic_mesh(4, model_parallel=4)
        mesh4 = make_mesh(plan.shape, plan.axes,
                          devices=jax.devices()[:4])
        p4 = Partitioner(mesh4, cfg)
        sh4 = p4.param_shardings(jax.eval_shape(lambda: params))
        restored = ck.restore(params, shardings=sh4)
        a = jax.tree.leaves(params8)[0]
        b = jax.tree.leaves(restored)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('ok')
        """)


def test_rgnn_hector_shards_over_mesh():
    """The generated RGNN code compiles and runs with node features sharded
    over the data axis (the DistDGL-style serving posture of DESIGN.md)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.graph import synthetic_heterograph
        from repro.core.module import HectorModule
        from repro.models import rgat_program
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        hg = synthetic_heterograph(256, 2000, 3, 6, seed=0)
        mod = HectorModule(rgat_program(16, 16), hg, jit=False)
        params = mod.init(jax.random.key(0))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(hg.num_nodes, 16)), jnp.float32)
        fn = jax.jit(
            lambda p, f: mod.apply(p, {'feature': f})['h_out'],
            in_shardings=(None, NamedSharding(mesh, P('data', None))))
        out = fn(params, x)
        ref = mod.apply(params, {'feature': x})['h_out']
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print('ok')
        """)
