"""Per-kernel shape/dtype sweeps + gradient checks vs the ref.py oracles
(deliverable c: each Pallas kernel validated in interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import layout as L, ops, ref as R

BACKENDS = ["xla", "pallas_interpret"]


def _segments(rng, n_groups, max_size):
    sizes = rng.integers(0, max_size, n_groups)
    ptr = np.zeros(n_groups + 1, np.int64)
    np.cumsum(sizes, out=ptr[1:])
    seg_ids = np.repeat(np.arange(n_groups), sizes)
    return ptr, seg_ids, int(sizes.sum())


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k,n,tile", [(8, 8, 8), (16, 24, 8), (32, 128, 16),
                                      (64, 48, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_mm_sweep(rng, backend, k, n, tile, dtype):
    ptr, seg_ids, m = _segments(rng, n_groups=5, max_size=21)
    if m == 0:
        pytest.skip("empty")
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(5, k, n)), dtype)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, tile))
    y = ops.segment_mm(x, w, lay, backend=backend)
    y_ref = R.segment_mm_ref(x, w, jnp.asarray(seg_ids))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_mm_row_scale_fusion(rng, backend):
    ptr, seg_ids, m = _segments(rng, 4, 17)
    x = jnp.asarray(rng.normal(size=(m, 12)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 12, 20)), jnp.float32)
    scale = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, 8))
    y = ops.segment_mm(x, w, lay, row_scale=scale, backend=backend)
    y_ref = R.segment_mm_ref(x, w, jnp.asarray(seg_ids), scale)
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_mm_grads(rng, backend):
    ptr, seg_ids, m = _segments(rng, 5, 13)
    x = jnp.asarray(rng.normal(size=(m, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(5, 16, 24)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, 8))

    def f(x, w, s):
        return jnp.sum(jnp.sin(ops.segment_mm(x, w, lay, row_scale=s,
                                              backend=backend)))

    def f_ref(x, w, s):
        return jnp.sum(jnp.sin(R.segment_mm_ref(x, w, jnp.asarray(seg_ids), s)))

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, s)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, s)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def _dst_layout(rng, n_nodes, n_edges, tile=8, nb=8):
    dst = np.sort(rng.integers(0, n_nodes, n_edges)).astype(np.int32)
    canon = rng.permutation(dst)
    perm = np.argsort(canon, kind="stable").astype(np.int32)
    ptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(np.bincount(canon[perm], minlength=n_nodes), out=ptr[1:])
    bc = ops.blocked_csr_dev(L.block_csr(ptr, tile, nb), perm)
    return jnp.asarray(canon), bc


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_nodes,n_edges,d", [(13, 60, 4), (40, 200, 12),
                                               (7, 7, 16)])
def test_softmax_agg_sweep(rng, backend, n_nodes, n_edges, d):
    dst, bc = _dst_layout(rng, n_nodes, n_edges)
    scores = jnp.asarray(rng.normal(size=(n_edges,)), jnp.float32)
    msg = jnp.asarray(rng.normal(size=(n_edges, d)), jnp.float32)
    out = ops.edge_softmax_agg(scores, msg, dst, n_nodes, bc=bc,
                               backend=backend)
    ref = R.softmax_agg_ref(scores, msg, dst, n_nodes)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_softmax_agg_grads(rng, backend):
    dst, bc = _dst_layout(rng, 11, 80)
    scores = jnp.asarray(rng.normal(size=(80,)), jnp.float32)
    msg = jnp.asarray(rng.normal(size=(80, 6)), jnp.float32)

    def f(s, m):
        return jnp.sum(jnp.cos(
            ops.edge_softmax_agg(s, m, dst, 11, bc=bc, backend=backend)))

    def f_ref(s, m):
        return jnp.sum(jnp.cos(R.softmax_agg_ref(s, m, dst, 11)))

    g = jax.grad(f, argnums=(0, 1))(scores, msg)
    gr = jax.grad(f_ref, argnums=(0, 1))(scores, msg)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_weighted_agg(rng, backend):
    dst, bc = _dst_layout(rng, 9, 50)
    scale = jnp.asarray(rng.normal(size=(50,)), jnp.float32)
    msg = jnp.asarray(rng.normal(size=(50, 5)), jnp.float32)
    out = ops.weighted_agg(scale, msg, dst, 9, bc=bc, backend=backend)
    ref = R.weighted_agg_ref(scale, msg, dst, 9)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# in-kernel gather access schemes (gather-fused variants)
# ---------------------------------------------------------------------------
def _gather_setup(rng, n_src=23, n_groups=4, max_size=17, k=6, n=5, tile=8):
    ptr, seg_ids, m = _segments(rng, n_groups, max_size)
    feats = jnp.asarray(rng.normal(size=(n_src, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n_groups, k, n)), jnp.float32)
    idx = rng.integers(0, n_src, size=m).astype(np.int32)
    ps = L.pad_segments(ptr, tile)
    lay = ops.padded_segments_dev(ps)
    gmap = jnp.asarray(L.compose_gather_rows(ps, idx))
    return feats, w, idx, seg_ids, lay, gmap, m


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("with_scale", [False, True])
def test_segment_mm_gather_matches_materialized(rng, backend, with_scale):
    feats, w, idx, seg_ids, lay, gmap, m = _gather_setup(rng)
    scale = (jnp.asarray(rng.normal(size=(m,)), jnp.float32)
             if with_scale else None)
    fused = ops.segment_mm_gather(feats, w, lay, gmap, row_scale=scale,
                                  backend=backend)
    materialized = ops.segment_mm(feats[idx], w, lay, row_scale=scale,
                                  backend=backend)
    ref = R.gather_mm_ref(feats, w, jnp.asarray(idx), jnp.asarray(seg_ids),
                          scale)
    np.testing.assert_allclose(fused, materialized, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(fused, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_mm_gather_grads(rng, backend):
    feats, w, idx, seg_ids, lay, gmap, m = _gather_setup(rng)
    scale = jnp.asarray(rng.normal(size=(m,)), jnp.float32)

    def f(feats, w, s):
        return jnp.sum(jnp.sin(ops.segment_mm_gather(
            feats, w, lay, gmap, row_scale=s, backend=backend)))

    def f_ref(feats, w, s):
        return jnp.sum(jnp.sin(R.gather_mm_ref(
            feats, w, jnp.asarray(idx), jnp.asarray(seg_ids), s)))

    g = jax.grad(f, argnums=(0, 1, 2))(feats, w, scale)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(feats, w, scale)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def _iter_eqns_outside_kernels(jaxpr):
    """All eqns reachable from ``jaxpr`` WITHOUT descending into Pallas
    kernel bodies — i.e. everything XLA would execute around the kernels."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue

        def _sub(v):
            if hasattr(v, "jaxpr") and hasattr(v, "eqns") is False:
                return [v.jaxpr]  # ClosedJaxpr
            if hasattr(v, "eqns"):
                return [v]        # Jaxpr
            if isinstance(v, (list, tuple)):
                return [j for item in v for j in _sub(item)]
            return []

        for v in eqn.params.values():
            for sub in _sub(v):
                yield from _iter_eqns_outside_kernels(sub)


def test_segment_mm_gather_no_prekernel_edge_copy(rng):
    """Acceptance: the gather-fused GEMM never materializes an edge-wide
    [rows, k] input copy outside the Pallas kernel (the gather lives in the
    kernel's index space). k=6 != n=5 disambiguates input-side gathers from
    the post-kernel output unpadding."""
    feats, w, idx, seg_ids, lay, gmap, m = _gather_setup(rng)
    k = feats.shape[1]
    rp = int(lay.row_map.shape[0])

    def fused(feats, w):
        return ops.segment_mm_gather(feats, w, lay, gmap,
                                     backend="pallas_interpret")

    jaxpr = jax.make_jaxpr(fused)(feats, w)
    gather_prims = {"gather", "take", "dynamic_slice"}
    banned = {(m, k), (rp, k)}   # edge-wide input copies
    offending = [
        eqn for eqn in _iter_eqns_outside_kernels(jaxpr.jaxpr)
        if eqn.primitive.name in gather_prims
        and any(tuple(o.aval.shape) in banned for o in eqn.outvars)
    ]
    assert not offending, (
        f"edge-wide input gather materialized outside the kernel: "
        f"{offending}")
    # the materialized path DOES produce one (sanity check of the detector)
    def materialized(feats, w):
        return ops.segment_mm(feats[jnp.asarray(idx)], w, lay,
                              backend="pallas_interpret")
    jaxpr_m = jax.make_jaxpr(materialized)(feats, w)
    hits = [
        eqn for eqn in _iter_eqns_outside_kernels(jaxpr_m.jaxpr)
        if eqn.primitive.name in gather_prims
        and any(tuple(o.aval.shape) in banned for o in eqn.outvars)
    ]
    assert hits, "detector failed to flag the materialized-gather baseline"


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("compact", [False, True])
def test_softmax_agg_gather_fused_matches_materialized(rng, backend, compact):
    n_nodes, n_edges, d = 13, 60, 4
    dst, bc = _dst_layout(rng, n_nodes, n_edges)
    scores = jnp.asarray(rng.normal(size=(n_edges,)), jnp.float32)
    if compact:
        n_rows = 20
        msg_rows = jnp.asarray(rng.integers(0, n_rows, n_edges), jnp.int32)
        msg = jnp.asarray(rng.normal(size=(n_rows, d)), jnp.float32)
        msg_e = msg[msg_rows]
    else:
        msg_rows = None
        msg = jnp.asarray(rng.normal(size=(n_edges, d)), jnp.float32)
        msg_e = msg
    fused = ops.edge_softmax_agg(scores, msg, dst, n_nodes, bc=bc,
                                 backend=backend, msg_rows=msg_rows,
                                 fuse_gather=True)
    materialized = ops.edge_softmax_agg(scores, msg, dst, n_nodes, bc=bc,
                                        backend=backend, msg_rows=msg_rows,
                                        fuse_gather=False)
    ref = R.softmax_agg_ref(scores, msg_e, dst, n_nodes)
    np.testing.assert_allclose(fused, materialized, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(fused, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_weighted_agg_gather_fused_compact_and_grads(rng, backend):
    n_nodes, n_edges, n_rows, d = 9, 50, 17, 5
    dst, bc = _dst_layout(rng, n_nodes, n_edges)
    msg_rows = jnp.asarray(rng.integers(0, n_rows, n_edges), jnp.int32)
    scale = jnp.asarray(rng.normal(size=(n_edges,)), jnp.float32)
    msg = jnp.asarray(rng.normal(size=(n_rows, d)), jnp.float32)

    def f(s, m):
        return jnp.sum(jnp.cos(ops.weighted_agg(
            s, m, dst, n_nodes, bc=bc, backend=backend,
            msg_rows=msg_rows, fuse_gather=True)))

    def f_ref(s, m):
        return jnp.sum(jnp.cos(R.weighted_agg_ref(s, m[msg_rows], dst,
                                                  n_nodes)))

    np.testing.assert_allclose(
        ops.weighted_agg(scale, msg, dst, n_nodes, bc=bc, backend=backend,
                         msg_rows=msg_rows),
        R.weighted_agg_ref(scale, msg[msg_rows], dst, n_nodes),
        rtol=1e-5, atol=1e-5)
    g = jax.grad(f, argnums=(0, 1))(scale, msg)
    gr = jax.grad(f_ref, argnums=(0, 1))(scale, msg)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n_groups=st.integers(1, 6),
    k=st.sampled_from([4, 8, 12]),
    n=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 3),
)
def test_property_segment_mm_matches_ref(n_groups, k, n, seed):
    rng = np.random.default_rng(seed)
    ptr, seg_ids, m = _segments(rng, n_groups, 11)
    if m == 0:
        return
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n_groups, k, n)), jnp.float32)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, 4))
    y = ops.segment_mm(x, w, lay, backend="pallas_interpret")
    np.testing.assert_allclose(
        y, R.segment_mm_ref(x, w, jnp.asarray(seg_ids)), rtol=2e-5, atol=2e-5)
