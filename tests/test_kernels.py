"""Per-kernel shape/dtype sweeps + gradient checks vs the ref.py oracles
(deliverable c: each Pallas kernel validated in interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import layout as L, ops, ref as R

BACKENDS = ["xla", "pallas_interpret"]


def _segments(rng, n_groups, max_size):
    sizes = rng.integers(0, max_size, n_groups)
    ptr = np.zeros(n_groups + 1, np.int64)
    np.cumsum(sizes, out=ptr[1:])
    seg_ids = np.repeat(np.arange(n_groups), sizes)
    return ptr, seg_ids, int(sizes.sum())


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k,n,tile", [(8, 8, 8), (16, 24, 8), (32, 128, 16),
                                      (64, 48, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_mm_sweep(rng, backend, k, n, tile, dtype):
    ptr, seg_ids, m = _segments(rng, n_groups=5, max_size=21)
    if m == 0:
        pytest.skip("empty")
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(5, k, n)), dtype)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, tile))
    y = ops.segment_mm(x, w, lay, backend=backend)
    y_ref = R.segment_mm_ref(x, w, jnp.asarray(seg_ids))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_mm_row_scale_fusion(rng, backend):
    ptr, seg_ids, m = _segments(rng, 4, 17)
    x = jnp.asarray(rng.normal(size=(m, 12)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 12, 20)), jnp.float32)
    scale = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, 8))
    y = ops.segment_mm(x, w, lay, row_scale=scale, backend=backend)
    y_ref = R.segment_mm_ref(x, w, jnp.asarray(seg_ids), scale)
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_mm_grads(rng, backend):
    ptr, seg_ids, m = _segments(rng, 5, 13)
    x = jnp.asarray(rng.normal(size=(m, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(5, 16, 24)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, 8))

    def f(x, w, s):
        return jnp.sum(jnp.sin(ops.segment_mm(x, w, lay, row_scale=s,
                                              backend=backend)))

    def f_ref(x, w, s):
        return jnp.sum(jnp.sin(R.segment_mm_ref(x, w, jnp.asarray(seg_ids), s)))

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, s)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, s)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def _dst_layout(rng, n_nodes, n_edges, tile=8, nb=8):
    dst = np.sort(rng.integers(0, n_nodes, n_edges)).astype(np.int32)
    canon = rng.permutation(dst)
    perm = np.argsort(canon, kind="stable").astype(np.int32)
    ptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(np.bincount(canon[perm], minlength=n_nodes), out=ptr[1:])
    bc = ops.blocked_csr_dev(L.block_csr(ptr, tile, nb), perm)
    return jnp.asarray(canon), bc


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_nodes,n_edges,d", [(13, 60, 4), (40, 200, 12),
                                               (7, 7, 16)])
def test_softmax_agg_sweep(rng, backend, n_nodes, n_edges, d):
    dst, bc = _dst_layout(rng, n_nodes, n_edges)
    scores = jnp.asarray(rng.normal(size=(n_edges,)), jnp.float32)
    msg = jnp.asarray(rng.normal(size=(n_edges, d)), jnp.float32)
    out = ops.edge_softmax_agg(scores, msg, dst, n_nodes, bc=bc,
                               backend=backend)
    ref = R.softmax_agg_ref(scores, msg, dst, n_nodes)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_softmax_agg_grads(rng, backend):
    dst, bc = _dst_layout(rng, 11, 80)
    scores = jnp.asarray(rng.normal(size=(80,)), jnp.float32)
    msg = jnp.asarray(rng.normal(size=(80, 6)), jnp.float32)

    def f(s, m):
        return jnp.sum(jnp.cos(
            ops.edge_softmax_agg(s, m, dst, 11, bc=bc, backend=backend)))

    def f_ref(s, m):
        return jnp.sum(jnp.cos(R.softmax_agg_ref(s, m, dst, 11)))

    g = jax.grad(f, argnums=(0, 1))(scores, msg)
    gr = jax.grad(f_ref, argnums=(0, 1))(scores, msg)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_weighted_agg(rng, backend):
    dst, bc = _dst_layout(rng, 9, 50)
    scale = jnp.asarray(rng.normal(size=(50,)), jnp.float32)
    msg = jnp.asarray(rng.normal(size=(50, 5)), jnp.float32)
    out = ops.weighted_agg(scale, msg, dst, 9, bc=bc, backend=backend)
    ref = R.weighted_agg_ref(scale, msg, dst, 9)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    n_groups=st.integers(1, 6),
    k=st.sampled_from([4, 8, 12]),
    n=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 3),
)
def test_property_segment_mm_matches_ref(n_groups, k, n, seed):
    rng = np.random.default_rng(seed)
    ptr, seg_ids, m = _segments(rng, n_groups, 11)
    if m == 0:
        return
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n_groups, k, n)), jnp.float32)
    lay = ops.padded_segments_dev(L.pad_segments(ptr, 4))
    y = ops.segment_mm(x, w, lay, backend="pallas_interpret")
    np.testing.assert_allclose(
        y, R.segment_mm_ref(x, w, jnp.asarray(seg_ids)), rtol=2e-5, atol=2e-5)
