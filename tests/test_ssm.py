"""Mamba2/SSD invariants: the chunked algorithm equals the sequential
recurrence; decode continues prefill exactly."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.nn.ssm import ssd_chunked, ssd_sequential

RNG = np.random.default_rng(0)


def _inputs(b, l, h, p, n, scale=1.0):
    xh = jnp.asarray(RNG.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, l, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(b, l, h, n)) * scale, jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, l, h, n)) * scale, jnp.float32)
    return xh, dt, a, bm, cm


@pytest.mark.parametrize("l,chunk", [(16, 4), (32, 8), (24, 24), (8, 2)])
def test_chunked_equals_sequential(l, chunk):
    xh, dt, a, bm, cm = _inputs(2, l, 3, 4, 5)
    y_c, s_c = ssd_chunked(xh, dt, a, bm, cm, chunk)
    y_s, s_s = ssd_sequential(xh, dt, a, bm, cm)
    np.testing.assert_allclose(y_c, y_s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s_c, s_s, rtol=1e-4, atol=1e-4)


def test_chunked_with_initial_state():
    xh, dt, a, bm, cm = _inputs(1, 16, 2, 3, 4)
    init = jnp.asarray(RNG.normal(size=(1, 2, 3, 4)), jnp.float32)
    y_c, s_c = ssd_chunked(xh, dt, a, bm, cm, 4, init_state=init)
    y_s, s_s = ssd_sequential(xh, dt, a, bm, cm, init_state=init)
    np.testing.assert_allclose(y_c, y_s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s_c, s_s, rtol=1e-4, atol=1e-4)


def test_state_handoff_splits_sequence():
    """Running [0:L1] then [L1:L] with the carried state == full run."""
    xh, dt, a, bm, cm = _inputs(1, 24, 2, 3, 4)
    y_full, s_full = ssd_sequential(xh, dt, a, bm, cm)
    y1, s1 = ssd_chunked(xh[:, :16], dt[:, :16], a, bm[:, :16], cm[:, :16], 8)
    y2, s2 = ssd_sequential(xh[:, 16:], dt[:, 16:], a, bm[:, 16:], cm[:, 16:],
                            init_state=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2, s_full, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(l=st.sampled_from([8, 16]), chunk=st.sampled_from([2, 4, 8]),
       h=st.integers(1, 3), seed=st.integers(0, 3))
def test_property_chunk_size_invariance(l, chunk, h, seed):
    rng = np.random.default_rng(seed)
    xh = jnp.asarray(rng.normal(size=(1, l, h, 2)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(1, l, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(1, l, h, 3)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(1, l, h, 3)), jnp.float32)
    y1, s1 = ssd_chunked(xh, dt, a, bm, cm, chunk)
    y2, s2 = ssd_chunked(xh, dt, a, bm, cm, l)   # single chunk
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_mamba_layer_decode_continues_prefill():
    from repro import configs as C
    from repro.nn.ssm import mamba_forward
    cfg = C.get_reduced("mamba2-780m")
    from repro.nn.ssm import init_mamba
    params = init_mamba(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 17, cfg.d_model)), jnp.float32)
    # full forward over 17 tokens
    y_full, _ = mamba_forward(params, x, cfg)
    # prefill 16 (chunked) then decode 1 (sequential)
    y_pre, cache = mamba_forward(params, x[:, :16], cfg, return_cache=True)
    y_dec, _ = mamba_forward(params, x[:, 16:], cfg, cache=cache)
    np.testing.assert_allclose(y_pre, y_full[:, :16], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_dec, y_full[:, 16:], rtol=1e-3, atol=1e-3)
