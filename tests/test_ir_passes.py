"""Compiler-pass unit tests: reordering, compaction, lowering structure,
and construction-time validation of malformed programs."""
import pytest

from repro.core.ir import inter_op as I
from repro.core.ir import intra_op as O
from repro.core.ir.passes import (
    apply_compact_materialization, lower_program, reorder_linear_ops,
)
from repro.core.ir.validate import ProgramValidationError
from repro.models import hgt_program, rgat_program, rgcn_program


def test_reorder_creates_weight_products():
    prog = rgat_program(8, 8)
    new, wprods = reorder_linear_ops(prog)
    # both attention dots reorder into weight-weight products
    assert len(wprods) == 2
    names = {w.out for w in wprods}
    # the rewritten statements now use the composed typed linear
    rewritten = [s for s in new.stmts
                 if isinstance(s, I.EdgeCompute)
                 and isinstance(s.expr, I.TypedLinear)
                 and s.expr.weight.name in names]
    assert len(rewritten) == 2
    # composed weight has output dim 1 (a typed GEMV)
    assert all(s.expr.weight.shape[-1] == 1 for s in rewritten)


def test_compaction_marks_src_etype_only():
    prog = rgat_program(8, 8)
    marked = apply_compact_materialization(prog)
    assert marked.layout_of("hs") == I.Layout.COMPACT
    # attt depends on dst: must stay vanilla
    assert marked.layout_of("attt") == I.Layout.VANILLA
    assert marked.layout_of("att_raw") == I.Layout.VANILLA


def test_compaction_hgt_messages():
    prog = hgt_program(8, 8)
    marked = apply_compact_materialization(prog)
    # the paper's msg_HGT example (Fig. 7): katt and msg are compactable
    assert marked.layout_of("katt") == I.Layout.COMPACT
    assert marked.layout_of("msg") == I.Layout.COMPACT


@pytest.mark.parametrize("prog_fn,max_fallback", [
    (rgcn_program, 0), (rgat_program, 0), (hgt_program, 0),
])
def test_lowering_never_falls_back(prog_fn, max_fallback):
    """§3.2.5: all three paper models lower fully onto the two templates."""
    for reorder in (False, True):
        for compact in (False, True):
            plan = lower_program(prog_fn(16, 16), reorder=reorder,
                                 compact=compact)
            assert plan.fallback_count() <= max_fallback, plan.describe()
            assert plan.gemm_count() >= 1
            assert plan.traversal_count() >= 1


def test_lowering_preference_gemm_first():
    plan = lower_program(rgat_program(16, 16), reorder=True, compact=True)
    kinds = [type(op).__name__ for op in plan.ops]
    # weight products hoisted to the front, GEMMs before the traversal tail
    assert kinds[0] == "WeightProductSpec"
    gemm_idx = [i for i, k in enumerate(kinds) if k == "GemmSpec"]
    trav_idx = [i for i, k in enumerate(kinds) if k == "TraversalSpec"]
    assert min(gemm_idx) < min(trav_idx)


def test_reordered_rgat_gemm_count():
    """Reordering moves the per-edge [d x d] GEMsM to per-relation BMM:
    edgewise GEMMs shrink to out_cols=1 instances."""
    plan = lower_program(rgat_program(16, 16), reorder=True, compact=True)
    gemv = [op for op in plan.ops
            if isinstance(op, O.GemmSpec) and op.out_cols == 1]
    assert len(gemv) == 2  # atts + attt


def test_compact_gemm_uses_unique_gather():
    plan = lower_program(hgt_program(16, 16), reorder=False, compact=True)
    compact_gemms = [op for op in plan.ops if isinstance(op, O.GemmSpec)
                     and op.gather == O.GatherScheme.BY_UNIQUE_SRC]
    assert len(compact_gemms) == 2  # katt, msg over unique (src, etype) rows
    assert all(op.seg_ptr == "unique_etype_ptr" for op in compact_gemms)


def test_traversal_fusion_single_region():
    """EdgeSoftmax + NodeAggregate fuse into ONE traversal instance."""
    plan = lower_program(rgat_program(16, 16), reorder=True, compact=True)
    assert plan.traversal_count() == 1
    trav = [op for op in plan.ops if isinstance(op, O.TraversalSpec)][0]
    kinds = [s.kind for s in trav.stmts]
    assert "segment_max" in kinds and "segment_sum" in kinds


# ---------------------------------------------------------------------------
# construction-time validation (no more bare KeyErrors in the lowering)
# ---------------------------------------------------------------------------
def test_lower_rejects_undefined_softmax_src():
    """An EdgeSoftmax reading an edge var nobody wrote used to KeyError
    deep inside codegen; now it is a named error with the statement index
    and the missing var."""
    prog = I.Program(
        stmts=[I.EdgeSoftmax("att", "scores"),
               I.NodeAggregate("h", msg="att")],
        outputs=["h"], name="bad")
    with pytest.raises(ProgramValidationError) as ei:
        lower_program(prog)
    msg = str(ei.value)
    assert "undefined edge var 'scores'" in msg
    assert "statement 0" in msg and "'bad'" in msg
    assert ei.value.stmt_index == 0


def test_lower_rejects_undefined_aggregate_msg():
    W = I.Weight("W", (8, 8), indexed_by="etype")
    prog = I.Program(
        stmts=[I.EdgeCompute("hs", I.TypedLinear(I.SrcFeature("feature"), W)),
               I.NodeAggregate("h", msg="mgs", scale=None)],   # typo'd var
        outputs=["h"], name="bad")
    with pytest.raises(ProgramValidationError) as ei:
        lower_program(prog)
    msg = str(ei.value)
    assert "undefined edge var 'mgs'" in msg
    assert "edge vars defined so far: hs" in msg
    assert ei.value.stmt_index == 1


def test_lower_rejects_undefined_aggregate_scale():
    W = I.Weight("W", (8, 8), indexed_by="etype")
    prog = I.Program(
        stmts=[I.EdgeCompute("hs", I.TypedLinear(I.SrcFeature("feature"), W)),
               I.NodeAggregate("h", msg="hs", scale="att")],
        outputs=["h"], name="bad")
    with pytest.raises(ProgramValidationError, match="undefined edge var "
                                                     "'att'"):
        lower_program(prog)


def test_lower_rejects_undefined_edge_var_in_node_compute():
    """Referential checks cover node statements too: a NodeCompute reading
    an edge var nobody wrote must not slip through to codegen."""
    prog = I.Program(
        stmts=[I.NodeCompute("h", I.Binary("add", I.EdgeVar("ghost"),
                                           I.Scalar(1.0)))],
        outputs=["h"], name="bad")
    with pytest.raises(ProgramValidationError,
                       match="undefined edge var 'ghost'"):
        lower_program(prog)


def test_lower_rejects_unassigned_output():
    W = I.Weight("W", (8, 8), indexed_by="etype")
    prog = I.Program(
        stmts=[I.EdgeCompute("hs", I.TypedLinear(I.SrcFeature("feature"), W))],
        outputs=["h_out"], name="bad")
    with pytest.raises(ProgramValidationError,
                       match="output 'h_out' is never assigned"):
        lower_program(prog)


# ---------------------------------------------------------------------------
# Program.describe() / fingerprint (stable structural identity)
# ---------------------------------------------------------------------------
def test_program_describe_stable_across_clone():
    prog = hgt_program(8, 8)
    assert prog.clone().describe() == prog.describe()
    assert prog.clone().fingerprint() == prog.fingerprint()


def test_program_fingerprint_sensitivity():
    base = rgat_program(8, 8)
    assert base.fingerprint() == rgat_program(8, 8).fingerprint()
    assert base.fingerprint() != rgat_program(8, 16).fingerprint()
    assert base.fingerprint() != rgat_program(8, 8, slope=0.2).fingerprint()
    # layout annotations are part of the structural identity
    marked = apply_compact_materialization(base)
    assert marked.fingerprint() != base.fingerprint()


def test_plan_fingerprint_tracks_lowering_choices():
    a = lower_program(rgat_program(8, 8), reorder=True, compact=True)
    b = lower_program(rgat_program(8, 8), reorder=True, compact=True)
    c = lower_program(rgat_program(8, 8), reorder=False, compact=True)
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != c.fingerprint()
