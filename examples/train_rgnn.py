"""End-to-end RGNN training example: 2-layer RGAT node classifier trained
on a synthetic heterograph with AdamW, cosine LR and checkpointing.

The forward runs through the compiled executors (``StackTrainExecutor``:
the whole step — layer-by-layer generated code, cross-entropy, backward
through the ``custom_vjp`` kernels, AdamW update — is one jitted callable);
no op-by-op ``execute_plan`` loop is involved. For the neighbor-sampled
mini-batch trainer, see ``python -m repro.launch.train_rgnn``.

    PYTHONPATH=src python examples/train_rgnn.py [--steps 200]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

import hector
from repro.checkpoint import Checkpointer
from repro.core.graph import synthetic_heterograph
from repro.optim import AdamW, cosine_schedule
from repro.train import FullGraphTrainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/rgnn_ckpt")
    args = ap.parse_args(argv)

    graph = synthetic_heterograph(2000, 16000, num_ntypes=4, num_etypes=16,
                                  seed=0, target_compaction=0.5)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(graph.num_nodes, args.dim)), jnp.float32)
    labels = np.asarray(rng.integers(0, args.classes, graph.num_nodes))

    engine = hector.compile("rgat", graph, layers=2, dim=args.dim,
                            hidden=args.dim, classes=args.classes)
    opt = AdamW(learning_rate=cosine_schedule(3e-3, 20, args.steps),
                weight_decay=0.01)
    trainer = FullGraphTrainer(engine, x, labels,
                               np.arange(graph.num_nodes), opt=opt)
    state = trainer.init_state(engine.init(jax.random.key(1)))
    ckpt = Checkpointer(args.ckpt)

    losses = []
    for i in range(0, args.steps, 50):
        state, chunk = trainer.train(state, steps=min(50, args.steps - i))
        losses.extend(chunk)
        ckpt.save(i + len(chunk), state)
        print(f"step {len(losses):4d}  loss {losses[-1]:.4f}")
    ckpt.wait()
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(acc proxy: {np.exp(-losses[-1]):.2%} vs chance "
          f"{1/args.classes:.2%})")
    assert losses[-1] < losses[0]
    return losses


if __name__ == "__main__":
    main()
