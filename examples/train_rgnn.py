"""End-to-end RGNN training driver: 2-layer RGAT node classifier trained for
a few hundred steps on a synthetic heterograph (the paper's workload kind),
with AdamW, cosine LR and checkpointing.

    PYTHONPATH=src python examples/train_rgnn.py [--steps 200]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.core.graph import synthetic_heterograph
from repro.core.module import HectorModule
from repro.models import rgat_program
from repro.optim import AdamW, cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/rgnn_ckpt")
    args = ap.parse_args(argv)

    graph = synthetic_heterograph(2000, 16000, num_ntypes=4, num_etypes=16,
                                  seed=0, target_compaction=0.5)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(graph.num_nodes, args.dim)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, args.classes, graph.num_nodes))

    layer1 = HectorModule(rgat_program(args.dim, args.dim), graph)
    layer2 = HectorModule(rgat_program(args.dim, args.classes), graph)
    params = {"l1": layer1.init(jax.random.key(1)),
              "l2": layer2.init(jax.random.key(2))}

    def forward(p, feats):
        h = layer1.apply(p["l1"], {"feature": feats})["h_out"]
        h = jax.nn.relu(h)
        return layer2.apply(p["l2"], {"feature": h})["h_out"]

    def loss_fn(p):
        logits = forward(p, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    opt = AdamW(learning_rate=cosine_schedule(3e-3, 20, args.steps),
                weight_decay=0.01)
    state = opt.init(params)
    ckpt = Checkpointer(args.ckpt)

    @jax.jit
    def step(state):
        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        return opt.update(grads, state), loss

    losses = []
    for i in range(args.steps):
        state, loss = step(state)
        losses.append(float(loss))
        if (i + 1) % 50 == 0:
            ckpt.save(i + 1, state)
            print(f"step {i+1:4d}  loss {losses[-1]:.4f}")
    ckpt.wait()
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(acc proxy: {np.exp(-losses[-1]):.2%} vs chance "
          f"{1/args.classes:.2%})")
    assert losses[-1] < losses[0]
    return losses


if __name__ == "__main__":
    main()
