"""Batched LM serving on CPU with a reduced architecture: prefill + decode
with the sharded KV-cache path (wraps repro.launch.serve).

    PYTHONPATH=src python examples/serve_lm.py --arch jamba-v0.1-52b
"""
import argparse
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    args, rest = ap.parse_known_args()
    serve_main(["--arch", args.arch, "--reduced", "--batch", "4",
                "--prompt-len", "32", "--gen", "16", *rest])
