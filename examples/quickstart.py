"""Quickstart: author an RGNN in the Python-embedded DSL, compile it with
the unified ``hector.compile()`` front door, inspect the generated plans,
and run every execution mode — the paper's Figure-5 workflow.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

import hector
from repro.core.graph import synthetic_heterograph

# a small heterogeneous graph: 5 node types, 12 relation types
graph = synthetic_heterograph(num_nodes=1000, num_edges=8000,
                              num_ntypes=5, num_etypes=12, seed=0)
print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
      f"entity compaction ratio {graph.entity_compaction_ratio:.2f}")


# the model is a plain function over edge/node proxies; tracing it emits
# the inter-operator IR (6 statements), validated with source-located
# diagnostics at trace time
@hector.model
def rgat(g, e, n, in_dim, out_dim, slope=0.01):
    W = g.weight("W_rel", (in_dim, out_dim), indexed_by="etype")
    w_s = g.weight("w_att_src", (out_dim,), indexed_by="etype")
    w_t = g.weight("w_att_dst", (out_dim,), indexed_by="etype")
    e["hs"] = e.src["feature"] @ W
    e["atts"] = hector.dot(e["hs"], w_s)
    e["attt"] = hector.dot(e.dst["feature"] @ W, w_t)
    e["att_raw"] = hector.leaky_relu(e["atts"] + e["attt"], slope)
    e["att"] = hector.edge_softmax(e["att_raw"])
    n["h_out"] = hector.aggregate(e["hs"], scale=e["att"])
    return n["h_out"]


print("\ntraced program:")
print(rgat(64, 64).describe())

# one call: trace -> reorder/compact -> lower -> compiled executors + sampler
compiled = hector.compile(rgat, graph, layers=2, dim=64, hidden=64,
                          classes=16, sample=5)
print("\ngenerated plans:")
print(compiled.describe())

params = compiled.init(0)
x = jnp.asarray(np.random.default_rng(0).normal(size=(graph.num_nodes, 64)),
                jnp.float32)

# full-graph forward (per-layer PlanExecutor, jitted + cached)
logits = compiled.apply(params, x)
print(f"\nfull-graph logits: {logits.shape} "
      f"finite={bool(jnp.all(jnp.isfinite(logits)))}")

# sampled mini-batch forward + one compiled train step over the same stack
labels = np.random.default_rng(1).integers(0, 16, graph.num_nodes)
loader = compiled.make_loader(
    lambda step: np.arange(32, dtype=np.int32), num_batches=2, depth=1)
state = compiled.init_state(params)
try:
    for mb in loader:
        batch_logits = compiled.apply_blocks(params, mb, x)
        state, metrics = compiled.train_step(
            state, mb, mb.seq.slice_labels(labels), x)
        print(f"batch {mb.step}: sampled logits {batch_logits.shape}, "
              f"train loss {float(metrics['loss']):.4f}")
finally:
    loader.close()

# malformed models are rejected at trace time with the offending line
@hector.model
def broken(g, e, n, in_dim, out_dim):
    W = g.weight("W", (in_dim, out_dim), indexed_by="etype")
    e["hs"] = e.src["feature"] @ W
    n["h_out"] = hector.aggregate(e["hs"], scale=e["att"])   # 'att' undefined
    return n["h_out"]

try:
    broken(64, 64)
except hector.ProgramValidationError as err:
    print(f"\nvalidation catches authoring bugs:\n  {err}")
