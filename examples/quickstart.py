"""Quickstart: express an RGNN in Hector IR, compile, inspect the generated
plan, and run it — the paper's Figure-5 workflow in ~20 lines of user code.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.graph import synthetic_heterograph
from repro.core.module import HectorModule
from repro.models import rgat_program

# a small heterogeneous graph: 5 node types, 12 relation types
graph = synthetic_heterograph(num_nodes=1000, num_edges=8000,
                              num_ntypes=5, num_etypes=12, seed=0)
print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
      f"entity compaction ratio {graph.entity_compaction_ratio:.2f}")

# the model is inter-operator IR (6 statements); compilation applies linear
# operator reordering + compact materialization and lowers onto the GEMM /
# traversal templates
prog = rgat_program(in_dim=64, out_dim=64)
mod = HectorModule(prog, graph, reorder=True, compact=True, backend="xla")
print("\ngenerated plan:")
print(mod.describe())

params = mod.init(jax.random.key(0))
x = jnp.asarray(np.random.default_rng(0).normal(size=(graph.num_nodes, 64)),
                jnp.float32)
out = mod.apply(params, {"feature": x})["h_out"]
print(f"\noutput: {out.shape} finite={bool(jnp.all(jnp.isfinite(out)))}")

# gradients come from template-derived backward ops (custom_vjp)
loss, grads = jax.value_and_grad(
    lambda p: jnp.mean(mod.apply(p, {"feature": x})["h_out"] ** 2))(params)
print(f"loss={float(loss):.4f}, grad norms: "
      + ", ".join(f"{k}={float(jnp.linalg.norm(v)):.3f}"
                  for k, v in grads.items()))
