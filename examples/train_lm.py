"""LM pre-training loop with fault-tolerance drill: trains a reduced config
of any assigned architecture with async checkpointing, then simulates a host
failure mid-run and recovers (wraps repro.launch.train).

    PYTHONPATH=src python examples/train_lm.py --arch moonshot-v1-16b-a3b
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=30)
    args, rest = ap.parse_known_args()
    train_main(["--arch", args.arch, "--reduced",
                "--steps", str(args.steps), "--batch", "8", "--seq", "64",
                "--ckpt-dir", "/tmp/train_lm_ckpt", "--ckpt-every", "10",
                "--simulate-failure", str(args.steps // 2), *rest])
