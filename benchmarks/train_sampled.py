"""Neighbor-sampled training benchmark: step latency + epoch throughput.

Runs the sampled RGNN trainer (``launch/train_rgnn.py``) on the reduced
synthetic heterograph and reports per-step latency (one compiled
``grad_and_update`` per mini-batch), end-to-end seed throughput, and
compiled-executor trace counts.

``--ci`` asserts the steady-state training contract: after the warmup
epoch the compiled train step retraces **zero** times across two further
epochs (shape-bucketed mini-batches all hit the executor compile cache),
and neighbor sampling stays stochastic across epochs — the same seed batch
draws fresh blocks each epoch instead of replaying a stale cached block
(the ``(seeds, fanout)``-keyed LRU bug). A retrace or a replayed block
fails the step.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import csv_row

CONFIG = dict(
    model="rgat", dataset="synthetic", scale=0.05, layers=2, dim=16,
    hidden=16, classes=6, fanouts=[3, 3], batch_size=32, epochs=3,
    lr=1e-2, tile=8, node_block=8, bucket=True, seed=0, val_frac=0.0,
    eval_every_epochs=0,
)


def run(out=print, backend: str = "xla", scale: float = 0.2):
    from repro.launch.train_rgnn import train
    cfg = dict(CONFIG, scale=scale, dim=32, hidden=32, batch_size=64,
               tile=16, node_block=16)
    stats = train(backend=backend, log=lambda *a, **k: None, **cfg)
    out(csv_row("train_sampled/step", stats["step_ms_p50"] / 1e3,
                f"seeds_per_s={stats['seeds_per_s']:.0f};"
                f"traces={stats['executor_traces']};"
                f"retraces_after_warmup={stats['retraces_after_warmup']}"))
    epoch_s = stats["step_ms_p50"] / 1e3 * stats["batches_per_epoch"]
    out(csv_row("train_sampled/epoch", epoch_s,
                f"batches_per_epoch={stats['batches_per_epoch']};"
                f"final_loss={stats['final_loss']:.4f}"))
    return stats


def check_fresh_blocks_per_epoch(failures) -> None:
    """The sampler/loader must draw fresh neighborhoods each epoch for the
    same seed batch — stale replay out of the (seeds, fanout)-keyed block
    cache would silently destroy sampling stochasticity under training.
    Appends failure strings to ``failures`` (shared with the regression
    test in tests/test_sampling.py — one implementation, two gates)."""
    from repro.core.graph import synthetic_heterograph
    from repro.sampling import FanoutSampler, MiniBatchLoader

    g = synthetic_heterograph(120, 900, num_ntypes=4, num_etypes=7, seed=0)
    sampler = FanoutSampler(g, [3, 3], seed=0)
    seeds = np.arange(32, dtype=np.int32)

    def edge_key(mb):
        b = mb.seq.blocks[0]
        return (b.node_ids[b.graph.src].tobytes(),
                b.node_ids[b.graph.dst].tobytes())

    class ConstantEpochStream:
        """Same seed batch every step; one step per 'epoch'."""
        def batch(self, step):
            return seeds
        def epoch_of(self, step):
            return step

    loader = MiniBatchLoader(sampler, ConstantEpochStream(), tile=8,
                             node_block=8, bucket=True, num_batches=3,
                             cache_blocks=8)
    try:
        keys = [edge_key(mb) for mb in loader]
        cache = loader.block_cache.stats()
    finally:
        loader.close()
    if len(set(keys)) != len(keys):
        failures.append(
            "same seed batch replayed identical blocks across epochs "
            "(block cache is not epoch-keyed)")
    if cache["hits"] != 0:
        failures.append(
            f"{cache['hits']} block-cache hits across epochs for a "
            f"training stream (expected 0: fresh sample each epoch)")


def ci_check(backend: str = "xla") -> None:
    """Training retrace/stochasticity regression gate (exit 1 on failure)."""
    from repro.launch.train_rgnn import train

    stats = train(backend=backend, log=lambda *a, **k: None, **CONFIG)
    failures = []
    # zero retraces across the two post-warmup epochs
    if stats["epochs"] - stats["warmup_steps"] // stats["batches_per_epoch"] \
            < 2:
        failures.append("config must leave >= 2 epochs after warmup")
    if stats["retraces_after_warmup"] != 0:
        failures.append(
            f"train step retraced {stats['retraces_after_warmup']}x after "
            f"the warmup epoch (expected 0 across two epochs)")
    if stats["executor_traces"] != stats["executor_compiled"]:
        failures.append(
            f"trace count {stats['executor_traces']} != compiled entries "
            f"{stats['executor_compiled']} (each bucket must trace once)")
    if not (stats["losses"][-1] < stats["losses"][0]):
        failures.append(
            f"loss did not decrease: {stats['losses'][0]:.4f} -> "
            f"{stats['losses'][-1]:.4f}")
    check_fresh_blocks_per_epoch(failures)
    if failures:
        for f in failures:
            print(f"[train_sampled --ci] FAIL: {f}", file=sys.stderr)
        raise SystemExit(1)
    print(f"[train_sampled --ci] OK: {stats['steps']} steps / "
          f"{stats['epochs']} epochs, {stats['executor_traces']} traces "
          f"({stats['executor_compiled']} buckets), 0 retraces after "
          f"warmup, fresh blocks each epoch, loss "
          f"{stats['losses'][0]:.4f} -> {stats['losses'][-1]:.4f}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="assertion mode (retrace + stochasticity gate)")
    ap.add_argument("--backend", default=None,
                    choices=["xla", "pallas", "pallas_interpret"])
    args = ap.parse_args(argv)
    if args.ci:
        ci_check(backend=args.backend or "xla")
    else:
        print("name,us_per_call,derived")
        run(backend=args.backend or "xla")


if __name__ == "__main__":
    main()
