"""Shared benchmark utilities: timing, dataset scaling, CSV output."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np

from repro.core.graph import CPU_REDUCED_SCALES, HeteroGraph, table3_graph

# CPU-tractable scale factors for the Table 3 datasets (names preserved;
# statistics proportional — see DESIGN.md §8.2). Shared with the serving
# driver's --reduced mode so benchmarks and serving see the same graphs.
BENCH_SCALES: Dict[str, float] = dict(CPU_REDUCED_SCALES)

DEFAULT_DATASETS = ["aifb", "mutag", "fb15k", "bgs"]


def bench_graph(name: str, scale_mult: float = 1.0) -> HeteroGraph:
    return table3_graph(name, scale=BENCH_SCALES[name] * scale_mult, seed=0)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds for a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
