"""Paper §4.1 programming-effort data point: Hector expressed the three
models in 51 lines of model code and generated ~8K lines of CUDA/C++.

Here the models are ``@hector.model`` DSL functions; this report counts the
non-blank, non-comment lines of each *model definition* (the decorated
function, decorator line excluded — ``ModelSpec.definition_loc``) against
the framework's "generated" layers (kernels + codegen + executors) and the
number of lowered plan ops. ``--ci`` gates the three paper models at
``MAX_MODEL_LOC`` total lines, pinning the paper-scale-brevity claim.

    PYTHONPATH=src python -m benchmarks.loc_report [--ci]
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from benchmarks.common import csv_row
from repro.core.ir.passes import lower_program
from repro.models import DSL_MODELS

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"

# the paper's three models (the gate target); extra zoo models are reported
# but do not count against the paper-parity budget
PAPER_MODELS = ("rgcn", "rgat", "hgt")
MAX_MODEL_LOC = 60


def _loc(path: pathlib.Path) -> int:
    n = 0
    for p in sorted(path.rglob("*.py")):
        for line in p.read_text().splitlines():
            s = line.strip()
            if s and not s.startswith("#"):
                n += 1
    return n


def run(out=print):
    per_model = {}
    for name, spec in DSL_MODELS.items():
        per_model[name] = spec.definition_loc
        out(csv_row(f"loc/model/{name}", 0.0, f"loc={per_model[name]}"))
    paper_loc = sum(per_model[m] for m in PAPER_MODELS)
    gen_loc = _loc(SRC / "kernels") + _loc(SRC / "core")
    plans = sum(len(lower_program(DSL_MODELS[m](64, 64)).ops)
                for m in PAPER_MODELS)
    ok = paper_loc <= MAX_MODEL_LOC
    out(csv_row("loc/model_definitions", 0.0,
                f"loc={paper_loc};gate={MAX_MODEL_LOC};ok={int(ok)}"))
    out(csv_row("loc/generator_and_kernels", 0.0, f"loc={gen_loc}"))
    out(csv_row("loc/generated_plan_ops", 0.0, f"ops={plans}"))
    return paper_loc, gen_loc, plans


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help=f"exit non-zero if the three paper models exceed "
                         f"{MAX_MODEL_LOC} definition LoC total")
    args = ap.parse_args(argv)
    paper_loc, _, _ = run()
    if args.ci and paper_loc > MAX_MODEL_LOC:
        print(f"[loc_report] FAIL: paper-model definitions total "
              f"{paper_loc} LoC > gate {MAX_MODEL_LOC}", file=sys.stderr)
        return 1
    if args.ci:
        print(f"[loc_report] OK: paper-model definitions total "
              f"{paper_loc} LoC <= gate {MAX_MODEL_LOC}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
