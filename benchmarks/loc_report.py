"""Paper §4.1 LoC data point: Hector took 51 lines of model code and
generated ~8K lines of CUDA/C++. Here: IR-level model definitions vs the
framework's "generated" layers (kernels + codegen + executors)."""
from __future__ import annotations

import inspect
import pathlib

from benchmarks.common import csv_row
from repro.core.ir.passes import lower_program
from repro.models import hgt, rgat, rgcn

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


def _loc(path: pathlib.Path) -> int:
    n = 0
    for p in sorted(path.rglob("*.py")):
        for line in p.read_text().splitlines():
            s = line.strip()
            if s and not s.startswith("#"):
                n += 1
    return n


def run(out=print):
    model_loc = 0
    for mod in (rgcn, rgat, hgt):
        src = inspect.getsource(mod)
        body = [l for l in src.splitlines() if l.strip()
                and not l.strip().startswith("#")]
        model_loc += len(body)
    gen_loc = _loc(SRC / "kernels") + _loc(SRC / "core")
    plans = sum(
        len(lower_program(fn(64, 64)).ops)
        for fn in (rgcn.rgcn_program, rgat.rgat_program, hgt.hgt_program))
    out(csv_row("loc/model_definitions", 0.0, f"loc={model_loc}"))
    out(csv_row("loc/generator_and_kernels", 0.0, f"loc={gen_loc}"))
    out(csv_row("loc/generated_plan_ops", 0.0, f"ops={plans}"))
    return model_loc, gen_loc, plans


if __name__ == "__main__":
    run()
