"""Device-native sampling gate: steady state with zero host pipeline work.

Serves repeat traffic (the power-law assumption of the serving benchmarks)
through both sampling pipelines and pins the device path's contract:

* **zero host builds** — with ``--sampler device`` every non-cached batch is
  built by the jit sampling + layout programs; the loader's ``host_builds``
  counter (which increments on every host NumPy sample/layout pass) must
  stay 0 for the whole run;
* **zero sampler retraces after warmup** — the fixed-shape bucketing makes
  every post-warmup batch replay already-traced programs
  (``sampler_retraces_after_warmup == 0``);
* **zero executor retraces after warmup** — device-built blocks land in the
  same bucketed-shape set, so the compiled block executor also replays.
* **zero count syncs, zero bucket overflows** — the sync-free bucket
  speculation never blocks on a stage-A count readback (the counters would
  expose a reintroduced blocking drain) and never truncates a batch with an
  under-sized shrunken bucket.

``--ci`` turns any violation into a failing exit code.

    PYTHONPATH=src python -m benchmarks.sample_native --ci
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from benchmarks.common import csv_row

CONFIG = dict(
    model="rgat", dataset="aifb", scale=0.05, layers=2, dim=8, hidden=8,
    classes=4, fanouts=[3, 3], batch_size=8, num_batches=12, tile=8,
    node_block=8, repeat_after=3, seed=0,
    # two full repeat cycles of warmup: the first cycle traces the
    # worst-case buckets, the second traces the shrunken ones after the
    # non-blocking count drains land
    warmup_batches=6,
)


def _quiet(*_a, **_k):
    pass


def run(out=print):
    """Host + device serving over identical repeat traffic; returns
    ``(problems, device_stats, host_stats)``."""
    from repro.launch.serve_rgnn import serve

    d = serve(sampler="device", log=_quiet, **CONFIG)
    h = serve(sampler="host", log=_quiet, **CONFIG)

    problems: List[str] = []
    if d["host_builds"] != 0:
        problems.append(
            f"device serve ran {d['host_builds']} host pipeline builds "
            f"(want 0)")
    if d["device_builds"] <= 0:
        problems.append("device serve built no batches on device")
    if d.get("sampler_retraces_after_warmup", 0) != 0:
        problems.append(
            f"device sampler retraced "
            f"{d['sampler_retraces_after_warmup']} times after warmup")
    if d["retraces_after_warmup"] != 0:
        problems.append(
            f"block executor retraced {d['retraces_after_warmup']} times "
            f"after warmup of the device stream")
    if d.get("sampler_count_syncs", 0) != 0:
        problems.append(
            f"device sampler blocked on {d['sampler_count_syncs']} count "
            f"readbacks (want a sync-free loop)")
    if d.get("sampler_bucket_overflows", 0) != 0:
        problems.append(
            f"{d['sampler_bucket_overflows']} stage-B bucket overflows "
            f"(shrunken guess truncated a batch)")
    # both pipelines must draw the same selection stream (shared
    # counter-based keys): identical last-batch predictions
    if d["last_preds"].tolist() != h["last_preds"].tolist():
        problems.append("device and host pipelines predicted differently "
                        "on the same seed stream")

    out(csv_row("sample_native/device", d["latency_ms_p50"] / 1e3,
                f"host_builds={d['host_builds']};"
                f"device_builds={d['device_builds']};"
                f"sampler_traces={d['sampler_traces']};"
                f"sampler_retraces={d['sampler_retraces_after_warmup']};"
                f"exec_retraces={d['retraces_after_warmup']};"
                f"count_syncs={d.get('sampler_count_syncs', 0)};"
                f"bucket_overflows={d.get('sampler_bucket_overflows', 0)};"
                f"bucket_shrinks={d.get('sampler_bucket_shrinks', 0)};"
                f"problems={len(problems)}"))
    out(csv_row("sample_native/host", h["latency_ms_p50"] / 1e3,
                f"host_builds={h['host_builds']};"
                f"wait_ms={h['wait_ms_mean']:.1f}"))
    return problems, d, h


def ci_check() -> None:
    """Exit 1 if the device steady state touches the host pipeline or
    retraces."""
    problems, d, _ = run(out=lambda *_: None)
    if problems:
        for pb in problems:
            print(f"[sample_native --ci] FAIL: {pb}", file=sys.stderr)
        raise SystemExit(1)
    print(f"[sample_native --ci] OK: {d['device_builds']} device-built "
          f"batches, 0 host builds, {d['sampler_traces']} sampler traces "
          f"(0 after warmup), 0 executor retraces, 0 count syncs, "
          f"{d.get('sampler_bucket_shrinks', 0)} bucket shrinks "
          f"(0 overflows); device p50 {d['latency_ms_p50']:.1f} ms")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="fail (exit 1) on any steady-state violation")
    args = ap.parse_args(argv)
    if args.ci:
        ci_check()
    else:
        print("name,us_per_call,derived")
        problems, _, _ = run()
        for pb in problems:
            print(f"[sample_native] problem: {pb}", file=sys.stderr)


if __name__ == "__main__":
    main()
