"""Observability smoke: serve + train with tracing on, schema-validated.

Runs one short serving loop and one short sampled-training loop with the
full telemetry surface enabled (metrics registry + span tracer + JSON
exports), then validates every artifact:

* the Chrome-trace JSON parses and conforms to the trace-event schema
  (``repro.obs.schema.validate_trace``),
* every registered phase span is present with nonzero duration
  (``sample`` / ``layout`` / ``execute`` for serving, ``sample`` /
  ``layout`` / ``train_step`` for training, and ``sample_device`` /
  ``layout_device`` when the device sampler is active),
* the metrics snapshot conforms to the registry schema and carries the
  counters/histograms the CI gates read (executor traces, latency
  histograms).

``--ci`` turns validation problems into a failing exit code — the CI step
that keeps the telemetry layer honest (a silently-empty trace or metrics
export is a regression even when serving itself still works).

    PYTHONPATH=src python -m benchmarks.obs_smoke --ci
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
from typing import List

from benchmarks.common import csv_row

SERVE_CONFIG = dict(
    model="rgat", dataset="aifb", scale=0.05, layers=2, dim=8, hidden=8,
    classes=4, fanouts=[3, 3], batch_size=8, num_batches=4, tile=8,
    node_block=8, seed=0,
)
TRAIN_CONFIG = dict(
    model="rgat", dataset="synthetic", scale=0.05, layers=2, dim=8,
    hidden=8, classes=4, fanouts=[3, 3], batch_size=16, epochs=1, tile=8,
    node_block=8, eval_every_epochs=0, seed=0,
)
ONLINE_CONFIG = dict(
    model="rgat", dataset="aifb", scale=0.05, layers=2, dim=8, hidden=8,
    classes=4, fanouts=[3, 3], tile=8, node_block=8, seed=0,
    max_batch=8, rate_rps=300.0, num_requests=12, size_choices=(1, 2, 4),
    slo_ms=5000.0,
)
SERVE_PHASES = ("sample", "layout", "execute")
TRAIN_PHASES = ("sample", "layout", "train_step")
# with --sampler device the host sample/layout phases are replaced by the
# jit pipeline's spans — require those instead
DEVICE_SERVE_PHASES = ("sample_device", "layout_device", "execute")
# the async online runtime adds its own worker-thread spans on top of the
# loader/executor phases: one "coalesce" per admission decision, one
# "execute_async" per executed batch
ONLINE_PHASES = ("coalesce", "execute_async", "sample", "layout", "execute")


def _quiet(*_a, **_k):
    pass


def _validate(kind: str, trace_path: str, metrics_path: str,
              phases) -> List[str]:
    from repro.obs import schema

    problems: List[str] = []
    try:
        trace = json.load(open(trace_path))
    except Exception as e:  # noqa: BLE001 - any unreadable artifact fails
        return [f"{kind}: unreadable trace {trace_path}: {e!r}"]
    try:
        metrics = json.load(open(metrics_path))
    except Exception as e:  # noqa: BLE001
        return [f"{kind}: unreadable metrics {metrics_path}: {e!r}"]
    problems += [f"{kind} trace: {p}" for p in schema.validate_trace(trace)]
    problems += [f"{kind} trace: {p}"
                 for p in schema.require_phases(trace, phases)]
    problems += [f"{kind} metrics: {p}"
                 for p in schema.validate_metrics(metrics)]
    return problems


def run(out=print, workdir=None):
    """Serve + train with tracing, validate the artifacts; returns
    ``(problems, serve_stats, train_stats)``."""
    from repro.launch.serve_rgnn import serve, serve_online
    from repro.launch.train_rgnn import train
    from repro.obs.registry import (snapshot_counter_total,
                                    snapshot_histogram,
                                    snapshot_histograms)

    workdir = workdir or tempfile.mkdtemp(prefix="repro-obs-smoke-")
    p = {k: os.path.join(workdir, f"{k}.json")
         for k in ("serve_trace", "serve_metrics",
                   "train_trace", "train_metrics",
                   "dserve_trace", "dserve_metrics",
                   "online_trace", "online_metrics")}

    s_stats = serve(trace_out=p["serve_trace"],
                    metrics_out=p["serve_metrics"], log=_quiet,
                    **SERVE_CONFIG)
    t_stats = train(trace_out=p["train_trace"],
                    metrics_out=p["train_metrics"], log=_quiet,
                    **TRAIN_CONFIG)
    d_stats = serve(trace_out=p["dserve_trace"],
                    metrics_out=p["dserve_metrics"], log=_quiet,
                    sampler="device", **SERVE_CONFIG)
    o_stats = serve_online(trace_out=p["online_trace"],
                           metrics_out=p["online_metrics"], log=_quiet,
                           **ONLINE_CONFIG)

    problems = _validate("serve", p["serve_trace"], p["serve_metrics"],
                         SERVE_PHASES)
    problems += _validate("train", p["train_trace"], p["train_metrics"],
                          TRAIN_PHASES)
    problems += _validate("serve[device]", p["dserve_trace"],
                          p["dserve_metrics"], DEVICE_SERVE_PHASES)
    if d_stats["host_builds"] != 0:
        problems.append(
            f"serve[device]: {d_stats['host_builds']} batches fell back to "
            f"the host sampling pipeline")
    problems += _validate("serve[online]", p["online_trace"],
                          p["online_metrics"], ONLINE_PHASES)
    # tenant-labeled request telemetry: the per-request latency histogram
    # is keyed model=<tenant>, and the multi-tenant snapshot reader must
    # enumerate it (count = every completed request)
    tenants = snapshot_histograms(o_stats["metrics"], "serve_request_ms")
    done = sum(n for s, n in o_stats["by_status"].items()
               if s in ("ok", "late"))
    if not tenants:
        problems.append(
            "serve[online] metrics: no serve_request_ms histogram (tenant-"
            "labeled request latency missing)")
    elif {t["labels"].get("model") for t in tenants} != {
            ONLINE_CONFIG["model"]}:
        problems.append(
            f"serve[online] metrics: serve_request_ms labels "
            f"{[t['labels'] for t in tenants]} missing the tenant label")
    elif sum(t["summary"]["count"] for t in tenants) != done:
        problems.append(
            f"serve[online] metrics: serve_request_ms recorded "
            f"{sum(t['summary']['count'] for t in tenants)} of {done} "
            f"completed requests")
    for counter in ("serve_requests", "serve_batches"):
        if snapshot_counter_total(o_stats["metrics"], counter) <= 0:
            problems.append(
                f"serve[online] metrics: {counter} counter empty")

    # the counters/histograms the CI gates and drivers report from must
    # actually be populated, not merely schema-valid
    if snapshot_counter_total(s_stats["metrics"], "executor_traces") <= 0:
        problems.append("serve metrics: executor_traces counter empty")
    sb = snapshot_histogram(s_stats["metrics"], "serve_batch_ms")
    if not sb or sb["count"] != s_stats["batches"]:
        problems.append(
            f"serve metrics: serve_batch_ms recorded "
            f"{sb['count'] if sb else 0} of {s_stats['batches']} batches")
    tb = snapshot_histogram(t_stats["metrics"], "train_step_ms")
    if not tb or tb["count"] != t_stats["steps"]:
        problems.append(
            f"train metrics: train_step_ms recorded "
            f"{tb['count'] if tb else 0} of {t_stats['steps']} steps")

    out(csv_row("obs_smoke/serve", s_stats["latency_ms_p50"] / 1e3,
                f"p99_ms={s_stats['latency_ms_p99']:.1f};"
                f"phases={len(SERVE_PHASES)};problems={len(problems)}"))
    out(csv_row("obs_smoke/train", t_stats["step_ms_p50"] / 1e3,
                f"p99_ms={t_stats['step_ms_p99']:.1f};"
                f"phases={len(TRAIN_PHASES)};problems={len(problems)}"))
    out(csv_row("obs_smoke/serve_device", d_stats["latency_ms_p50"] / 1e3,
                f"p99_ms={d_stats['latency_ms_p99']:.1f};"
                f"phases={len(DEVICE_SERVE_PHASES)};"
                f"host_builds={d_stats['host_builds']};"
                f"problems={len(problems)}"))
    out(csv_row("obs_smoke/serve_online", o_stats["latency_ms_p50"] / 1e3,
                f"p99_ms={o_stats['latency_ms_p99']:.1f};"
                f"phases={len(ONLINE_PHASES)};"
                f"slo_attainment={o_stats['slo_attainment']:.2f};"
                f"problems={len(problems)}"))
    return problems, s_stats, t_stats


def ci_check(workdir=None) -> None:
    """Exit 1 if any telemetry artifact is invalid or any phase span is
    missing/zero."""
    problems, s_stats, _ = run(out=lambda *_: None, workdir=workdir)
    if problems:
        for pb in problems:
            print(f"[obs_smoke --ci] FAIL: {pb}", file=sys.stderr)
        raise SystemExit(1)
    print(f"[obs_smoke --ci] OK: serve phases {list(SERVE_PHASES)} + train "
          f"phases {list(TRAIN_PHASES)} + device-sampler phases "
          f"{list(DEVICE_SERVE_PHASES)} + online-runtime phases "
          f"{list(ONLINE_PHASES)} all present and nonzero; trace and "
          f"metrics JSON schema-valid; p50 {s_stats['latency_ms_p50']:.1f} "
          f"ms / p99 {s_stats['latency_ms_p99']:.1f} ms over "
          f"{s_stats['batches']} served batches")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="fail (exit 1) on any schema/phase problem")
    ap.add_argument("--workdir", default=None,
                    help="directory for the exported artifacts "
                         "(default: fresh temp dir)")
    args = ap.parse_args(argv)
    if args.ci:
        ci_check(workdir=args.workdir)
    else:
        print("name,us_per_call,derived")
        problems, _, _ = run(workdir=args.workdir)
        for pb in problems:
            print(f"[obs_smoke] problem: {pb}", file=sys.stderr)


if __name__ == "__main__":
    main()
