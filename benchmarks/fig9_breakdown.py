"""Paper Fig. 3/9: execution-time breakdown by operator category
(GEMM-template vs traversal-template vs weight products) for the generated
plans — the profiling view that motivated lowering-to-GEMM."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, csv_row
from repro.core import codegen
from repro.core.ir import intra_op as O
from repro.core.ir.passes import lower_program
from repro.models import hgt_program, rgat_program


def profile_plan(plan, params, gt, feats, kl, iters=5):
    """Execute the plan op-by-op with per-category timing."""
    from repro.core.codegen import _Env, _exec_gemm, _exec_traversal

    def once():
        env = _Env(plan, gt, params, feats)
        derived = {}
        cat_t = {"gemm": 0.0, "traversal": 0.0, "wprod": 0.0}

        def weight(name):
            return derived.get(name, env.params.get(name))

        for op in plan.ops:
            t0 = time.perf_counter()
            if isinstance(op, O.WeightProductSpec):
                wm, wv = env.params[op.w_matrix], env.params[op.w_vector]
                derived[op.out] = jax.block_until_ready(
                    jnp.einsum("rdf,rf->rd", wm, wv)[..., None])
                cat_t["wprod"] += time.perf_counter() - t0
            elif isinstance(op, O.GemmSpec):
                _exec_gemm(op, env, weight, gt, kl, "xla")
                jax.block_until_ready(env.get(op.out))
                cat_t["gemm"] += time.perf_counter() - t0
            elif isinstance(op, O.TraversalSpec):
                _exec_traversal(op, env, gt, kl, "xla")
                jax.block_until_ready(env.get(op.stmts[-1].out))
                cat_t["traversal"] += time.perf_counter() - t0
        return cat_t

    once()  # warmup/compile
    cats = [once() for _ in range(iters)]
    return {k: float(np.median([c[k] for c in cats])) for k in cats[0]}


def run(datasets=("fb15k", "mutag"), d=64, out=print):
    rows = []
    for ds in datasets:
        hg = bench_graph(ds)
        gt = hg.to_tensors()
        kl = codegen.build_kernel_layouts(hg, tile=32, node_block=32)
        x = jnp.asarray(
            np.random.default_rng(0).normal(size=(hg.num_nodes, d)),
            jnp.float32)
        for mname, prog_fn in [("rgat", rgat_program), ("hgt", hgt_program)]:
            plan = lower_program(prog_fn(d, d), reorder=True, compact=True)
            params = codegen.init_params(plan, gt, jax.random.key(0))
            cats = profile_plan(plan, params, gt, {"feature": x}, kl)
            total = sum(cats.values()) or 1e-9
            out(csv_row(
                f"fig9/{ds}/{mname}", total,
                ";".join(f"{k}={v/total*100:.0f}%" for k, v in cats.items())))
            rows.append((ds, mname, cats))
    return rows


if __name__ == "__main__":
    run()
