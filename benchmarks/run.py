# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only ...] [--json PATH]

fig8   — Hector vs vanilla baselines (Fig. 8 / Table 4)
table5 — compaction / reordering ablation (Table 5) + autotuned column
fig9   — op-category breakdown (Fig. 3 / Fig. 9)
fig10  — memory footprint & compaction ratio (Fig. 10)
fig11  — hidden-dim sweep (Fig. 11)
loc    — LoC report (§4.1)
serve  — sampled mini-batch serving vs full-graph inference
serve_cached — cache-hit-rate + per-batch latency of the cached serving path
train_sampled — neighbor-sampled training step latency / epoch throughput
tune_smoke — autotuner cold/warm persistent-cache invariants
obs_smoke — telemetry artifacts (trace + metrics JSON) schema validation
sample_native — device-native sampling steady-state gate (zero host builds)
dist_smoke — multi-shard serve/train retrace gate + dp=4 bitwise parity
feature_cache — tiered feature storage: per-tier gather latency + hot-row cache hit rate
serve_open_loop — online serving: open-loop traffic through the async runtime (SLO / tail latency)

``--json PATH`` (e.g. ``--json BENCH_table5.json``) additionally writes the
rows machine-readably — ``{"name", "us_per_call", "derived": {k: v}}`` —
plus the run's aggregate metrics-registry snapshot (every benchmark runs
inside one ``obs.scope``, so nested driver scopes fold their counters and
latency histograms upward), so the perf trajectory is trackable across PRs
without re-parsing CSV.
"""
import argparse
import json
import sys


def parse_csv_row(line: str):
    """``name,us,k=v;k=v`` -> row dict (None if the line is not a row)."""
    parts = line.strip().split(",", 2)
    if len(parts) < 2:
        return None
    name, us = parts[0], parts[1]
    try:
        us_val = float(us)
    except ValueError:
        return None
    derived = {}
    if len(parts) == 3 and parts[2]:
        for item in parts[2].split(";"):
            if "=" in item:
                k, v = item.split("=", 1)
                derived[k] = v
    return {"name": name, "us_per_call": us_val, "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig8,table5,fig9,fig10,fig11,loc,"
                         "serve,serve_cached,train_sampled,tune_smoke,"
                         "obs_smoke,sample_native,dist_smoke,feature_cache,"
                         "serve_open_loop")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (e.g. BENCH_all.json)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (dist_smoke, feature_cache, fig8_speedup,
                            fig9_breakdown, fig10_memory, fig11_dims,
                            loc_report, obs_smoke, sample_native,
                            serve_cached, serve_open_loop, serve_sampled,
                            table5_opts, train_sampled, tune_smoke)
    from repro import obs

    rows = []

    def emit(line) -> None:
        print(line)
        row = parse_csv_row(str(line))
        if row is not None:
            rows.append(row)

    print("name,us_per_call,derived")
    jobs = [
        ("fig10", fig10_memory.run),   # cheap first
        ("loc", loc_report.run),
        ("fig11", fig11_dims.run),
        ("table5", table5_opts.run),
        ("fig9", fig9_breakdown.run),
        ("fig8", fig8_speedup.run),
        ("serve", serve_sampled.run),
        ("serve_cached", serve_cached.run),
        ("train_sampled", train_sampled.run),
        ("tune_smoke", tune_smoke.run),
        ("obs_smoke", obs_smoke.run),
        ("sample_native", sample_native.run),
        ("dist_smoke", dist_smoke.run),
        ("feature_cache", feature_cache.run),
        ("serve_open_loop", serve_open_loop.run),
    ]
    # one enclosing scope: every driver/benchmark scope folds its counters
    # and histograms into this registry on exit, so the JSON snapshot is
    # the union of the whole run's telemetry
    with obs.scope(metrics=True) as sc:
        for name, fn in jobs:
            if only and name not in only:
                continue
            try:
                fn(out=emit)
            except Exception as e:  # noqa: BLE001
                print(f"{name},ERROR,{e!r}", file=sys.stderr)
                raise
        metrics_snapshot = sc.registry.snapshot()

    if args.json:
        import jax
        payload = {
            "schema_version": 1,
            "backend": jax.default_backend(),
            "rows": rows,
            "metrics": metrics_snapshot,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[run] wrote {len(rows)} rows to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
