# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

fig8   — Hector vs vanilla baselines (Fig. 8 / Table 4)
table5 — compaction / reordering ablation (Table 5)
fig9   — op-category breakdown (Fig. 3 / Fig. 9)
fig10  — memory footprint & compaction ratio (Fig. 10)
fig11  — hidden-dim sweep (Fig. 11)
loc    — LoC report (§4.1)
serve  — sampled mini-batch serving vs full-graph inference
serve_cached — cache-hit-rate + per-batch latency of the cached serving path
train_sampled — neighbor-sampled training step latency / epoch throughput
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig8,table5,fig9,fig10,fig11,loc,"
                         "serve,serve_cached,train_sampled")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig8_speedup, fig9_breakdown, fig10_memory,
                            fig11_dims, loc_report, serve_cached,
                            serve_sampled, table5_opts, train_sampled)

    print("name,us_per_call,derived")
    jobs = [
        ("fig10", fig10_memory.run),   # cheap first
        ("loc", loc_report.run),
        ("fig11", fig11_dims.run),
        ("table5", table5_opts.run),
        ("fig9", fig9_breakdown.run),
        ("fig8", fig8_speedup.run),
        ("serve", serve_sampled.run),
        ("serve_cached", serve_cached.run),
        ("train_sampled", train_sampled.run),
    ]
    for name, fn in jobs:
        if only and name not in only:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{e!r}", file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
