"""Tiered feature storage microbenchmark + CI gate (ISSUE 9).

Runs identical Zipf-skewed serving traffic through the three feature
tiers (device / host / cached) and reports per-gather latency, bytes
moved, and the cached tier's steady-state hit rate. The cached tier is
the interesting row: with power-law traffic a small device hot-row cache
absorbs most of the feature reads, so its per-gather cost and host
traffic land well below the host tier's.

The tiers are timed *interleaved* — each steady-state batch goes through
every tier back-to-back and the reported number is the per-tier median —
so machine-level drift (GC pauses, scheduler noise) hits all tiers
equally instead of biasing whichever ran last. The scale is chosen so
data movement, not Python/dispatch overhead, is the dominant cost: that
is the regime the cache exists for (a wide feature table whose full
batch gather is expensive to ship).

``--ci`` asserts the contract the cache exists for:

* steady-state hit rate >= 60% under Zipf-skewed traffic with a
  quarter-table budget;
* zero (re)traces of the gather programs after warmup (fixed batch size
  + pow2 miss bucketing => a fixed compiled program set);
* a fully-hot batch performs **zero** host feature gathers and moves
  zero bytes;
* device feature memory stays strictly below the full-table footprint
  under a forced small budget (the OOM-avoidance property);
* the cached tier's host traffic (bytes moved) is strictly below the
  host tier's on the same stream, and its median per-gather latency is
  no worse than the host tier's (speedup >= 1.0 on CPU);
* all three tiers return bitwise-identical feature rows.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core.graph import synthetic_heterograph
from repro.feats import make_feature_store
from repro.sampling import SeedStream

CONFIG = dict(num_nodes=16000, num_edges=64000, num_ntypes=4, num_etypes=8,
              seed=0, target_compaction=0.5)
DIM = 1024
BATCH = 1024
WARMUP = 8
STEADY = 40
ALPHA = 1.5
TIERS = ("device", "host", "cached")


def _build():
    graph = synthetic_heterograph(**CONFIG)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(graph.num_nodes, DIM)).astype(np.float32)
    stream = SeedStream(graph.num_nodes, BATCH, seed=7, zipf_alpha=ALPHA)
    batches = [stream.batch(t) for t in range(WARMUP + STEADY)]
    return graph, feats, batches


def _measure(graph, feats, batches):
    """Warm then time all tiers interleaved; returns per-tier
    ``(store, median_seconds, checksum, steady)`` keyed by kind."""
    stores = {
        kind: make_feature_store(
            feats, graph, kind=kind,
            budget=graph.num_nodes // 4 if kind == "cached" else None)
        for kind in TIERS}
    for step, ids in enumerate(batches[:WARMUP]):
        for store in stores.values():
            store.gather(ids, step=step)
    cached = stores["cached"]
    warm_traces, warm_hits, warm_misses = (
        cached.trace_count, cached.hits, cached.misses)

    times = {kind: [] for kind in TIERS}
    sums = {kind: 0.0 for kind in TIERS}
    for step, ids in enumerate(batches[WARMUP:], start=WARMUP):
        for kind, store in stores.items():
            t0 = time.perf_counter()
            out = store.gather(ids, step=step)["feature"]
            out.block_until_ready()
            times[kind].append(time.perf_counter() - t0)
            sums[kind] += float(np.asarray(out).sum())

    sh = cached.hits - warm_hits
    sm = cached.misses - warm_misses
    steady = {"hit_rate": sh / max(sh + sm, 1),
              "retraces": cached.trace_count - warm_traces}
    return {kind: (stores[kind], float(np.median(times[kind])), sums[kind],
                   steady if kind == "cached" else {})
            for kind in TIERS}


def run(out=print):
    results = _measure(*_build())
    for kind in TIERS:
        store, per_gather, _, steady = results[kind]
        derived = (f"bytes_moved={store.bytes_moved};"
                   f"device_bytes={store.device_bytes()}")
        if steady:
            derived += (f";hit_rate={steady['hit_rate']:.2f};"
                        f"retraces_after_warmup={steady['retraces']}")
        out(csv_row(f"feature_cache/{kind}_gather", per_gather, derived))
    return results


def ci_check() -> None:
    """Assertion mode for the CI workflow (exit 1 on failure)."""
    graph, feats, batches = _build()
    results = _measure(graph, feats, batches)
    dev_store, _, dev_sum, _ = results["device"]
    host_store, host_t, host_sum, _ = results["host"]
    cached, cached_t, cached_sum, steady = results["cached"]

    failures = []
    if steady["hit_rate"] < 0.6:
        failures.append(f"steady-state hit rate {steady['hit_rate']:.2f} "
                        f"< 0.60 under Zipf({ALPHA}) traffic")
    if steady["retraces"] != 0:
        failures.append(f"{steady['retraces']} gather-program retraces "
                        f"after warmup (expected 0)")
    if not (dev_sum == host_sum == cached_sum):
        failures.append(f"tier checksums diverge: device={dev_sum!r} "
                        f"host={host_sum!r} cached={cached_sum!r}")
    if cached.bytes_moved >= host_store.bytes_moved:
        failures.append(f"cached tier moved {cached.bytes_moved} host bytes "
                        f">= host tier's {host_store.bytes_moved}")
    speedup = host_t / max(cached_t, 1e-12)
    if speedup < 1.0:
        failures.append(f"cached gather slower than host gather "
                        f"({cached_t*1e6:.0f}us vs {host_t*1e6:.0f}us, "
                        f"speedup {speedup:.2f}x < 1.0x)")

    # fully-hot batches do zero host feature work
    hot = make_feature_store(feats, graph, kind="cached",
                             budget=graph.num_nodes)
    ids = batches[0]
    hot.gather(ids, step=0)
    g0, b0 = hot.host_gathers, hot.bytes_moved
    np.testing.assert_array_equal(
        np.asarray(hot.gather(ids, step=1)["feature"]), feats[ids])
    if hot.host_gathers != g0 or hot.bytes_moved != b0:
        failures.append("a fully-hot batch touched the host tables "
                        f"({hot.host_gathers - g0} gathers, "
                        f"{hot.bytes_moved - b0} bytes)")

    # OOM avoidance: a forced small budget bounds device feature memory
    # strictly below the full-table footprint
    tiny = make_feature_store(feats, graph, kind="cached", budget=64)
    for step, ids in enumerate(batches[:4]):
        np.testing.assert_array_equal(
            np.asarray(tiny.gather(ids, step=step)["feature"]), feats[ids])
    if not tiny.device_bytes() < tiny.table_bytes:
        failures.append(f"tiny-budget device bytes {tiny.device_bytes()} "
                        f"not below table bytes {tiny.table_bytes}")
    if tiny.overflows == 0:
        failures.append("forced tiny budget produced no overflows "
                        "(gate is not exercising the overflow path)")

    if failures:
        for f in failures:
            print(f"[feature_cache --ci] FAIL: {f}", file=sys.stderr)
        raise SystemExit(1)
    print(f"[feature_cache --ci] OK: hit rate {steady['hit_rate']:.2f}, "
          f"0 retraces after warmup, cached/host speedup {speedup:.2f}x, "
          f"cached moved {cached.bytes_moved / 1e6:.2f} MB vs host "
          f"{host_store.bytes_moved / 1e6:.2f} MB, tiny-budget device "
          f"bytes {tiny.device_bytes()} < table {tiny.table_bytes}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="assertion mode (hit-rate / retrace / memory gate)")
    args = ap.parse_args(argv)
    if args.ci:
        ci_check()
    else:
        print("name,us_per_call,derived")
        run()


if __name__ == "__main__":
    main()
