"""Sampled mini-batch serving vs full-graph inference.

Not a paper figure — this captures the serving trajectory the ROADMAP asks
for: per-request latency of fanout-sampled mini-batch inference (the
production shape) against a full-graph forward (the paper's artifact shape),
on scaled Table-3 graphs. Sampled timings are steady-state (bucketed shapes,
measured after warmup batches).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, csv_row, time_fn
from repro import obs
from repro.core.module import HectorStack
from repro.models import rgat_program
from repro.sampling import FanoutSampler, MiniBatchLoader, SeedStream

DATASETS = ["aifb", "mutag"]


def _sampled_latency(stack, params, feats, graph, fanouts, batch_size,
                     bench, warmup=6, iters=8, tile=32, node_block=32):
    sampler = FanoutSampler(graph, fanouts, seed=0)
    loader = MiniBatchLoader(
        sampler, SeedStream(graph.num_nodes, batch_size, seed=0),
        tile=tile, node_block=node_block, bucket=True,
        num_batches=warmup + iters,
    )
    # per-batch latency lands in a registry histogram (labeled per bench
    # point) so the caller reports p50/p99, not just a central tendency
    h = obs.metrics().histogram("serve_batch_ms", bench=bench)
    times = []
    try:
        for i, mb in enumerate(loader):
            t0 = time.perf_counter()
            out = stack.apply_blocks(params, mb, feats, compiled=True)
            out.block_until_ready()
            if i >= warmup:
                dt = time.perf_counter() - t0
                times.append(dt)
                h.observe(dt * 1e3)
    finally:
        loader.close()
    return float(np.median(times))


def run(datasets=None, d=64, batch_size=64, out=print):
    datasets = datasets or DATASETS
    with obs.scope(metrics=True):
        for ds in datasets:
            hg = bench_graph(ds)
            rng = np.random.default_rng(0)
            feats = jnp.asarray(rng.normal(size=(hg.num_nodes, d)),
                                jnp.float32)
            stack = HectorStack([rgat_program(d, d), rgat_program(d, 16)],
                                hg, tile=32, node_block=32, jit=False)
            params = stack.init(jax.random.key(0))

            t_full = time_fn(lambda: stack.apply(params, {"feature": feats}))
            out(csv_row(f"serve/{ds}/full_graph", t_full,
                        f"nodes={hg.num_nodes}"))

            for fanout in (5, 10):
                bench = f"{ds}_f{fanout}_b{batch_size}"
                t_s = _sampled_latency(stack, params, feats, hg,
                                       [fanout, fanout], batch_size,
                                       bench=bench)
                hs = obs.metrics().histogram_summary("serve_batch_ms",
                                                     bench=bench)
                out(csv_row(
                    f"serve/{ds}/sampled_f{fanout}_b{batch_size}", t_s,
                    f"seeds_per_s={batch_size / max(t_s, 1e-9):.0f};"
                    f"p50_ms={hs['p50']:.2f};p99_ms={hs['p99']:.2f}"))


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
