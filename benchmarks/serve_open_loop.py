"""Open-loop online serving benchmark: the async request pipeline under
seeded Poisson traffic.

Replays a deterministic open-loop request schedule (arrivals independent
of completions — the regime where tail latency is meaningful) through the
``ServingRuntime``: deadline-aware coalescing over the measured batch-size
ladder, sampling / feature-gather / compiled execute overlapped across
worker threads. Reports per-request p50/p99 latency, SLO attainment,
queue depth, batch fill, and the zero-retrace counters.

``--ci`` asserts the serving contracts on a small configuration:

* zero executor retraces after calibration (the shape floors + ladder
  warmup pinned the compiled set before traffic started);
* SLO attainment >= 0.95 and request p99 within the per-request budget;
* the *fine* ladder (2^k and 3*2^k rungs) costs no more than pow2-only
  coalescing would, priced against the load's request-size distribution
  with the one calibration-measured latency table (finer rungs must pay
  for their extra compiled shapes in reduced pad waste, or validation
  should have dropped them).
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import csv_row
from repro.launch.serve_rgnn import serve_online
from repro.serve import OpenLoopLoad

# one small bucketed config: 2-hop rgat over the reduced AIFB graph
CONFIG = dict(
    model="rgat", dataset="aifb", scale=0.05, layers=2, dim=8, hidden=8,
    classes=4, fanouts=[3, 3], tile=8, node_block=8, seed=0,
    max_batch=8, max_wait_ms=3.0,
)
LOAD = dict(rate_rps=200.0, num_requests=32, process="poisson",
            size_choices=(1, 2, 4), slo_ms=2000.0)


def _covering(size: int, rungs) -> int:
    return min(r for r in rungs if r >= size)


def ladder_cost_ms(sizes, rungs, measured_ms) -> float:
    """Expected per-schedule execute cost (ms) serving each request at its
    covering rung — the pad-waste price of a rung set, under the one
    measured latency table."""
    return sum(measured_ms[_covering(s, rungs)] for s in sizes)


def run(out=print, backend: str = "xla"):
    stats = serve_online(backend=backend, ladder_kind="fine",
                         log=lambda *a, **k: None, **CONFIG, **LOAD)
    out(csv_row(
        "serve_open_loop/request_p50", stats["latency_ms_p50"] / 1e3,
        f"rate_rps={LOAD['rate_rps']:g};requests={stats['requests']}"))
    out(csv_row(
        "serve_open_loop/request_p99", stats["latency_ms_p99"] / 1e3,
        f"slo_attainment={stats['slo_attainment']:.3f};"
        f"deadline_misses={stats['deadline_misses']};"
        f"queue_depth_max={stats['queue_depth_max']}"))
    out(csv_row(
        "serve_open_loop/batch_execute", stats["execute_ms_mean"] / 1e3,
        f"batches={stats['batches']};"
        f"batch_fill={stats['batch_fill']:.2f};"
        f"ladder={'/'.join(map(str, stats['ladder']))};"
        f"retraces_after_warmup={stats['retraces_after_warmup']}"))
    return stats


def ci_check(backend: str = "xla") -> None:
    """Online-serving regression gate (exit 1 on failure)."""
    stats = run(out=lambda *_: None, backend=backend)
    failures = []

    if stats["retraces_after_warmup"] != 0:
        failures.append(
            f"executor retraced {stats['retraces_after_warmup']}x during "
            f"traffic (expected 0: calibration must pin the shape set)")
    if stats["slo_attainment"] < 0.95:
        failures.append(
            f"SLO attainment {stats['slo_attainment']:.3f} < 0.95")
    if stats["latency_ms_p99"] > LOAD["slo_ms"]:
        failures.append(
            f"request p99 {stats['latency_ms_p99']:.1f} ms exceeds the "
            f"{LOAD['slo_ms']:g} ms budget")
    if stats["requests"] != LOAD["num_requests"]:
        failures.append(
            f"{stats['requests']} terminal responses for "
            f"{LOAD['num_requests']} submitted requests (drain leaked)")

    # fine-vs-pow2 ladder economics, priced with the single calibration
    # table over the load's actual request-size mix
    measured = stats["ladder_ms"]
    sizes = [r.num_seeds for r in OpenLoopLoad(
        1000, seed=CONFIG["seed"], **LOAD).requests()]
    pow2 = [r for r in measured if r & (r - 1) == 0]
    fine_cost = ladder_cost_ms(sizes, stats["ladder"], measured)
    pow2_cost = ladder_cost_ms(sizes, pow2, measured)
    if fine_cost > pow2_cost * 1.001:
        failures.append(
            f"validated fine ladder {stats['ladder']} costs "
            f"{fine_cost:.2f} ms over the schedule vs {pow2_cost:.2f} ms "
            f"pow2-only (validation kept a rung that does not pay)")

    if failures:
        for f in failures:
            print(f"[serve_open_loop --ci] FAIL: {f}", file=sys.stderr)
        raise SystemExit(1)
    print(f"[serve_open_loop --ci] OK: {stats['requests']} requests, "
          f"p50 {stats['latency_ms_p50']:.1f} / "
          f"p99 {stats['latency_ms_p99']:.1f} ms, "
          f"SLO attainment {stats['slo_attainment']:.2f}, "
          f"0 retraces after warmup; fine ladder {stats['ladder']} "
          f"{fine_cost:.2f} ms <= pow2 {pow2_cost:.2f} ms over the "
          f"schedule")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="assertion mode: SLO/retrace/ladder gates")
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"])
    args = ap.parse_args(argv)
    if args.ci:
        ci_check(backend=args.backend)
    else:
        print("name,us_per_call,derived")
        run(backend=args.backend)


if __name__ == "__main__":
    main()
