"""Paper Fig. 8 / Table 4: Hector (best-optimized) vs prior-art baselines.

Baselines reproduce the systems' characteristic implementations:
  * ``replicated``  — PyG FastRGCNConv pattern: [E, d, d] weight replication
  * ``type_loop``   — DGL HeteroConv pattern: one GEMM per relation, masked

Measured on CPU wall-clock over scaled Table-3 graphs (same numerics per
earlier allclose tests) for inference and training (fwd+bwd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DEFAULT_DATASETS, bench_graph, csv_row, time_fn
from repro.core.module import HectorModule
from repro.models import baselines, hgt_program, rgat_program, rgcn_program

MODELS = {
    "rgcn": (rgcn_program, baselines.rgcn_vanilla),
    "rgat": (rgat_program, baselines.rgat_vanilla),
    "hgt": (hgt_program, baselines.hgt_vanilla),
}


def run(datasets=None, d=64, train=True, out=print):
    datasets = datasets or DEFAULT_DATASETS
    rows = []
    for ds in datasets:
        hg = bench_graph(ds)
        gt = hg.to_tensors()
        x = jnp.asarray(
            np.random.default_rng(0).normal(size=(hg.num_nodes, d)),
            jnp.float32)
        for mname, (prog_fn, vanilla) in MODELS.items():
            prog = prog_fn(d, d)
            mod = HectorModule(prog, hg, reorder=True, compact=True,
                               backend="xla", tile=32, node_block=32)
            params = mod.init(jax.random.key(0))

            hector_inf = lambda p, xx: mod.apply(p, {"feature": xx})["h_out"]
            van_rep = jax.jit(functools.partial(vanilla, gt=gt))
            van_loop = jax.jit(functools.partial(vanilla, gt=gt,
                                                 per_type_loop=True))

            t_h = time_fn(hector_inf, params, x)
            t_r = time_fn(lambda p, xx: van_rep(p, feats={"feature": xx})["h_out"],
                          params, x)
            t_l = time_fn(lambda p, xx: van_loop(p, feats={"feature": xx})["h_out"],
                          params, x)
            out(csv_row(f"fig8/{ds}/{mname}/infer/hector", t_h,
                        f"speedup_vs_replicated={t_r/t_h:.2f};"
                        f"speedup_vs_typeloop={t_l/t_h:.2f}"))
            rows.append((ds, mname, "infer", t_h, t_r, t_l))

            if train:
                def mk_loss(f):
                    def loss(p, xx):
                        return jnp.sum(f(p, xx) ** 2)
                    return jax.jit(jax.grad(loss))
                g_h = mk_loss(hector_inf)
                g_r = mk_loss(lambda p, xx: van_rep(p, feats={"feature": xx})["h_out"])
                t_h = time_fn(g_h, params, x)
                t_r = time_fn(g_r, params, x)
                out(csv_row(f"fig8/{ds}/{mname}/train/hector", t_h,
                            f"speedup_vs_replicated={t_r/t_h:.2f}"))
                rows.append((ds, mname, "train", t_h, t_r, None))
    return rows


if __name__ == "__main__":
    run()
