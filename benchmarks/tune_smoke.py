"""Autotuner persistent-cache smoke: tune once cold, rerun warm.

Runs the serving driver twice with ``--tune full`` against the same
persistent cache file:

* the cold run must actually measure (the tuner's whole point), and
* the warm run must replay **every** decision from the cache — zero
  on-device measurements — and serve its steady state with zero retraces
  after warmup (the tuned decision table is part of the executor
  compile-cache key, so replayed decisions hit warm executables).

``--ci`` turns the invariants into hard assertions (a CI step, like
``serve_cached --ci``).

    PYTHONPATH=src python -m benchmarks.tune_smoke --ci
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

from benchmarks.common import csv_row


def _quiet(*a, **k):
    pass


def run(out=print, ci: bool = False, dataset: str = "aifb",
        tune_cache=None):
    from repro.core.graph import CPU_REDUCED_SCALES
    from repro.launch.serve_rgnn import serve

    if tune_cache is None:
        tmpdir = tempfile.mkdtemp(prefix="repro-tune-smoke-")
        tune_cache = os.path.join(tmpdir, "tune.json")

    kwargs = dict(
        model="rgat", dataset=dataset, scale=CPU_REDUCED_SCALES[dataset],
        layers=2, dim=32, hidden=32, classes=8, batch_size=16,
        num_batches=6, repeat_after=2, cache_blocks=8, cache_layouts=32,
        tune="full", tune_cache=tune_cache, log=_quiet,
    )

    t0 = time.perf_counter()
    cold = serve(**kwargs)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = serve(**kwargs)
    t_warm = time.perf_counter() - t0

    out(csv_row("tune_smoke/cold", t_cold,
                f"measurements={cold['tune_measurements']}"
                f";tuned_ops={cold['tune_tuned_ops']}"
                f";retraces={cold['retraces_after_warmup']}"))
    out(csv_row("tune_smoke/warm", t_warm,
                f"measurements={warm['tune_measurements']}"
                f";cache_replays={warm['tune_cache_hits']}"
                f";retraces={warm['retraces_after_warmup']}"))

    if ci:
        # the gates read the runs' metrics-registry snapshots — the obs
        # layer is the telemetry surface, not the tuner's stats dict
        from repro.obs.registry import snapshot_counter_total as total

        cold_meas = total(cold["metrics"], "tune_measurements")
        warm_meas = total(warm["metrics"], "tune_measurements")
        warm_replays = total(warm["metrics"], "tune_cache_hits")
        assert cold_meas > 0, \
            f"cold tuning measured nothing: {cold}"
        assert warm_meas == 0, \
            f"warm run re-measured despite persistent cache: {warm_meas}"
        assert warm_replays >= cold["tune_tuned_ops"], \
            (warm_replays, cold["tune_tuned_ops"])
        assert warm["retraces_after_warmup"] == 0, \
            f"tuned serving retraced after warmup: " \
            f"{warm['retraces_after_warmup']}"
        print("[tune_smoke] CI assertions passed: cold run measured "
              f"{cold_meas}x, warm run replayed "
              f"{warm_replays} decisions with 0 measurements "
              "and 0 retraces after warmup")
    return cold, warm


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="assert the cold/warm invariants")
    ap.add_argument("--dataset", default="aifb")
    ap.add_argument("--tune-cache", default=None,
                    help="cache path (default: fresh temp file)")
    args = ap.parse_args(argv)
    run(ci=args.ci, dataset=args.dataset, tune_cache=args.tune_cache)


if __name__ == "__main__":
    main()
