"""Paper Table 5: speedup of compact materialization (C), linear-operator
reordering (R) and C+R over unoptimized Hector code, for RGAT and HGT."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DEFAULT_DATASETS, bench_graph, csv_row, time_fn
from repro.core.module import HectorModule
from repro.models import hgt_program, rgat_program


def run(datasets=None, d=64, out=print):
    datasets = datasets or DEFAULT_DATASETS
    rows = []
    for ds in datasets:
        hg = bench_graph(ds)
        x = jnp.asarray(
            np.random.default_rng(0).normal(size=(hg.num_nodes, d)),
            jnp.float32)
        for mname, prog_fn in [("rgat", rgat_program), ("hgt", hgt_program)]:
            prog = prog_fn(d, d)
            times = {}
            params = None
            for label, reorder, compact in [
                ("U", False, False), ("R", True, False),
                ("C", False, True), ("C+R", True, True),
            ]:
                mod = HectorModule(prog, hg, reorder=reorder, compact=compact,
                                   backend="xla", tile=32, node_block=32)
                if params is None:
                    params = mod.init(jax.random.key(0))
                times[label] = time_fn(
                    lambda p, xx, m=mod: m.apply(p, {"feature": xx})["h_out"],
                    params, x)
            base = times["U"]
            derived = ";".join(f"{k}={base/v:.2f}x" for k, v in times.items()
                               if k != "U")
            derived += f";compaction_ratio={hg.entity_compaction_ratio:.2f}"
            out(csv_row(f"table5/{ds}/{mname}", base, derived))
            rows.append((ds, mname, times, hg.entity_compaction_ratio))
    return rows


if __name__ == "__main__":
    run()
