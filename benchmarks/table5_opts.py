"""Paper Table 5: speedup of compact materialization (C), linear-operator
reordering (R) and C+R over unoptimized Hector code, for RGAT and HGT —
plus a "T" column: the autotuned variant (measured per-op backend/tile/
fusion decisions, per-var materialization, tuned layout tile) against the
same U baseline, and its ratio to the current static default (C+R).

The tuner runs in ``full`` mode against the persistent cache, so the first
invocation measures and every later one replays with zero measurements
(``tune_measurements`` in the derived fields tracks this).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DEFAULT_DATASETS, bench_graph, csv_row, time_fn
from repro.core.module import HectorModule
from repro.models import hgt_program, rgat_program
from repro.tune.tuner import Tuner


def run(datasets=None, d=64, out=print, tune_cache=None):
    datasets = datasets or DEFAULT_DATASETS
    rows = []
    tuned_ratios = {"rgat": [], "hgt": []}
    for ds in datasets:
        hg = bench_graph(ds)
        x = jnp.asarray(
            np.random.default_rng(0).normal(size=(hg.num_nodes, d)),
            jnp.float32)
        for mname, prog_fn in [("rgat", rgat_program), ("hgt", hgt_program)]:
            prog = prog_fn(d, d)
            times = {}
            params = None
            for label, reorder, compact in [
                ("U", False, False), ("R", True, False),
                ("C", False, True), ("C+R", True, True),
            ]:
                mod = HectorModule(prog, hg, reorder=reorder,
                                   compact=compact, backend="xla")
                if params is None:
                    params = mod.init(jax.random.key(0))
                times[label] = time_fn(
                    lambda p, xx, m=mod: m.apply(p, {"feature": xx})["h_out"],
                    params, x)

            # T: the autotuned variant (decisions replayed from the
            # persistent cache after the first run)
            tuner = Tuner(mode="full", cache_path=tune_cache)
            rep = tuner.tune_stack([prog], hg, backend="xla",
                                   feat_dims=[d], seed=0)
            mod_t = HectorModule(prog, hg, reorder=True, compact=True,
                                 compact_vars=rep.compact_vars[0],
                                 backend="xla", tile=rep.tile,
                                 node_block=rep.node_block,
                                 decisions=rep.decisions)
            times["T"] = time_fn(
                lambda p, xx, m=mod_t: m.apply(p, {"feature": xx})["h_out"],
                params, x)

            base = times["U"]
            tuned_vs_default = times["C+R"] / times["T"]
            tuned_ratios[mname].append(tuned_vs_default)
            derived = ";".join(f"{k}={base/v:.2f}x" for k, v in times.items()
                               if k != "U")
            derived += (f";T_vs_default={tuned_vs_default:.2f}x"
                        f";tune_measurements={tuner.stats['measurements']}"
                        f";compaction_ratio="
                        f"{hg.entity_compaction_ratio:.2f}")
            out(csv_row(f"table5/{ds}/{mname}", base, derived))
            rows.append((ds, mname, times, hg.entity_compaction_ratio))

    # acceptance gate: tuned >= the current static default, geomean across
    # datasets, per model
    for mname, ratios in tuned_ratios.items():
        if not ratios:
            continue
        geo = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
        out(csv_row(f"table5/geomean/{mname}", 0.0,
                    f"T_vs_default_geomean={geo:.3f}x"))
    return rows


if __name__ == "__main__":
    run()
