"""Paper Fig. 10: memory footprint of compact vs vanilla materialization.

Reports, per dataset: edgewise-tensor bytes under both layouts, the
entity-compaction ratio, and the measured footprint ratio including nodewise
data + weights (matching the paper's observation that the footprint ratio
upper-bounds the compaction ratio)."""
from __future__ import annotations

from benchmarks.common import bench_graph, csv_row
from repro.core.graph import TABLE3_DATASETS


def run(datasets=None, d=64, out=print):
    datasets = datasets or list(TABLE3_DATASETS)
    rows = []
    for ds in datasets:
        hg = bench_graph(ds)
        e, u, n = hg.num_edges, hg.num_unique, hg.num_nodes
        r = hg.num_etypes
        # HGT-like layer: 2 edgewise hidden-dim tensors (katt, msg)
        edge_vanilla = 2 * e * d * 4
        edge_compact = 2 * u * d * 4
        nodewise = 3 * n * d * 4              # k, q, v
        weights = (3 * hg.num_ntypes + 2 * r) * d * d * 4
        total_vanilla = edge_vanilla + nodewise + weights
        total_compact = edge_compact + nodewise + weights
        ratio = total_compact / total_vanilla
        out(csv_row(
            f"fig10/{ds}", 0.0,
            f"entity_compaction={u/e:.3f};footprint_ratio={ratio:.3f};"
            f"edge_MB_vanilla={edge_vanilla/2**20:.1f};"
            f"edge_MB_compact={edge_compact/2**20:.1f};avg_degree={e/n:.1f}"))
        rows.append((ds, u / e, ratio))
        assert ratio >= u / e - 1e-9   # paper: footprint ratio > compaction
    return rows


if __name__ == "__main__":
    run()
