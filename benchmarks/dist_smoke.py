"""Distributed execution gate: sharded serving + data-parallel training.

Pins the contract of the data-parallel layer on forced-CPU hardware:

* **zero retraces after warmup across shards** — multi-shard serving over
  repeat traffic and the data-parallel training loop both replay their
  compiled ``shard_map`` step after the warmup window (the per-shard
  bucketing would otherwise retrace on every routing change);
* **all-reduce fused into one compiled step** — the lowered StableHLO of
  the train step contains the halo-feature all-gather and the gradient
  all-reduce collectives inside the single jitted module (no separate
  communication dispatches), and repeat steps stay on one cache entry;
* **dp=4 parity** — a subprocess with 4 forced host devices checks that
  serve logits, train loss, and the full updated optimizer state are
  bitwise identical between dp=1 (4 shards folded on one device) and dp=4
  (1 shard per device).

``--ci`` turns any violation into a failing exit code.

    PYTHONPATH=src python -m benchmarks.dist_smoke --ci
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time
from typing import List

from benchmarks.common import csv_row

# multi-shard serving over repeat traffic (dp=1: 4 logical shards folded
# onto the one real device; the shard_map program is identical at dp=4)
SERVE_CONFIG = dict(
    model="rgat", dataset="aifb", scale=0.05, layers=2, dim=8, hidden=8,
    classes=4, fanouts=[3, 3], batch_size=8, num_batches=9, tile=8,
    node_block=8, repeat_after=3, seed=0, partitions=4, obs_mode="off",
)

# data-parallel training loop: 64 seeds x batch 16 over 2 epochs; epoch 1
# is warmup (traces every shuffled bucket combination), epoch 2 must replay
TRAIN_CONFIG = dict(num_ids=64, batch_size=16, epochs=2, warmup_epochs=1)

# dp=1 vs dp=4 bitwise parity + fused-collective HLO check, run in a
# subprocess so the host platform can be split into 4 devices
_DP4_CODE = """
    import json
    import numpy as np, jax, jax.numpy as jnp
    assert jax.device_count() == 4, jax.devices()
    from repro.core.graph import synthetic_heterograph
    from repro.dist import (partition_graph, ShardedBatcher,
                            ShardedServeExecutor, ShardedTrainExecutor)
    from repro.launch.mesh import make_data_mesh
    from repro.optim import AdamW
    from repro.train import EngineConfig, RGNNEngine

    g = synthetic_heterograph(120, 900, 4, 7, seed=0)
    part = partition_graph(g, 4)
    SEEDS = np.array([3, 50, 7, 3, 119, 0, 88, 12], dtype=np.int32)
    eng = RGNNEngine(g, EngineConfig(
        model="rgat", layers=2, dim=16, hidden=12, classes=6,
        fanouts=[3, 3], tile=8, node_block=8, seed=0))
    rng = np.random.default_rng(1)
    feats = np.asarray(rng.normal(size=(g.num_nodes, 16)), np.float32)
    labels = np.asarray(rng.integers(0, 6, g.num_nodes))
    params = eng.init_params(jax.random.key(0))
    own = jnp.asarray(part.shard_features(feats))
    smb = ShardedBatcher(part, [3, 3], seed=0, tile=8,
                         node_block=8).build(SEEDS, step=0, epoch=0)
    opt = AdamW(learning_rate=1e-2, weight_decay=0.01)

    out = {}
    for dp in (1, 4):
        mesh = make_data_mesh(dp)
        logits = np.asarray(ShardedServeExecutor(eng.plans, mesh)
                            .run_minibatch(params, smb, own))
        st, m = ShardedTrainExecutor(eng.plans, opt, mesh) \\
            .grad_and_update(opt.init(params), smb, labels, own)
        leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(
            (st.params, st.mu, st.nu))]
        out[dp] = (logits, float(m["loss"]), leaves)
    parity = (bool((out[1][0] == out[4][0]).all())
              and out[1][1] == out[4][1]
              and all((a == b).all() for a, b in zip(out[1][2], out[4][2])))

    # the fused step at dp=4: the collectives must live inside the one
    # lowered module, and repeat steps must stay on one cache entry
    mesh = make_data_mesh(4)
    tr = ShardedTrainExecutor(eng.plans, opt, mesh)
    hlo = tr.lowered_hlo(opt.init(params), smb, labels, own)
    state = opt.init(params)
    for _ in range(3):
        state, _m = tr.grad_and_update(state, smb, labels, own)
    print(json.dumps({
        "parity": parity,
        "hlo_all_gathers": hlo.count("all_gather"),
        "train_compiled": tr.num_compiled,
        "train_cache_hits": tr.cache_hits,
    }))
"""


def _quiet(*_a, **_k):
    pass


def _run_dp4_subprocess() -> dict:
    """Run the parity/HLO check under 4 forced host devices; returns the
    JSON result dict printed by the child."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_DP4_CODE)],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"dp4 subprocess failed:\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _run_train() -> dict:
    """Data-parallel training loop on a synthetic partitioned graph; the
    epoch-2 steps must all replay epoch-1 traces."""
    import numpy as np
    import jax
    from repro.core.graph import synthetic_heterograph
    from repro.dist import DistTrainer
    from repro.train import EngineConfig, RGNNEngine

    g = synthetic_heterograph(120, 900, 4, 7, seed=0)
    eng = RGNNEngine(g, EngineConfig(
        model="rgat", layers=2, dim=16, hidden=12, classes=6,
        fanouts=[3, 3], tile=8, node_block=8, seed=0, partitions=4))
    rng = np.random.default_rng(1)
    feats = np.asarray(rng.normal(size=(g.num_nodes, 16)), np.float32)
    labels = np.asarray(rng.integers(0, 6, g.num_nodes))
    ids = np.arange(0, TRAIN_CONFIG["num_ids"], dtype=np.int32)
    tr = DistTrainer(eng, feats, labels, ids, log=None)
    state = tr.init_state(eng.init_params(jax.random.key(0)))
    t0 = time.perf_counter()
    _state, stats = tr.train(
        state, epochs=TRAIN_CONFIG["epochs"],
        batch_size=TRAIN_CONFIG["batch_size"],
        warmup_epochs=TRAIN_CONFIG["warmup_epochs"])
    stats["wall_s"] = time.perf_counter() - t0
    return stats


def run(out=print):
    """Serve + train + dp4 parity; returns ``(problems, serve_stats,
    train_stats, dp4_result)``."""
    from repro.launch.serve_rgnn import serve

    s = serve(log=_quiet, **SERVE_CONFIG)
    t = _run_train()
    d = _run_dp4_subprocess()

    problems: List[str] = []
    if s["retraces_after_warmup"] != 0:
        problems.append(
            f"multi-shard serve retraced {s['retraces_after_warmup']} "
            f"times after warmup (want 0)")
    if s["batcher_batch_cache"]["hits"] <= 0:
        problems.append("sharded batcher never reused a cached batch on "
                        "repeat traffic")
    if t["retraces_after_warmup"] != 0:
        problems.append(
            f"data-parallel trainer retraced {t['retraces_after_warmup']} "
            f"times after the warmup epoch (want 0)")
    if not (t["losses"][-1] < t["losses"][0]):
        problems.append(
            f"train loss did not decrease ({t['losses'][0]:.4f} -> "
            f"{t['losses'][-1]:.4f})")
    if not d["parity"]:
        problems.append("dp=4 is not bitwise identical to dp=1 "
                        "(serve logits / loss / optimizer state)")
    if d["hlo_all_gathers"] < 2:
        problems.append(
            f"lowered train step contains {d['hlo_all_gathers']} all_gather "
            f"collectives (want >=2: halo features + gradient all-reduce "
            f"fused into the one compiled module)")
    if d["train_compiled"] != 1 or d["train_cache_hits"] < 2:
        problems.append(
            f"dp=4 train step not served from one compiled entry "
            f"(compiled={d['train_compiled']}, hits={d['train_cache_hits']})")

    out(csv_row("dist_smoke/serve", s["latency_ms_p50"] / 1e3,
                f"shards={s['num_partitions']};dp={s['dp']};"
                f"retraces={s['retraces_after_warmup']};"
                f"compiled={s['executor_compiled']};"
                f"batch_cache_hits={s['batcher_batch_cache']['hits']}"))
    out(csv_row("dist_smoke/train", t["step_ms_p50"] / 1e3,
                f"steps={t['steps']};retraces={t['retraces_after_warmup']};"
                f"compiled={t['executor_compiled']};"
                f"loss={t['losses'][0]:.3f}->{t['losses'][-1]:.3f}"))
    out(csv_row("dist_smoke/dp4", 0.0,
                f"parity={'ok' if d['parity'] else 'FAIL'};"
                f"hlo_all_gathers={d['hlo_all_gathers']};"
                f"compiled={d['train_compiled']};"
                f"problems={len(problems)}"))
    return problems, s, t, d


def ci_check() -> None:
    """Exit 1 unless serving and training replay across shards, the
    collectives are fused into the compiled step, and dp=4 == dp=1."""
    problems, s, t, d = run(out=lambda *_: None)
    if problems:
        for pb in problems:
            print(f"[dist_smoke --ci] FAIL: {pb}", file=sys.stderr)
        raise SystemExit(1)
    print(f"[dist_smoke --ci] OK: {s['num_partitions']}-shard serve "
          f"{s['batches']} batches (0 retraces after warmup), "
          f"dist train {t['steps']} steps (0 retraces, loss "
          f"{t['losses'][0]:.3f}->{t['losses'][-1]:.3f}), dp4 bitwise "
          f"parity, {d['hlo_all_gathers']} all_gathers fused into "
          f"{d['train_compiled']} compiled step")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="fail (exit 1) on any distributed-contract "
                         "violation")
    args = ap.parse_args(argv)
    if args.ci:
        ci_check()
    else:
        print("name,us_per_call,derived")
        problems, *_ = run()
        for pb in problems:
            print(f"[dist_smoke] problem: {pb}", file=sys.stderr)


if __name__ == "__main__":
    main()
