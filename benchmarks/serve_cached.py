"""Cache-hit-rate + per-batch latency microbenchmark for cached serving.

Runs the serving driver over power-law repeat traffic (the seed stream
cycles over a few distinct batches) twice — cold path (no caches) vs the
full cached pipeline (sampled-block LRU + KernelLayouts LRU + whole-plan
compiled executor) — and reports steady-state per-batch latency, cache hit
rates, and compiled-executor trace counts.

``--ci`` runs a small interpret-mode configuration and *asserts* the
steady-state contract the caches exist for: zero executor retraces after
warmup, every repeated batch served from the block cache (zero host-side
KernelLayouts rebuilds for repeats), and exactly one compiled trace per
shape bucket. A retracing or cache regression fails the step.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import csv_row
from repro.launch.serve_rgnn import serve

# one bucketed shape set, small enough for interpret mode in CI
CONFIG = dict(
    model="rgat", dataset="aifb", scale=0.05, layers=2, dim=8, hidden=8,
    classes=4, fanouts=[3, 3], batch_size=8, tile=8, node_block=8,
    bucket=True, seed=0,
)
DISTINCT = 3          # distinct seed batches the stream cycles over
NUM_BATCHES = 12


def run(out=print, backend: str = "xla", num_batches: int = NUM_BATCHES):
    quiet = dict(log=lambda *a, **k: None, backend=backend,
                 num_batches=num_batches, repeat_after=DISTINCT, **CONFIG)
    uncached = serve(cache_blocks=0, cache_layouts=0, **quiet)
    cached = serve(cache_blocks=32, cache_layouts=128, **quiet)

    out(csv_row("serve_cached/uncached_batch", uncached["latency_ms_p50"] / 1e3,
                f"traces={uncached['executor_traces']}"))
    out(csv_row(
        "serve_cached/cached_batch", cached["latency_ms_p50"] / 1e3,
        f"traces={cached['executor_traces']};"
        f"block_hit_rate={cached['block_cache_hit_rate']:.2f};"
        f"retraces_after_warmup={cached['retraces_after_warmup']}"))
    return uncached, cached


def ci_check(backend: str = "pallas_interpret") -> None:
    """Interpret-mode retracing/caching regression gate (exit 1 on failure).

    All counters are read from the run's metrics-registry snapshot
    (``stats["metrics"]``) — the obs layer is the one surface for cache and
    trace telemetry, not the executor/loader internals."""
    from repro.obs.registry import snapshot_counter_total, snapshot_value

    _, cached = run(out=lambda *_: None, backend=backend)
    m = cached["metrics"]
    traces = snapshot_counter_total(m, "executor_traces")
    block_hits = snapshot_value(m, "loader_cache_hits",
                                cache="block_cache") or 0
    block_misses = snapshot_value(m, "loader_cache_misses",
                                  cache="block_cache") or 0
    block_rate = snapshot_value(m, "loader_cache_hit_rate",
                                cache="block_cache") or 0.0
    n_repeats = NUM_BATCHES - DISTINCT
    want_rate = n_repeats / NUM_BATCHES
    failures = []
    if cached["retraces_after_warmup"] != 0:
        failures.append(
            f"executor retraced {cached['retraces_after_warmup']}x after "
            f"warmup (expected 0)")
    # steady state: one compiled trace per shape bucket, every later batch a
    # compile-cache hit
    if traces != cached["executor_compiled"]:
        failures.append(
            f"trace count {traces} != compiled entries "
            f"{cached['executor_compiled']} (each bucket must trace once)")
    if traces > DISTINCT:
        failures.append(
            f"{traces} traces for {DISTINCT} distinct "
            f"batches (bucketing regressed)")
    # every repeated seed batch must come from the sampled-block cache, i.e.
    # zero host-side sampling/KernelLayouts work for repeats
    if block_misses != DISTINCT:
        failures.append(
            f"{block_misses} block-cache misses for "
            f"{DISTINCT} distinct batches")
    if block_hits != n_repeats:
        failures.append(
            f"{block_hits} block-cache hits, expected "
            f"{n_repeats} (a repeat rebuilt its layouts host-side)")
    # the registry must carry the *rate* gauge too (dashboards/CI read
    # reuse directly instead of recomputing it from raw counters)
    if abs(block_rate - want_rate) > 1e-9:
        failures.append(
            f"loader_cache_hit_rate gauge {block_rate:.3f} != expected "
            f"{want_rate:.3f} for {n_repeats}/{NUM_BATCHES} repeats")
    if failures:
        for f in failures:
            print(f"[serve_cached --ci] FAIL: {f}", file=sys.stderr)
        raise SystemExit(1)
    print(f"[serve_cached --ci] OK: {traces} traces for "
          f"{NUM_BATCHES} batches ({DISTINCT} distinct), 0 retraces after "
          f"warmup, {block_hits}/{n_repeats} repeats served "
          f"from the block cache (hit rate {block_rate:.2f})")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="interpret-mode assertion mode (retrace gate)")
    ap.add_argument("--backend", default=None,
                    choices=["xla", "pallas", "pallas_interpret"])
    args = ap.parse_args(argv)
    if args.ci:
        ci_check(backend=args.backend or "pallas_interpret")
    else:
        print("name,us_per_call,derived")
        run(backend=args.backend or "xla")


if __name__ == "__main__":
    main()
