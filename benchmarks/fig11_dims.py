"""Paper Fig. 11: (32,32) / (64,64) / (128,128) dimension sweep of
unoptimized Hector — checks the sublinear time scaling the paper reports."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, csv_row, time_fn
from repro.core.module import HectorModule
from repro.models import rgat_program


def run(datasets=("aifb", "mutag"), dims=(32, 64, 128), out=print):
    rows = []
    for ds in datasets:
        hg = bench_graph(ds)
        per_dim = {}
        for d in dims:
            x = jnp.asarray(
                np.random.default_rng(0).normal(size=(hg.num_nodes, d)),
                jnp.float32)
            mod = HectorModule(rgat_program(d, d), hg, reorder=False,
                               compact=False, backend="xla", tile=32, node_block=32)
            params = mod.init(jax.random.key(0))
            t = time_fn(lambda p, xx, m=mod: m.apply(p, {"feature": xx})["h_out"],
                        params, x)
            per_dim[d] = t
            out(csv_row(f"fig11/{ds}/d{d}", t,
                        f"rel_to_d32={t/per_dim[dims[0]]:.2f}x"))
        rows.append((ds, per_dim))
    return rows


if __name__ == "__main__":
    run()
