"""LM architecture configuration.

A model is a sequence of **stages**; each stage is a repeated homogeneous
layer *pattern* (tuple of LayerSpec). Stages are executed with
``jax.lax.scan`` over the repeat dimension (stacked params), which keeps HLO
size and compile time bounded for the 512-device dry-run and mirrors how
MaxText-class frameworks structure deep models. Hybrid architectures (Jamba's
1:7 Mamba:attention interleave, Gemma's local:global alternation,
Llama-vision's cross-attention insertion) are expressed as multi-layer
patterns.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the backbone pattern."""

    kind: str = "self_attn"         # self_attn | cross_attn | mamba
    moe: bool = False               # MoE MLP instead of dense MLP
    window: Optional[int] = None    # sliding-window size; None = global
    dec_cross: bool = False         # enc-dec decoder layer (self + cross)


@dataclasses.dataclass(frozen=True)
class Stage:
    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    stages: Tuple[Stage, ...]
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention options
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 128
    # encoder / multimodal frontend (stubs provide embeddings)
    encoder_layers: int = 0
    encoder_seq: int = 0            # whisper: 1500 frames
    frontend_tokens: int = 0        # llama-vision: image patch tokens
    frontend_dim: int = 0           # provided embedding dim (projected to d_model)
    # misc
    tie_embeddings: bool = True
    scale_embed: bool = False       # Gemma-style sqrt(d_model) embed scaling
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    sub_quadratic: bool = False     # eligible for long_500k cell
    decoder_only_note: str = ""

    # -------------------------------------------------------------- derived
    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.stages)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def has_kind(self, kind: str) -> bool:
        return any(l.kind == kind for s in self.stages for l in s.pattern)

    # ------------------------------------------------------------ counting
    def param_count(self) -> int:
        """Exact parameter count (embedding + backbone + heads)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        n = v * d  # token embedding
        if not self.tie_embeddings:
            n += v * d
        n += d  # final norm
        for st in self.stages:
            for spec in st.pattern:
                n += st.repeats * self._layer_params(spec, d, hd)
        if self.encoder_layers:
            enc_spec = LayerSpec(kind="self_attn")
            n += self.encoder_layers * self._layer_params(enc_spec, d, hd)
            n += d  # encoder final norm
        if self.frontend_dim:
            n += self.frontend_dim * d  # projection of provided embeddings
        return n

    def _layer_params(self, spec: LayerSpec, d: int, hd: int) -> int:
        n = 0
        if spec.kind in ("self_attn", "cross_attn"):
            n += d * self.num_heads * hd            # q
            n += 2 * d * self.num_kv_heads * hd     # k, v
            n += self.num_heads * hd * d            # o
            n += d                                   # pre-norm
            if self.qk_norm:
                n += 2 * hd
            if spec.dec_cross:                       # extra cross block
                n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                n += self.num_heads * hd * d + d
        elif spec.kind == "mamba":
            din, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            g = self.ssm_groups
            proj_in = d * (2 * din + 2 * g * ns + nh)
            n += proj_in + din * d                   # in/out proj
            n += (din + 2 * g * ns) * self.ssm_conv  # conv
            n += 2 * nh + din                        # A, dt bias, skip D
            n += d                                   # pre-norm
        # MLP (mamba layers in hybrid archs also carry an MLP when d_ff > 0)
        if spec.kind != "mamba" or self.d_ff > 0:
            if spec.moe:
                f = self.moe_d_ff or self.d_ff
                n += d * self.num_experts            # router
                n += self.num_experts * (3 * d * f)  # gate/up/down
            else:
                n += 3 * d * self.d_ff
            n += d                                   # pre-norm (mlp)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        f = self.moe_d_ff or self.d_ff
        per_layer_all = self.num_experts * 3 * d * f
        per_layer_active = self.experts_per_tok * 3 * d * f
        moe_layers = sum(
            st.repeats * sum(1 for l in st.pattern if l.moe) for st in self.stages
        )
        return self.param_count() - moe_layers * (per_layer_all - per_layer_active)


# ---------------------------------------------------------------------------
# input shape cells (assigned per architecture)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
