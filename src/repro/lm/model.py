"""TransformerLM: one model class covering all 10 assigned architectures.

* Layers run as ``lax.scan`` over each stage's repeat dimension (stacked
  params) with optional remat — bounded HLO for the 512-device dry-run.
* ``loss`` computes the LM cross-entropy with a **sequence-chunked head**:
  logits for 262k-vocab archs never materialize for the full sequence.
* ``prefill`` / ``decode_step`` implement KV-cache (attention) and
  conv+state cache (Mamba) serving. Cross-attention memory (VLM image
  patches, Whisper encoder frames) is passed as ``memory``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.lm.config import LMConfig, LayerSpec, Stage
from repro.nn import attention as A
from repro.nn import mlp as M
from repro.nn import moe as MOE
from repro.nn import ssm as S
from repro.nn.common import dense_init, rms_norm, shard, softcap


def padded_vocab(v: int, multiple: int = 128) -> int:
    return ((v + multiple - 1) // multiple) * multiple


class TransformerLM:
    def __init__(self, cfg: LMConfig, *, remat: bool = True,
                 loss_chunk: int = 2048, moe_aux_coef: float = 0.01):
        self.cfg = cfg
        self.remat = remat
        self.loss_chunk = loss_chunk
        self.moe_aux_coef = moe_aux_coef
        self.vp = padded_vocab(cfg.vocab_size)
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------ params
    def _init_layer(self, key, spec: LayerSpec) -> Dict:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 6)
        p: Dict[str, Any] = {"norm": jnp.ones((cfg.d_model,), dt)}
        if spec.kind in ("self_attn", "cross_attn"):
            p["attn"] = A.init_attention(ks[0], cfg, dt,
                                         cross=spec.kind == "cross_attn")
            if spec.dec_cross:
                p["cross_norm"] = jnp.ones((cfg.d_model,), dt)
                p["cross"] = A.init_attention(ks[1], cfg, dt, cross=True)
        elif spec.kind == "mamba":
            p["mamba"] = S.init_mamba(ks[0], cfg, dt)
        if spec.kind != "mamba" or cfg.d_ff > 0:
            p["mlp_norm"] = jnp.ones((cfg.d_model,), dt)
            if spec.moe:
                p["moe"] = MOE.init_moe(ks[2], cfg.d_model,
                                        cfg.moe_d_ff or cfg.d_ff,
                                        cfg.num_experts, dt)
            elif cfg.d_ff > 0:
                p["mlp"] = M.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dt)
            else:
                del p["mlp_norm"]
        return p

    def _init_stage(self, key, stage: Stage) -> Dict:
        def one(k):
            kk = jax.random.split(k, len(stage.pattern))
            return {f"l{i}": self._init_layer(kk[i], spec)
                    for i, spec in enumerate(stage.pattern)}
        keys = jax.random.split(key, stage.repeats)
        return jax.vmap(one)(keys)

    def init(self, key) -> Dict:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 8 + len(cfg.stages))
        params: Dict[str, Any] = {
            "embed": dense_init(ks[0], (self.vp, cfg.d_model), dt,
                                fan_in=cfg.d_model),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "stages": [self._init_stage(ks[3 + i], st)
                       for i, st in enumerate(cfg.stages)],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], (cfg.d_model, self.vp), dt)
        if cfg.frontend_dim:
            params["frontend_proj"] = dense_init(
                ks[2], (cfg.frontend_dim, cfg.d_model), dt)
        if cfg.encoder_layers:
            enc_stage = Stage((LayerSpec(kind="self_attn"),),
                              cfg.encoder_layers)
            params["encoder"] = {
                "stages": [self._init_stage(ks[-1], enc_stage)],
                "final_norm": jnp.ones((cfg.d_model,), dt),
            }
        return params

    # ------------------------------------------------------------ layers
    def _apply_layer(self, spec: LayerSpec, p: Dict, x, positions, *,
                     memory=None, cache=None, cache_index=None,
                     prefill=False, causal=True):
        cfg = self.cfg
        new_cache = {}
        aux = jnp.float32(0.0)
        if spec.kind == "mamba":
            h = rms_norm(x, p["norm"], cfg.norm_eps)
            # prefill uses the chunked path and emits a fresh cache;
            # decode consumes the rolling conv window + recurrent state
            m_cache = cache.get("mamba") if (cache and not prefill) else None
            h, mc = S.mamba_forward(p["mamba"], h, cfg, cache=m_cache,
                                    return_cache=prefill)
            if mc is not None:
                new_cache["mamba"] = mc
            x = x + h
        elif spec.kind == "cross_attn":
            h = rms_norm(x, p["norm"], cfg.norm_eps)
            ckv = cache.get("cross") if (cache and not prefill) else None
            h, ck = A.attention(p["attn"], h, cfg, spec, positions,
                                memory=memory, cross_kv=ckv,
                                store_cross=prefill, causal=False)
            if ck is not None:
                new_cache["cross"] = ck
            x = x + h
        else:  # self_attn
            h = rms_norm(x, p["norm"], cfg.norm_eps)
            kv_cache = cache.get("attn") if cache else None
            h, kc = A.attention(p["attn"], h, cfg, spec, positions,
                                kv_cache=kv_cache, cache_index=cache_index,
                                causal=causal)
            if kc is not None:
                new_cache["attn"] = kc
            x = x + h
            if spec.dec_cross:
                h = rms_norm(x, p["cross_norm"], cfg.norm_eps)
                ckv = cache.get("cross") if (cache and not prefill) else None
                h, ck = A.attention(p["cross"], h, cfg, spec, positions,
                                    memory=memory, cross_kv=ckv,
                                    store_cross=prefill, causal=False)
                if ck is not None:
                    new_cache["cross"] = ck
                x = x + h
        if "mlp_norm" in p:
            h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
            if "moe" in p:
                h, moe_aux = MOE.moe_ffn(p["moe"], h, cfg.num_experts,
                                         cfg.experts_per_tok,
                                         cfg.capacity_factor)
                aux = aux + moe_aux["lb_loss"]
            else:
                h = M.mlp(p["mlp"], h)
            x = x + h
        x = shard("activation", x)
        return x, new_cache, aux

    def _stage_cache_init(self, stage: Stage, batch: int, cache_len: int):
        cfg, dt = self.cfg, self.dtype
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        mem_len = cfg.encoder_seq or cfg.frontend_tokens
        out = []
        for spec in stage.pattern:
            c = {}
            if spec.kind == "self_attn":
                c["attn"] = {
                    "k": jnp.zeros((stage.repeats, batch, cache_len, kv, hd), dt),
                    "v": jnp.zeros((stage.repeats, batch, cache_len, kv, hd), dt),
                }
            if (spec.kind == "cross_attn" or spec.dec_cross) and mem_len:
                # §Perf v-G: cross K/V cached at prefill; decode skips
                # recomputing (and re-encoding) the static memory
                c["cross"] = {
                    "k": jnp.zeros((stage.repeats, batch, mem_len, kv, hd), dt),
                    "v": jnp.zeros((stage.repeats, batch, mem_len, kv, hd), dt),
                }
            elif spec.kind == "mamba":
                conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
                c["mamba"] = {
                    "conv": jnp.zeros(
                        (stage.repeats, batch, cfg.ssm_conv - 1, conv_ch), dt),
                    "state": jnp.zeros(
                        (stage.repeats, batch, cfg.ssm_heads,
                         cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                }
            out.append(c)
        return out

    def init_cache(self, batch: int, cache_len: int):
        return [self._stage_cache_init(st, batch, cache_len)
                for st in self.cfg.stages]

    # ------------------------------------------------------------ stages
    def _run_stage(self, stage: Stage, sp, x, positions, *, memory=None,
                   caches=None, cache_index=None, mode="train"):
        """mode: train | prefill | decode. caches: list per pattern-layer of
        stacked cache pytrees (leading dim = repeats)."""
        specs = stage.pattern

        if mode == "train":
            def body(carry, layer_params):
                x, aux = carry
                for i, spec in enumerate(specs):
                    x, _, a = self._apply_layer(spec, layer_params[f"l{i}"],
                                                x, positions, memory=memory)
                    aux = aux + a
                return (x, aux), None
            if self.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), sp)
            return x, None, aux

        # prefill and decode share the cache-threading scan body; prefill
        # writes the whole prompt at index 0 and lets Mamba layers emit
        # fresh (conv, state) caches from the chunked path.
        prefill = mode == "prefill"

        def body(carry, inp):
            x, aux = carry
            layer_params, cache_slices = inp
            new_slices = []
            for i, spec in enumerate(specs):
                x, nc, a = self._apply_layer(
                    spec, layer_params[f"l{i}"], x, positions,
                    memory=memory,
                    cache=cache_slices[i] if cache_slices[i] else None,
                    cache_index=0 if prefill else cache_index,
                    prefill=prefill)
                aux = aux + a
                # merge: layers may update only part of their cache entry
                # (e.g. self-attn K/V while the cross K/V stays as-is)
                merged = dict(cache_slices[i]) if cache_slices[i] else {}
                merged.update(nc or {})
                new_slices.append(merged)
            return (x, aux), new_slices

        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (sp, caches))
        return x, new_caches, aux

    # ------------------------------------------------------------ encoder
    def _encode(self, params, frames):
        """Whisper-style encoder over provided frame embeddings [B, M, D]."""
        cfg = self.cfg
        enc = params["encoder"]
        stage = Stage((LayerSpec(kind="self_attn"),), cfg.encoder_layers)
        pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
        x = frames

        def body(carry, layer_params):
            x, aux = carry
            spec = LayerSpec(kind="self_attn")
            x, _, a = self._apply_layer(spec, layer_params["l0"], x, pos,
                                        causal=False)
            return (x, aux + a), None

        (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                 enc["stages"][0])
        return rms_norm(x, enc["final_norm"], cfg.norm_eps)

    def _memory(self, params, frontend: Optional[jnp.ndarray]):
        """Resolve cross-attention memory from stubbed frontend embeddings."""
        cfg = self.cfg
        if frontend is None:
            return None
        if cfg.encoder_layers:
            return self._encode(params, frontend)
        if cfg.frontend_dim:
            return frontend @ params["frontend_proj"]
        return frontend

    # ------------------------------------------------------------ forward
    def backbone(self, params, tokens, *, frontend=None, positions=None,
                 mode="train", caches=None, cache_index=None):
        cfg = self.cfg
        b, s = tokens.shape
        if positions is None:
            positions = jnp.arange(s, dtype=jnp.int32)   # [S], batch-shared
        x = params["embed"][tokens].astype(self.dtype)
        if cfg.scale_embed:
            x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(self.dtype)
        x = shard("activation", x)
        if mode == "decode" and caches is not None:
            memory = None      # cross K/V cached at prefill (§Perf v-G)
        else:
            memory = self._memory(params, frontend)
        aux = jnp.float32(0.0)
        new_caches = []
        for i, stage in enumerate(cfg.stages):
            x, nc, a = self._run_stage(
                stage, params["stages"][i], x, positions, memory=memory,
                caches=caches[i] if caches is not None else None,
                cache_index=cache_index, mode=mode)
            aux = aux + a
            new_caches.append(nc)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, new_caches, aux

    def logits(self, params, hidden):
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        lg = jnp.einsum("bsd,dv->bsv", hidden, head).astype(jnp.float32)
        return softcap(lg, self.cfg.logit_softcap)

    # ------------------------------------------------------------ loss
    def loss(self, params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        """batch: tokens [B,S], targets [B,S], optional frontend embeds."""
        tokens, targets = batch["tokens"], batch["targets"]
        hidden, _, aux = self.backbone(params, tokens,
                                       frontend=batch.get("frontend"),
                                       mode="train")
        b, s, d = hidden.shape
        chunk = min(self.loss_chunk, s)
        assert s % chunk == 0
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])

        def chunk_loss(carry, inp):
            h_c, t_c = inp                       # [chunk, B, D], [chunk, B]
            lg = jnp.einsum("cbd,dv->cbv", h_c, head).astype(jnp.float32)
            lg = softcap(lg, self.cfg.logit_softcap)
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, t_c[..., None], axis=-1)[..., 0]
            return carry + jnp.sum(lse - gold), None

        h_cs = hidden.swapaxes(0, 1).reshape(s // chunk, chunk, b, d)
        t_cs = targets.swapaxes(0, 1).reshape(s // chunk, chunk, b)
        total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (h_cs, t_cs))
        nll = total / (b * s)
        loss = nll + self.moe_aux_coef * aux / max(1, self.cfg.num_layers)
        return loss, {"nll": nll, "moe_aux": aux}

    # ------------------------------------------------------------ serving
    def prefill(self, params, tokens, *, frontend=None, cache_len=None):
        """Run the prompt, build the cache, return (logits_last, caches)."""
        cache_len = cache_len or tokens.shape[1]
        caches = self.init_cache(tokens.shape[0], cache_len)
        hidden, caches, _ = self.backbone(params, tokens, frontend=frontend,
                                          mode="prefill", caches=caches)
        lg = self.logits(params, hidden[:, -1:])
        return lg, caches

    def decode_step(self, params, token, index, caches, *, frontend=None):
        """One-token decode: token [B,1], index scalar (position)."""
        b = token.shape[0]
        positions = jnp.full((1,), index, jnp.int32)
        hidden, new_caches, _ = self.backbone(
            params, token, frontend=frontend, positions=positions,
            mode="decode", caches=caches, cache_index=index)
        return self.logits(params, hidden), new_caches
