"""Deterministic per-etype fanout neighbor sampling over ``HeteroGraph``.

Message-flow-graph ("block") semantics follow the DGL/GraphBolt shape: seed
nodes are the destination frontier of the last hop; each hop samples up to
``fanout[etype]`` incoming edges per (destination node, edge type) from the
*full* graph, and the union of the frontier with the sampled sources becomes
the next (inner) frontier. The block for hop ``l`` is a standalone
``HeteroGraph`` over that union, so all Hector preprocessing — etype-sorted
edges, destination CSR, and the compact-materialization map (unique
(src, etype) pairs, the data-reuse structure HiHGNN motivates preserving) —
is recomputed per block and the existing kernels/layouts apply unchanged.

Node-ID bookkeeping exploits a seed-graph invariant: ``HeteroGraph`` nodes
are presorted by node type, so sorting global IDs also sorts by
(ntype, id) and every frontier is represented as a sorted unique ID array.
Local IDs are then ``searchsorted`` positions, and each block's destination
frontier ordering matches the next block's node ordering by construction.

Sampling is seeded per (sampler seed, batch index) — the same determinism
contract as ``data/pipeline.py`` — so restarts and replicas replay the
exact same mini-batch stream.

The per-candidate randomness is a **counter-based stateless hash** over the
candidate edge's destination-sorted position (``mix32`` of position XOR a
per-(seed, epoch, batch, hop) base key), not a stateful generator: the host
sampler and ``sampling/device_sampler.py`` evaluate the identical function
over the identical positions, so both select the same edges — the
host/device parity contract, and the reason sampling carries no per-host
nondeterminism.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.graph import HeteroGraph

FanoutSpec = Union[int, Dict[int, int], Sequence[int], np.ndarray]

FULL_NEIGHBORHOOD = -1  # fanout value meaning "keep every in-edge"


# ---------------------------------------------------------------------------
# counter-based randomness (shared host/device)
# ---------------------------------------------------------------------------
_MIX_M1 = np.uint32(0x85EBCA6B)
_MIX_M2 = np.uint32(0xC2B2AE35)


def mix32(x):
    """murmur3 finalizer over uint32 values; elementwise, wraparound.

    Works unchanged on NumPy and jax.numpy uint32 arrays (the constants are
    ``np.uint32`` scalars, which both array types combine without upcasting),
    so host and device samplers share one key function.
    """
    x = x ^ (x >> 16)
    x = x * _MIX_M1
    x = x ^ (x >> 13)
    x = x * _MIX_M2
    x = x ^ (x >> 16)
    return x


def fold_key(*parts: int) -> np.uint32:
    """Fold integer key parts into one uint32 base key (pure Python ints
    internally, so no overflow warnings; order-sensitive)."""
    k = 0x9E3779B9
    for p in parts:
        k ^= int(p) & 0xFFFFFFFF
        # inline scalar mix32 on python ints (exact uint32 semantics)
        k ^= k >> 16
        k = (k * 0x85EBCA6B) & 0xFFFFFFFF
        k ^= k >> 13
        k = (k * 0xC2B2AE35) & 0xFFFFFFFF
        k ^= k >> 16
    return np.uint32(k)


def hop_base_key(seed: int, batch_index: int, hop: int,
                 epoch: Optional[int] = None) -> np.uint32:
    """Base key for one sampling hop — the determinism contract: a pure
    function of (sampler seed, epoch, batch index, hop), with ``epoch=None``
    distinct from every integer epoch."""
    etag = 0 if epoch is None else int(epoch) + 1
    return fold_key(seed, etag, batch_index, hop)


def edge_sample_keys(base_key, pos):
    """Per-candidate uint32 sort key: candidates with the k smallest keys in
    their (destination, etype) bin are the sampled edges. ``pos`` is the
    candidate's destination-sorted edge position — the shared host/device
    candidate enumeration — and the full re-hash of (position XOR base key)
    decorrelates the per-batch orderings."""
    pos_u32 = (pos.astype(np.uint32) if isinstance(pos, np.ndarray)
               else pos.astype("uint32"))
    return mix32(pos_u32 ^ base_key)


def normalize_fanout(fanout: FanoutSpec, num_etypes: int) -> np.ndarray:
    """Per-etype fanout vector [R]; -1 means the full neighborhood."""
    if isinstance(fanout, (int, np.integer)):
        return np.full(num_etypes, int(fanout), dtype=np.int64)
    if isinstance(fanout, dict):
        arr = np.zeros(num_etypes, dtype=np.int64)
        unlisted = sorted(set(range(num_etypes)) - {int(e) for e in fanout})
        if unlisted:
            warnings.warn(
                f"dict fanout leaves {len(unlisted)} of {num_etypes} etypes "
                f"unlisted (e.g. {unlisted[:5]}); they default to fanout 0 "
                f"(drop all edges of that type). Pass an explicit 0 to "
                f"silence this.", UserWarning, stacklevel=2)
        for et, k in fanout.items():
            arr[int(et)] = int(k)
        return arr
    arr = np.asarray(fanout, dtype=np.int64)
    if arr.shape != (num_etypes,):
        raise ValueError(
            f"per-etype fanout must have shape ({num_etypes},), got {arr.shape}"
        )
    return arr


@dataclasses.dataclass
class Block:
    """One hop of a sampled message-flow graph.

    ``graph`` is a valid standalone ``HeteroGraph`` over the block's local
    node set (the input frontier of this hop). Only the rows selected by
    ``dst_local`` — the output frontier — carry meaningful aggregations.
    """

    graph: HeteroGraph
    node_ids: np.ndarray   # [n_local] global node IDs (sorted ascending)
    dst_local: np.ndarray  # [n_dst] local indices of the output frontier

    @property
    def num_src(self) -> int:
        return int(self.node_ids.shape[0])

    @property
    def num_dst(self) -> int:
        return int(self.dst_local.shape[0])

    @property
    def dst_ids(self) -> np.ndarray:
        return self.node_ids[self.dst_local]


@dataclasses.dataclass
class BlockSequence:
    """Per-hop blocks in execution order (``blocks[0]`` is the innermost
    hop; ``blocks[-1]``'s output frontier covers the seeds)."""

    blocks: List[Block]
    seeds: np.ndarray      # the requested seed IDs, order and dupes preserved
    seed_perm: np.ndarray  # [len(seeds)] row of each seed in the final output

    @property
    def num_hops(self) -> int:
        return len(self.blocks)

    @property
    def input_node_ids(self) -> np.ndarray:
        """Global IDs whose input features the first hop consumes."""
        return self.blocks[0].node_ids

    def slice_labels(self, labels: np.ndarray) -> np.ndarray:
        """Per-batch label slice aligned with the block forward's output.

        The block forward returns the final frontier's rows re-permuted by
        ``seed_perm`` — i.e. one row per requested seed, in request order
        (duplicates included) — so the aligned labels are simply
        ``labels[self.seeds]``.
        """
        return np.asarray(labels)[self.seeds]

    def describe(self) -> str:
        lines = [f"BlockSequence(seeds={len(self.seeds)})"]
        for i, b in enumerate(self.blocks):
            lines.append(
                f"  hop {i}: {b.num_src} nodes -> {b.num_dst} dst, "
                f"{b.graph.num_edges} edges, "
                f"compaction {b.graph.entity_compaction_ratio:.2f}"
            )
        return "\n".join(lines)


class FanoutSampler:
    """Seeded per-etype fanout neighbor sampler emitting ``BlockSequence``s.

    ``fanouts`` is one spec per hop, listed input-to-output (hop 0 is the
    innermost layer, matching execution order); sampling itself proceeds
    from the seeds backwards.
    """

    def __init__(self, hg: HeteroGraph, fanouts: Sequence[FanoutSpec],
                 seed: int = 0):
        if not fanouts:
            raise ValueError("need at least one hop fanout")
        self.hg = hg
        self.fanouts = [normalize_fanout(f, hg.num_etypes) for f in fanouts]
        self.seed = seed
        # dst-sorted companions of the dst CSR, so a frontier's in-edges are
        # contiguous ranges with O(1) lookup of (src, etype) per edge.
        self._src_d = hg.src[hg.perm_dst]
        self._etype_d = hg.etype[hg.perm_dst]

    @property
    def num_hops(self) -> int:
        return len(self.fanouts)

    # ------------------------------------------------------------------
    def sample(self, seeds: np.ndarray, batch_index: int = 0,
               epoch: Optional[int] = None) -> BlockSequence:
        """Sample a ``BlockSequence`` for ``seeds``.

        Randomness is keyed by ``(sampler seed, batch_index, hop)`` — or
        ``(sampler seed, epoch, batch_index, hop)`` when ``epoch`` is given,
        the epoch-aware training contract: replaying a step reproduces its
        blocks exactly, while the same seed batch in a different epoch
        draws a fresh neighborhood. The keying is counter-based
        (``hop_base_key``/``edge_sample_keys``), the exact scheme the device
        sampler evaluates — identical inputs select identical edges on both.
        """
        seeds = np.asarray(seeds, dtype=np.int32)
        if seeds.ndim != 1 or seeds.size == 0:
            raise ValueError("seeds must be a non-empty 1-D int array")
        if seeds.min() < 0 or seeds.max() >= self.hg.num_nodes:
            raise ValueError("seed node id out of range")

        frontier = np.unique(seeds)
        seed_perm = np.searchsorted(frontier, seeds).astype(np.int32)

        blocks: List[Block] = []
        for hop, fanout in enumerate(reversed(self.fanouts)):
            base = hop_base_key(self.seed, int(batch_index), hop, epoch)
            src, dst, et = self._sample_in_edges(frontier, fanout, base)
            node_ids = np.unique(np.concatenate([frontier, src]))
            bg = HeteroGraph.from_edges(
                np.searchsorted(node_ids, src).astype(np.int32),
                np.searchsorted(node_ids, dst).astype(np.int32),
                et,
                num_nodes=int(node_ids.shape[0]),
                num_etypes=self.hg.num_etypes,
                node_type=self.hg.node_type[node_ids],
                num_ntypes=self.hg.num_ntypes,
            )
            dst_local = np.searchsorted(node_ids, frontier).astype(np.int32)
            blocks.append(Block(graph=bg, node_ids=node_ids.astype(np.int32),
                                dst_local=dst_local))
            frontier = node_ids
        blocks.reverse()
        return BlockSequence(blocks=blocks, seeds=seeds, seed_perm=seed_perm)

    # ------------------------------------------------------------------
    def _sample_in_edges(self, frontier: np.ndarray, fanout: np.ndarray,
                         base_key: np.uint32):
        """Sample ≤ fanout[etype] in-edges per (frontier node, etype),
        without replacement. Returns global (src, dst, etype) arrays."""
        hg = self.hg
        starts = hg.dst_ptr[frontier].astype(np.int64)
        counts = (hg.dst_ptr[frontier + 1] - hg.dst_ptr[frontier]).astype(np.int64)
        pos, owner = candidate_positions(starts, counts)
        if pos.size == 0:
            empty = np.zeros(0, dtype=np.int32)
            return empty, empty, empty
        et = self._etype_d[pos].astype(np.int64)
        sel, sel_owner = select_by_keys(pos, owner, et, fanout, base_key,
                                        hg.num_etypes)
        src = self._src_d[sel]
        dst = frontier[sel_owner].astype(np.int32)
        return src.astype(np.int32), dst, self._etype_d[sel].astype(np.int32)


# ---------------------------------------------------------------------------
# the shared selection core (single-box and sharded samplers)
# ---------------------------------------------------------------------------
def candidate_positions(starts: np.ndarray, counts: np.ndarray):
    """Expand per-frontier-node CSR runs ``[start, start+count)`` into the
    flat candidate position array plus each candidate's frontier index.

    ``starts`` are *global* dst-sorted offsets — shards pass their owned
    nodes' global ``dst_ptr`` values here, which is how per-shard candidate
    enumeration lands on the same key domain as the single-box sampler."""
    starts = np.asarray(starts, dtype=np.int64)
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return (np.zeros(0, dtype=np.int64),) * 2
    offs = np.concatenate([[0], np.cumsum(counts)])
    pos = (np.arange(total, dtype=np.int64)
           - np.repeat(offs[:-1], counts) + np.repeat(starts, counts))
    owner = np.repeat(np.arange(len(counts), dtype=np.int64), counts)
    return pos, owner


def select_by_keys(pos: np.ndarray, owner: np.ndarray, et: np.ndarray,
                   fanout: np.ndarray, base_key: np.uint32,
                   num_etypes: int):
    """Rank candidates within each (owner, etype) bin by their counter-based
    key and keep ranks < fanout[etype] — uniform sampling w/o replacement.

    The bin ranking depends only on the candidates *inside* the bin (the
    keys are pure functions of global position), so any evaluator holding a
    destination's complete in-edge list — the single-box sampler, the device
    sampler, or the destination's owner shard — selects the same edges.
    lexsort is stable, so equal keys tie-break by ascending position, the
    same total order the device sampler's stable argsort produces.

    Returns ``(sel_pos, sel_owner)``: the kept candidates' positions and
    frontier indices, in (bin, key) order.
    """
    total = int(pos.shape[0])
    group = owner * num_etypes + et
    order = np.lexsort((edge_sample_keys(base_key, pos), group))
    g_sorted = group[order]
    boundary = np.concatenate([[True], g_sorted[1:] != g_sorted[:-1]])
    group_start = np.flatnonzero(boundary)
    group_len = np.diff(np.concatenate([group_start, [total]]))
    rank = np.arange(total, dtype=np.int64) - np.repeat(group_start, group_len)
    cap = fanout[et[order]]
    keep = (cap == FULL_NEIGHBORHOOD) | (rank < cap)
    return pos[order][keep], owner[order][keep]
