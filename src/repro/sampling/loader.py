"""Prefetching mini-batch loader for sampled RGNN blocks.

Mirrors the queue pattern of ``data/pipeline.py``: a background thread pulls
seed batches from a deterministic stream, runs the fanout sampler, and —
crucially — builds the tile-aligned ``KernelLayouts`` for every block on the
host, off the accelerator path. The consumer (training or serving loop) only
ever dequeues device-ready ``MiniBatch`` bundles, so layout construction
(NumPy segment padding / CSR blocking) overlaps with accelerator compute.

Serving traffic is power-law, so the loader layers two LRU caches over that
pipeline (ROADMAP "cached neighbor layouts"):

* a **KernelLayouts cache** keyed by block signature (a content hash of the
  block graph's edge/node-type arrays plus the tile/bucket config) — blocks
  that sample the same subgraph skip the NumPy padding/CSR-blocking passes;
* a **sampled-block cache** keyed by ``(seeds, fanout)`` — repeated seed
  batches skip sampling *and* layout construction entirely and return the
  previously built device-ready ``MiniBatch``.

Hit/miss counters are exposed (``cache_stats``) so the serving driver and
benchmarks can report and assert steady-state reuse.

**Device mode**: constructed with a ``DeviceSampler`` (anything exposing
``sample_minibatch``) instead of a ``FanoutSampler``, the loader switches to
a threadless prefetch: sampling and layout construction are jit-compiled
device programs whose dispatch is asynchronous, so overlapping batch k+1's
sampling with batch k's execution only requires *dispatching* k+1 before the
consumer executes k — two interleaved streams of enqueued device work, no
producer thread. ``host_builds`` / ``device_builds`` count which pipeline
actually built each non-cached batch, so benchmarks can assert the device
steady state performs zero host-side sampling or layout work.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import queue
import threading
from typing import Callable, List, Optional, Union

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.core import codegen
from repro.core.graph import GraphTensors, HeteroGraph
from repro.kernels.layout import pow2ceil
from repro.sampling.bucketing import pad_block_graph, pad_index
from repro.sampling.sampler import BlockSequence, FanoutSampler


class LRUCache:
    """Minimal LRU map with hit/miss/eviction counters (single-consumer:
    each loader's producer thread owns its caches, so no locking).

    ``name`` labels the cache in the obs metrics registry: every hit, miss,
    and eviction is mirrored to ``loader_cache_{hits,misses,evictions}``
    counters with a ``cache=<name>`` label when metrics are enabled (the
    plain integer attributes remain the always-on source of truth)."""

    def __init__(self, maxsize: int = 64, name: str = "lru"):
        if maxsize <= 0:
            raise ValueError("LRUCache needs a positive maxsize")
        self.maxsize = maxsize
        self.name = name
        self._d: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        try:
            v = self._d.pop(key)
        except KeyError:
            self.misses += 1
            obs.metrics().counter("loader_cache_misses",
                                  cache=self.name).inc()
            self._mirror_rate()
            return None
        self._d[key] = v          # re-insert: most recently used
        self.hits += 1
        obs.metrics().counter("loader_cache_hits", cache=self.name).inc()
        self._mirror_rate()
        return v

    def _mirror_rate(self) -> None:
        # registry snapshots carry the *rate*, not just raw counters, so CI
        # gates and dashboards read reuse directly (ISSUE 9 satellite)
        obs.metrics().gauge("loader_cache_hit_rate",
                            cache=self.name).set(self.hit_rate)

    def put(self, key, value) -> None:
        self._d.pop(key, None)
        self._d[key] = value
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
            self.evictions += 1
            obs.metrics().counter("loader_cache_evictions",
                                  cache=self.name).inc()

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._d),
                "hit_rate": self.hit_rate}


def block_signature(hg: HeteroGraph, tile: int, node_block: int,
                    bucket: bool) -> tuple:
    """Content key for a block graph's kernel layouts: two blocks with equal
    signatures produce identical ``KernelLayouts`` (all layout products are
    pure functions of the edge arrays, node types, and the tile config)."""
    h = hashlib.blake2b(digest_size=16)
    for arr in (hg.src, hg.dst, hg.etype, hg.node_type):
        h.update(np.ascontiguousarray(arr).tobytes())
    return (hg.num_nodes, hg.num_ntypes, hg.num_etypes,
            tile, node_block, bool(bucket), h.digest())


class SeedStream:
    """Deterministic seed-node request stream: step -> seed ID batch.

    Models a serving request stream (seeds drawn with replacement, so
    duplicate seeds within a batch are exercised). ``batch(step)`` is a pure
    function of (seed, step), the same restart-determinism contract as
    ``SyntheticLMStream``.

    ``num_distinct`` models power-law / repeating traffic: steps wrap onto
    ``step % num_distinct``, so the stream cycles over a fixed set of seed
    batches — the workload shape that makes the sampled-block and layout
    caches (and the compiled-executor cache) pay off.

    ``zipf_alpha`` draws seeds from a Zipf distribution over the node
    population instead of uniformly: node popularity rank ``r`` (0-based)
    has probability proportional to ``(r + 1) ** -alpha``, and a
    seed-keyed permutation maps ranks onto ids so the hot set is spread
    across the id space (not just the lowest ids). This is the realistic
    skewed-traffic model the feature-cache benchmarks run against; with
    ``alpha`` ~1.0-1.5 a small device hot-row cache absorbs most of the
    feature traffic. ``batch(step)`` stays a pure function of
    ``(seed, step)`` — the rank table is built once from the seed.

    ``ids`` restricts the population to an explicit id set (e.g. a train
    split) instead of ``[0, num_nodes)``.
    """

    def __init__(self, num_nodes: Optional[int] = None,
                 batch_size: int = 32, seed: int = 0,
                 num_distinct: Optional[int] = None,
                 zipf_alpha: Optional[float] = None,
                 ids: Optional[np.ndarray] = None):
        if ids is not None:
            self.ids = np.asarray(ids, dtype=np.int32)
            if self.ids.ndim != 1 or self.ids.size == 0:
                raise ValueError("ids must be a non-empty 1-D int array")
            self.num_nodes = int(self.ids.size)
        else:
            if num_nodes is None:
                raise ValueError("need num_nodes or ids")
            self.ids = None
            self.num_nodes = int(num_nodes)
        self.batch_size = batch_size
        self.seed = seed
        self.num_distinct = num_distinct
        self.zipf_alpha = zipf_alpha
        self._cdf = self._rank2idx = None
        if zipf_alpha is not None:
            if zipf_alpha <= 0:
                raise ValueError("zipf_alpha must be positive")
            p = np.arange(1, self.num_nodes + 1,
                          dtype=np.float64) ** -float(zipf_alpha)
            self._cdf = np.cumsum(p / p.sum())
            # popularity rank -> population index, keyed off the stream
            # seed so the hot rows aren't simply the lowest ids
            self._rank2idx = np.random.default_rng(
                (self.seed, 0x5eed)).permutation(
                self.num_nodes).astype(np.int64)

    def batch(self, step: int) -> np.ndarray:
        if self.num_distinct:
            step = step % self.num_distinct
        rng = np.random.default_rng((self.seed, step))
        if self._cdf is None:
            # identical draws to the pre-skew stream (dtype is part of the
            # Generator contract — don't change it)
            draw = rng.integers(0, self.num_nodes, size=self.batch_size,
                                dtype=np.int32)
        else:
            # inverse-CDF sampling of popularity ranks, mapped to indices
            u = rng.random(self.batch_size)
            ranks = np.searchsorted(self._cdf, u, side="right")
            draw = self._rank2idx[np.minimum(ranks, self.num_nodes - 1)]
        out = draw if self.ids is None else self.ids[draw]
        return out.astype(np.int32)


class EpochSeedStream:
    """Epoch-aware training seed stream: shuffled, without replacement.

    Each epoch is an independent permutation of ``ids`` (rng keyed by
    ``(seed, epoch)``) cut into fixed-size batches; ``drop_last`` keeps the
    batch shape static so the compiled train step never sees a ragged tail.
    ``batch(step)`` is a pure function of ``(seed, step)`` — the same
    restart-determinism contract as ``SeedStream`` — so a trainer resumed
    mid-epoch replays the exact remaining batches of that epoch.

    ``epoch_of(step)`` is the loader's epoch hook: when present on a seed
    source, ``MiniBatchLoader`` keys the sampler rng *and* the sampled-block
    cache by the epoch, so neighbor resampling stays stochastic across
    epochs (no stale block replay).
    """

    def __init__(self, ids: np.ndarray, batch_size: int, seed: int = 0,
                 drop_last: bool = True):
        self.ids = np.asarray(ids, dtype=np.int32)
        if self.ids.ndim != 1 or self.ids.size == 0:
            raise ValueError("ids must be a non-empty 1-D int array")
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        self.batch_size = min(batch_size, self.ids.size)
        self.seed = seed
        self.drop_last = drop_last
        n = self.ids.size
        self.batches_per_epoch = (n // self.batch_size if drop_last
                                  else -(-n // self.batch_size))
        self._perm_cache = (-1, None)   # (epoch, permutation) memo

    @property
    def num_ids(self) -> int:
        return int(self.ids.size)

    def epoch_of(self, step: int) -> int:
        return step // self.batches_per_epoch

    def steps_for(self, epochs: int) -> int:
        return epochs * self.batches_per_epoch

    def batch(self, step: int) -> np.ndarray:
        epoch, k = divmod(step, self.batches_per_epoch)
        if self._perm_cache[0] != epoch:
            # still a pure function of (seed, epoch): the memo only avoids
            # re-permuting the full id set for every batch of an epoch
            self._perm_cache = (epoch, np.random.default_rng(
                (self.seed, epoch)).permutation(self.ids.size))
        perm = self._perm_cache[1]
        lo = k * self.batch_size
        return self.ids[perm[lo:lo + self.batch_size]]


@dataclasses.dataclass
class MiniBatch:
    """Device-ready bundle for one sampled batch: per-hop graph tensors and
    kernel layouts, plus the gather maps that chain hops and restore the
    requested seed order."""

    step: int
    seq: BlockSequence
    tensors: List[GraphTensors]
    layouts: List[codegen.KernelLayouts]
    input_ids: jnp.ndarray          # [n_input] global IDs feeding hop 0
    dst_locals: List[jnp.ndarray]   # per hop: local rows of the out frontier
    seed_perm: jnp.ndarray          # final-frontier row of each seed
    # pre-gathered input features for this batch (a ``{"feature": [n, d]}``
    # pytree), attached by a loader wired to a ``repro.feats`` store: the
    # gather for batch k+1 is dispatched while batch k executes, so the
    # host->device row transfer rides the prefetch overlap. ``None`` means
    # the executor indexes the global table itself (pre-tiering behavior).
    # Executors DONATE these buffers — they are valid for one consumption.
    feats: Optional[dict] = None

    @property
    def num_hops(self) -> int:
        return len(self.tensors)


def build_minibatch(seq: BlockSequence, step: int = 0, tile: int = 128,
                    node_block: int = 128, bucket: bool = False,
                    layout_cache: Optional[LRUCache] = None,
                    layout_scope=None, shape_floors=None) -> MiniBatch:
    """Host-side assembly of a ``MiniBatch`` from a sampled ``BlockSequence``.

    With ``bucket=True`` (the serving fast path) each block graph, its
    kernel layouts, and every gather-index vector are padded to power-of-two
    buckets, so the compiled-shape set is small and repeated batches run
    from warm compilation caches. Padding is numerically inert: pad
    nodes/edges only feed pad rows, which the hop-chaining gathers never
    read.

    ``layout_cache`` (an ``LRUCache``) memoizes ``KernelLayouts`` by block
    signature, skipping the host-side NumPy layout passes for blocks seen
    before. ``layout_scope`` (any hashable, e.g. a partition id) namespaces
    the cache entries so callers sharing one cache across graph shards
    never replay each other's layouts.

    ``shape_floors`` (a ``bucketing.ShapeFloors``) additionally pads each
    hop up to the largest bucket previously seen for this seed count — the
    serving runtime's grow-only guarantee that one ladder rung converges
    to one compiled shape set instead of retracing on every fresh bucket
    combination.
    """
    graphs = [b.graph for b in seq.blocks]
    input_ids = seq.input_node_ids
    dst_locals = [b.dst_local for b in seq.blocks]
    if bucket:
        if shape_floors is not None:
            key = int(seq.seed_perm.shape[0])
            graphs = [shape_floors.pad_graph(key, i, g)
                      for i, g in enumerate(graphs)]
        else:
            graphs = [pad_block_graph(g) for g in graphs]
        input_ids = pad_index(input_ids, graphs[0].num_nodes)
        # hop l's output rows become hop l+1's (padded) node-feature rows;
        # the last hop only needs to cover the seed gather, so any stable
        # bucket works.
        dst_locals = [
            pad_index(d, graphs[i + 1].num_nodes if i + 1 < len(graphs)
                      else (shape_floors.pad_tail(key, d.shape[0])
                            if shape_floors is not None
                            else pow2ceil(d.shape[0])))
            for i, d in enumerate(dst_locals)
        ]

    def layouts_for(hop: int, g: HeteroGraph) -> codegen.KernelLayouts:
        # Layout-internal row buckets jitter with the edge distribution even
        # at pinned graph buckets, so the floors must reach into the layout
        # build too — and the cache key must carry the floor values, or a
        # pre-growth entry would replay stale shapes after a floor raise.
        rf = (shape_floors.layout_floors(int(seq.seed_perm.shape[0]), hop)
              if bucket and shape_floors is not None else None)
        if layout_cache is None:
            return codegen.build_kernel_layouts(
                g, tile=tile, node_block=node_block, bucket=bucket,
                row_floors=rf)
        ck = (layout_scope, block_signature(g, tile, node_block, bucket),
              None if rf is None else (hop, tuple(sorted(rf.items()))))
        kl = layout_cache.get(ck)
        if kl is None:
            kl = codegen.build_kernel_layouts(
                g, tile=tile, node_block=node_block, bucket=bucket,
                row_floors=rf)
            layout_cache.put(ck, kl)
        return kl

    return MiniBatch(
        step=step,
        seq=seq,
        tensors=[g.to_tensors() for g in graphs],
        layouts=[layouts_for(i, g) for i, g in enumerate(graphs)],
        input_ids=jnp.asarray(input_ids),
        dst_locals=[jnp.asarray(d) for d in dst_locals],
        seed_perm=jnp.asarray(seq.seed_perm),
    )


class _EndOfStream(Exception):
    """Internal: a callable seed source returned None — stream over."""


def _partition_token(partition):
    """Stable hashable identity of a graph partition (or shard thereof).

    Accepts ``None`` (unpartitioned), a ``repro.dist.GraphPartition``
    (identified by its shard bounds), a ``(GraphPartition, shard_index)``
    pair, or any hashable token the caller chooses."""
    if partition is None:
        return None
    if isinstance(partition, tuple) and len(partition) == 2:
        return (_partition_token(partition[0]), partition[1])
    bounds = getattr(partition, "bounds", None)
    if bounds is not None:
        return ("part", int(getattr(partition, "num_parts", 0)),
                np.asarray(bounds).tobytes())
    return partition


class MiniBatchLoader:
    """Background-thread prefetch of sampled mini-batches.

    ``seed_source`` is a ``SeedStream`` or any ``step -> np.ndarray``
    callable. Iteration yields ``MiniBatch`` in step order; with
    ``num_batches`` set the loader raises ``StopIteration`` afterwards. A
    *callable* source may also return ``None`` to end the stream early —
    the hook the online serving runtime uses to drain an unbounded loader
    on shutdown.

    Failure contract: an exception anywhere in the producer pipeline
    (seed source, sampler, layout build, feature gather) is re-raised in
    the consumer on its next ``__next__`` — after already-built batches —
    with the worker thread stopped and joined first; a worker that dies
    without managing to report is detected and surfaced as a
    ``RuntimeError`` instead of stalling the iterator forever.

    ``partition`` names the graph shard this loader samples from (a
    ``repro.dist.GraphPartition``, a ``(partition, shard)`` pair, or any
    hashable id): it becomes part of every block/layout cache key, so
    multiple shards sharing a process never replay each other's cached
    blocks.

    ``cache_blocks``/``cache_layouts`` give the two LRU capacities (0
    disables either). The sampled-block cache is keyed by
    ``(seeds, fanout, layout config, sampler epoch)``: for *serving* streams
    (no epoch) a repeated seed batch returns the block sampled at its first
    occurrence (re-stamped with the current step), trading per-request
    resampling noise for skipping the whole host pipeline. For *training*
    streams — any seed source exposing ``epoch_of(step)``, e.g.
    ``EpochSeedStream`` — the epoch is part of the key and also re-keys the
    sampler rng, so the same seed batch in a later epoch draws a fresh
    neighborhood instead of silently replaying a stale cached block
    (which would destroy neighbor-sampling stochasticity).
    """

    _SENTINEL = object()

    def __init__(
        self,
        sampler: FanoutSampler,
        seed_source: Union[SeedStream, EpochSeedStream,
                           Callable[[int], np.ndarray]],
        *,
        tile: int = 128,
        node_block: int = 128,
        bucket: bool = False,
        depth: int = 2,
        start_step: int = 0,
        num_batches: Optional[int] = None,
        cache_blocks: int = 0,
        cache_layouts: int = 0,
        partition=None,
        feature_store=None,
        shape_floors=None,
    ):
        self.sampler = sampler
        # serving's grow-only bucket floors (bucketing.ShapeFloors); host
        # pipeline only — the device sampler has its own bucket hysteresis
        self.shape_floors = shape_floors
        # a repro.feats store: the producer gathers each batch's input rows
        # and attaches them as mb.feats (single-writer contract — only this
        # loader's producer calls gather on it)
        self.feature_store = feature_store
        self._seeds_for = (seed_source.batch
                           if hasattr(seed_source, "batch") else seed_source)
        # training streams expose epoch_of(step); serving streams don't
        self._epoch_of = getattr(seed_source, "epoch_of", None)
        self.tile = tile
        self.node_block = node_block
        self.bucket = bucket
        self.num_batches = num_batches
        self.block_cache = LRUCache(cache_blocks, name="block_cache") \
            if cache_blocks else None
        self.layout_cache = LRUCache(cache_layouts, name="layout_cache") \
            if cache_layouts else None
        self._fanout_key = tuple(
            tuple(int(x) for x in f) for f in sampler.fanouts)
        # shard identity: loaders for different partitions of one graph may
        # share a process (and, via a shared LRUCache, each other's layout
        # cache) — the partition token keeps their cached blocks/layouts
        # from colliding on identical local seed ids
        self._partition_key = _partition_token(partition)
        # a DeviceSampler builds whole MiniBatches on device; everything else
        # goes through the host sample + build_minibatch pipeline
        self.mode = ("device" if hasattr(sampler, "sample_minibatch")
                     else "host")
        self.host_builds = 0     # batches built by the host NumPy pipeline
        self.device_builds = 0   # batches built by jit device programs
        self._start_step = start_step
        self._done = False
        if self.mode == "device":
            # threadless prefetch: a deque of already-dispatched batches
            self._depth = max(1, depth)
            self._next_step = start_step
            self._pending: collections.deque = collections.deque()
            self._thread = None
            return
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def cache_stats(self) -> dict:
        """Hit/miss counters of both loader caches (empty dict if disabled)."""
        out = {}
        if self.block_cache is not None:
            out["block_cache"] = self.block_cache.stats()
        if self.layout_cache is not None:
            out["layout_cache"] = self.layout_cache.stats()
        return out

    def build_stats(self) -> dict:
        """Which pipeline built the non-cached batches (the ``sample_native``
        CI gate asserts ``host_builds == 0`` in device mode), plus the
        per-cache hit *rates* (not just raw counters)."""
        out = {"mode": self.mode, "host_builds": self.host_builds,
               "device_builds": self.device_builds}
        if self.block_cache is not None:
            out["block_cache_hit_rate"] = self.block_cache.hit_rate
        if self.layout_cache is not None:
            out["layout_cache_hit_rate"] = self.layout_cache.hit_rate
        return out

    def _attach_feats(self, mb: MiniBatch, step: int) -> MiniBatch:
        """Gather this batch's input-feature rows through the store and
        attach them. Runs on the producer (thread or async-dispatch), so
        the host gather + transfer for batch k+1 overlaps batch k's
        compute. Cached batches are stored *without* feats: executors
        donate the feature buffers and the cache state advances every
        batch, so each occurrence re-gathers (hot rows stay device-side
        in the cached store, making the re-gather cheap)."""
        if self.feature_store is None:
            return mb
        # stores normalize ids themselves: the device tier keeps them on
        # device (no sync); host/cached tiers pull them to host (the row
        # addresses are needed there — the unavoidable cost of the tier)
        feats = self.feature_store.gather(mb.input_ids, step=step)
        return dataclasses.replace(mb, feats=feats)

    def _cache_key(self, seeds: np.ndarray, epoch) -> tuple:
        return (seeds.tobytes(), self._fanout_key, self.tile,
                self.node_block, self.bucket, epoch, self._partition_key)

    def _build(self, step: int) -> MiniBatch:
        seeds = self._seeds_for(step)
        if seeds is None:   # callable sources may end the stream this way
            raise _EndOfStream
        epoch = self._epoch_of(step) if self._epoch_of is not None else None
        key = None
        if self.block_cache is not None:
            key = self._cache_key(seeds, epoch)
            mb = self.block_cache.get(key)
            if mb is not None:
                return self._attach_feats(
                    dataclasses.replace(mb, step=step), step)
        self.host_builds += 1
        with obs.span("sample", step=step):
            seq = self.sampler.sample(seeds, batch_index=step, epoch=epoch)
        with obs.span("layout", step=step):
            mb = build_minibatch(seq, step=step, tile=self.tile,
                                 node_block=self.node_block,
                                 bucket=self.bucket,
                                 layout_cache=self.layout_cache,
                                 layout_scope=self._partition_key,
                                 shape_floors=self.shape_floors)
        if self.block_cache is not None:
            self.block_cache.put(key, mb)   # cached without feats
        return self._attach_feats(mb, step)

    def _build_device(self, step: int) -> MiniBatch:
        seeds = self._seeds_for(step)
        if seeds is None:
            raise _EndOfStream
        epoch = self._epoch_of(step) if self._epoch_of is not None else None
        key = None
        if self.block_cache is not None:
            key = self._cache_key(seeds, epoch)
            mb = self.block_cache.get(key)
            if mb is not None:
                return self._attach_feats(
                    dataclasses.replace(mb, step=step), step)
        self.device_builds += 1
        mb = self.sampler.sample_minibatch(seeds, batch_index=step,
                                           epoch=epoch, step=step)
        if self.block_cache is not None:
            self.block_cache.put(key, mb)   # cached without feats
        return self._attach_feats(mb, step)

    def _pump(self) -> None:
        """Dispatch device builds until the prefetch window is full: JAX
        execution is asynchronous, so each build enqueues device work and
        returns — batch k+1 samples while the consumer executes batch k."""
        while len(self._pending) < self._depth:
            if (self.num_batches is not None and
                    self._next_step - self._start_step >= self.num_batches):
                return
            try:
                self._pending.append(self._build_device(self._next_step))
            except _EndOfStream:
                self.num_batches = self._next_step - self._start_step
                return
            self._next_step += 1

    def _fill(self):
        step = self._start_step
        item = None
        while not self._stop.is_set():
            if item is None:
                if (self.num_batches is not None
                        and step - self._start_step >= self.num_batches):
                    item = self._SENTINEL
                else:
                    try:
                        item = self._build(step)
                    except _EndOfStream:
                        item = self._SENTINEL
                    except BaseException as e:  # surface in the consumer
                        item = e
                    step += 1
            try:
                self.q.put(item, timeout=0.5)
            except queue.Full:
                continue
            if item is self._SENTINEL or isinstance(item, BaseException):
                break
            item = None

    def __iter__(self):
        return self

    def __next__(self) -> MiniBatch:
        if self._done:
            raise StopIteration
        if self.mode == "device":
            self._pump()
            if not self._pending:
                self._done = True
                raise StopIteration
            mb = self._pending.popleft()
            self._pump()   # dispatch the next batch before the caller executes
            return mb
        while True:
            try:
                item = self.q.get(timeout=0.5)
                break
            except queue.Empty:
                # a worker that died without enqueuing anything (it should
                # always enqueue its exception, but a daemon thread can be
                # torn down mid-put) must surface as an error, not as an
                # iterator that blocks forever
                if self._thread is not None and not self._thread.is_alive():
                    self._done = True
                    raise RuntimeError(
                        "MiniBatchLoader worker thread died without "
                        "reporting a batch or an exception") from None
        if item is self._SENTINEL:
            self._done = True
            raise StopIteration
        if isinstance(item, BaseException):
            # the producer failed on this batch: it enqueued the exception
            # and exited its loop — stop the worker cleanly, then re-raise
            # in the consumer instead of stalling the serving loop
            self._done = True
            self._stop.set()
            self._thread.join(timeout=2)
            raise item
        return item

    def close(self):
        if self.mode == "device":
            self._done = True
            self._pending.clear()
            return
        self._stop.set()
        # drain so a blocked producer can observe the stop flag
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
