"""Prefetching mini-batch loader for sampled RGNN blocks.

Mirrors the queue pattern of ``data/pipeline.py``: a background thread pulls
seed batches from a deterministic stream, runs the fanout sampler, and —
crucially — builds the tile-aligned ``KernelLayouts`` for every block on the
host, off the accelerator path. The consumer (training or serving loop) only
ever dequeues device-ready ``MiniBatch`` bundles, so layout construction
(NumPy segment padding / CSR blocking) overlaps with accelerator compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, List, Optional, Union

import numpy as np
import jax.numpy as jnp

from repro.core import codegen
from repro.core.graph import GraphTensors
from repro.kernels.layout import pow2ceil
from repro.sampling.bucketing import pad_block_graph, pad_index
from repro.sampling.sampler import BlockSequence, FanoutSampler


class SeedStream:
    """Deterministic seed-node request stream: step -> seed ID batch.

    Models a serving request stream (seeds drawn with replacement, so
    duplicate seeds within a batch are exercised). ``batch(step)`` is a pure
    function of (seed, step), the same restart-determinism contract as
    ``SyntheticLMStream``.
    """

    def __init__(self, num_nodes: int, batch_size: int, seed: int = 0):
        self.num_nodes = num_nodes
        self.batch_size = batch_size
        self.seed = seed

    def batch(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        return rng.integers(0, self.num_nodes, size=self.batch_size,
                            dtype=np.int32)


@dataclasses.dataclass
class MiniBatch:
    """Device-ready bundle for one sampled batch: per-hop graph tensors and
    kernel layouts, plus the gather maps that chain hops and restore the
    requested seed order."""

    step: int
    seq: BlockSequence
    tensors: List[GraphTensors]
    layouts: List[codegen.KernelLayouts]
    input_ids: jnp.ndarray          # [n_input] global IDs feeding hop 0
    dst_locals: List[jnp.ndarray]   # per hop: local rows of the out frontier
    seed_perm: jnp.ndarray          # final-frontier row of each seed

    @property
    def num_hops(self) -> int:
        return len(self.tensors)


def build_minibatch(seq: BlockSequence, step: int = 0, tile: int = 128,
                    node_block: int = 128, bucket: bool = False) -> MiniBatch:
    """Host-side assembly of a ``MiniBatch`` from a sampled ``BlockSequence``.

    With ``bucket=True`` (the serving fast path) each block graph, its
    kernel layouts, and every gather-index vector are padded to power-of-two
    buckets, so the compiled-shape set is small and repeated batches run
    from warm compilation caches. Padding is numerically inert: pad
    nodes/edges only feed pad rows, which the hop-chaining gathers never
    read.
    """
    graphs = [b.graph for b in seq.blocks]
    input_ids = seq.input_node_ids
    dst_locals = [b.dst_local for b in seq.blocks]
    if bucket:
        graphs = [pad_block_graph(g) for g in graphs]
        input_ids = pad_index(input_ids, graphs[0].num_nodes)
        # hop l's output rows become hop l+1's (padded) node-feature rows;
        # the last hop only needs to cover the seed gather, so any stable
        # bucket works.
        dst_locals = [
            pad_index(d, graphs[i + 1].num_nodes if i + 1 < len(graphs)
                      else pow2ceil(d.shape[0]))
            for i, d in enumerate(dst_locals)
        ]
    return MiniBatch(
        step=step,
        seq=seq,
        tensors=[g.to_tensors() for g in graphs],
        layouts=[codegen.build_kernel_layouts(g, tile=tile,
                                              node_block=node_block,
                                              bucket=bucket)
                 for g in graphs],
        input_ids=jnp.asarray(input_ids),
        dst_locals=[jnp.asarray(d) for d in dst_locals],
        seed_perm=jnp.asarray(seq.seed_perm),
    )


class MiniBatchLoader:
    """Background-thread prefetch of sampled mini-batches.

    ``seed_source`` is a ``SeedStream`` or any ``step -> np.ndarray``
    callable. Iteration yields ``MiniBatch`` in step order; with
    ``num_batches`` set the loader raises ``StopIteration`` afterwards.
    """

    _SENTINEL = object()

    def __init__(
        self,
        sampler: FanoutSampler,
        seed_source: Union[SeedStream, Callable[[int], np.ndarray]],
        *,
        tile: int = 128,
        node_block: int = 128,
        bucket: bool = False,
        depth: int = 2,
        start_step: int = 0,
        num_batches: Optional[int] = None,
    ):
        self.sampler = sampler
        self._seeds_for = (seed_source.batch if isinstance(seed_source, SeedStream)
                           else seed_source)
        self.tile = tile
        self.node_block = node_block
        self.bucket = bucket
        self.num_batches = num_batches
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = False
        self._stop = threading.Event()
        self._start_step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _build(self, step: int) -> MiniBatch:
        seq = self.sampler.sample(self._seeds_for(step), batch_index=step)
        return build_minibatch(seq, step=step, tile=self.tile,
                               node_block=self.node_block, bucket=self.bucket)

    def _fill(self):
        step = self._start_step
        item = None
        while not self._stop.is_set():
            if item is None:
                if (self.num_batches is not None
                        and step - self._start_step >= self.num_batches):
                    item = self._SENTINEL
                else:
                    try:
                        item = self._build(step)
                    except BaseException as e:  # surface in the consumer
                        item = e
                    step += 1
            try:
                self.q.put(item, timeout=0.5)
            except queue.Full:
                continue
            if item is self._SENTINEL or isinstance(item, BaseException):
                break
            item = None

    def __iter__(self):
        return self

    def __next__(self) -> MiniBatch:
        if self._done:
            raise StopIteration
        item = self.q.get()
        if item is self._SENTINEL:
            self._done = True
            raise StopIteration
        if isinstance(item, BaseException):
            # the producer thread died on this; don't hang the serving loop
            self._done = True
            raise item
        return item

    def close(self):
        self._stop.set()
        # drain so a blocked producer can observe the stop flag
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
