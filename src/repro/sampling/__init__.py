"""Hetero mini-batch sampling: fanout neighbor sampling over ``HeteroGraph``
producing message-flow-graph blocks, plus a prefetching mini-batch loader.

The subsystem turns the full-graph Hector reproduction into a servable
system: the same lowered IR plans and Pallas/XLA kernels run unchanged on
each sampled block, because every block *is* a ``HeteroGraph`` with the
full per-graph preprocessing (etype-sorted edges, dst CSR, compact
materialization map) recomputed on the sampled subgraph.

Two interchangeable sampling pipelines share one determinism contract
(counter-based per-edge keys; see ``sampler.edge_sample_keys``):

* ``FanoutSampler`` — host NumPy sampling + ``build_minibatch`` layouts;
* ``DeviceSampler`` — the same selection and layout build as jit-compiled
  device programs over a device-resident CSC (``device_sampler``).
"""
from repro.sampling.sampler import (  # noqa: F401
    Block,
    BlockSequence,
    FanoutSampler,
)
from repro.sampling.device_sampler import (  # noqa: F401
    DeviceBlock,
    DeviceBlockSequence,
    DeviceSampler,
)
from repro.sampling.loader import (  # noqa: F401
    EpochSeedStream,
    LRUCache,
    MiniBatch,
    MiniBatchLoader,
    SeedStream,
    block_signature,
    build_minibatch,
)
