"""Hetero mini-batch sampling: fanout neighbor sampling over ``HeteroGraph``
producing message-flow-graph blocks, plus a prefetching mini-batch loader.

The subsystem turns the full-graph Hector reproduction into a servable
system: the same lowered IR plans and Pallas/XLA kernels run unchanged on
each sampled block, because every block *is* a ``HeteroGraph`` with the
full per-graph preprocessing (etype-sorted edges, dst CSR, compact
materialization map) recomputed on the sampled subgraph.
"""
from repro.sampling.sampler import (  # noqa: F401
    Block,
    BlockSequence,
    FanoutSampler,
)
from repro.sampling.loader import (  # noqa: F401
    EpochSeedStream,
    LRUCache,
    MiniBatch,
    MiniBatchLoader,
    SeedStream,
    block_signature,
    build_minibatch,
)
