"""Shape bucketing for sampled blocks (the serving fast path).

Every sampled block has fresh (node, edge, unique-pair) counts, so each
mini-batch would otherwise trigger fresh XLA compilations — multi-second
stalls that dwarf the actual forward pass on every request. Bucketing pads
each block graph to power-of-two sizes with *inert* pad structure, so the
set of compiled shapes is logarithmic in graph size and serving hits warm
caches after the first few batches.

Pad structure is numerically invisible to real outputs:

* pad nodes carry the max node type (keeps the presorted-by-type invariant)
  and only appear as endpoints of pad edges;
* pad edges connect pad sources to the first pad node, so they aggregate
  into pad destination rows only;
* pad (src, etype) pairs are chosen distinct until the unique-pair table
  reaches its bucket, then one pair is repeated — giving exact control of
  the compact-materialization table size.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import HeteroGraph
from repro.kernels.layout import pow2ceil


def pad_block_graph(bg: HeteroGraph, n_target: int = 0, e_target: int = 0,
                    u_target: int = 0) -> HeteroGraph:
    """Return ``bg`` padded so nodes/edges/unique-pairs hit power-of-two
    buckets. The first ``bg.num_nodes`` node IDs and all real edges keep
    their meaning; everything is rebuilt via ``from_edges`` so every derived
    product (CSR, compact map, segment pointers) stays consistent.

    ``n_target``/``e_target``/``u_target`` raise the buckets to explicit
    power-of-two sizes (the cross-shard stacking path pads every shard's
    block to the max bucket over shards so the per-hop pytrees stack into
    one ``[P, ...]`` array set); 0 keeps the block's own bucket."""
    n, e, u = bg.num_nodes, bg.num_edges, bg.num_unique
    num_r, num_t = bg.num_etypes, bg.num_ntypes

    u_pad = max(pow2ceil(u + 1), u_target)  # +1: >= 1 pad pair to spend
    k_u = u_pad - u                  # distinct pad (src, etype) pairs needed
    e_pad = max(pow2ceil(e + k_u), e_target)
    k_e = e_pad - e
    n_extra = max(1, -(-k_u // num_r))   # pad sources to host k_u pairs
    n_pad = max(pow2ceil(n + n_extra), n_target)

    # distinct pad pairs first, then repeats of pair 0 up to the edge bucket
    pair_src = (n + np.arange(k_u, dtype=np.int64) // num_r).astype(np.int32)
    pair_et = (np.arange(k_u, dtype=np.int64) % num_r).astype(np.int32)
    pick = np.concatenate([np.arange(k_u, dtype=np.int64),
                           np.zeros(k_e - k_u, dtype=np.int64)])
    pad_src = pair_src[pick]
    pad_et = pair_et[pick]
    pad_dst = np.full(k_e, n, dtype=np.int32)  # all into the first pad node

    node_type = np.concatenate([
        bg.node_type,
        np.full(n_pad - n, num_t - 1, dtype=np.int32),
    ])
    hg = HeteroGraph.from_edges(
        np.concatenate([bg.src, pad_src]),
        np.concatenate([bg.dst, pad_dst]),
        np.concatenate([bg.etype, pad_et]),
        num_nodes=n_pad,
        num_etypes=num_r,
        node_type=node_type,
        num_ntypes=num_t,
    )
    assert hg.num_edges == e_pad and hg.num_unique == u_pad, (
        hg.num_edges, e_pad, hg.num_unique, u_pad)
    return hg


class LayoutRowFloors(dict):
    """Grow-only floors for layout-internal row buckets.

    ``build_kernel_layouts`` pads segment layouts to
    ``pow2ceil(sum_seg ceil(count / tile) * tile)`` — a quantity that moves
    with the *distribution* of edges across segments, not just the padded
    totals, so two blocks with identical (n, e, u) buckets can still land
    in different layout row buckets and retrace. This maps a layout field
    name to the largest row bucket seen; ``raise_to`` is the grow-only
    clamp the layout builder calls per field."""

    def __init__(self, owner=None):
        super().__init__()
        self._owner = owner

    def raise_to(self, name: str, rows: int) -> int:
        cur = self.get(name, 0)
        if rows <= cur:
            return cur
        if name in self and self._owner is not None:
            self._owner.growths += 1
        self[name] = rows
        return rows


class ShapeFloors:
    """Grow-only bucket floors, keyed by (batch key, hop).

    Open-loop serving pads every admitted batch to a ladder rung, but the
    *sampled* block shapes at one rung still jitter across pow2 buckets
    (per-hop node/edge counts land on either side of a bucket boundary),
    so every new bucket combination is a fresh XLA compile — a
    multi-hundred-ms latency spike in the middle of traffic. A
    ``ShapeFloors`` remembers, per key and hop, the largest bucket seen so
    far and pads every later block *up* to it: shapes converge to one
    compiled set per key, and since a floor only ever grows (by whole
    pow2 buckets, so log-many times at most), steady-state retraces reach
    zero instead of recurring forever.

    Single-writer: owned by one loader's producer thread (the serving
    runtime passes a fresh instance per tenant). Callers using a
    sampled-block cache should key it off the same floors epoch or leave
    it disabled — a cached batch replays the shapes it was built under.
    """

    def __init__(self):
        self._graph = {}    # (key, hop) -> [n, e, u] floors
        self._layout = {}   # (key, hop) -> LayoutRowFloors
        self._tail = {}     # key -> final dst_local bucket floor
        self.growths = 0    # floor raises after the first sighting of a key

    def pad_graph(self, key, hop: int, g: HeteroGraph) -> HeteroGraph:
        f = self._graph.get((key, hop))
        hg = pad_block_graph(g, *(f if f is not None else (0, 0, 0)))
        grown = (hg.num_nodes, hg.num_edges, hg.num_unique)
        if f is None:
            self._graph[(key, hop)] = list(grown)
        elif grown != tuple(f):
            self._graph[(key, hop)] = list(grown)
            self.growths += 1
        return hg

    def layout_floors(self, key, hop: int) -> LayoutRowFloors:
        lf = self._layout.get((key, hop))
        if lf is None:
            lf = LayoutRowFloors(self)
            self._layout[(key, hop)] = lf
        return lf

    def pad_tail(self, key, n: int) -> int:
        t = max(self._tail.get(key, 0), pow2ceil(max(1, n)))
        if key in self._tail and t > self._tail[key]:
            self.growths += 1
        self._tail[key] = t
        return t

    def bump(self, levels: int = 1) -> None:
        """Raise every floor by ``levels`` pow2 buckets — headroom so the
        probed maximum is not the compiled ceiling. A serving calibration
        pass probes floors on sampled traffic, bumps once, and thereafter
        a floor growth (i.e. a retrace) needs a batch beyond *double* the
        largest probed bucket."""
        if levels <= 0:
            return
        for f in self._graph.values():
            f[0] <<= levels
            f[1] <<= levels
            f[2] <<= levels
        for lf in self._layout.values():
            for k in lf:
                lf[k] <<= levels
        for k in self._tail:
            self._tail[k] <<= levels


def pad_index(idx: np.ndarray, target: int, fill: int = 0) -> np.ndarray:
    """Pad a gather-index vector to ``target`` entries with a benign index.

    The padded entries gather arbitrary-but-finite rows that only ever feed
    pad positions downstream."""
    extra = target - idx.shape[0]
    if extra < 0:
        raise ValueError("index longer than bucket target")
    if extra == 0:
        return idx
    return np.concatenate([idx, np.full(extra, fill, dtype=idx.dtype)])
