"""Device-native fanout sampler: sample -> layout -> execute without host
NumPy in the steady-state loop.

``DeviceSampler`` reproduces ``FanoutSampler``'s exact selection — both rank
candidate in-edges by the shared counter-based keys of
``sampler.edge_sample_keys`` over the shared destination-sorted candidate
positions, keyed by the same ``hop_base_key(seed, batch_index, hop, epoch)``
— but evaluates it as jit-compiled programs over a device-resident CSC
(``HeteroGraph.to_device_graph``), and builds each block's ``GraphTensors``
*and* ``KernelLayouts`` on device (``kernels/sampling_ops.py``). The
``MiniBatch`` it emits is a drop-in for the host loader's: same pytree
types, same hop chaining, same seed-order restoration.

Shape discipline (the retrace-freeness contract): every per-hop program is
compiled for a static (frontier bucket, fanout, count-bucket) tuple. Stage A
(selection) is shaped by the frontier bucket alone; the stage-B
(compaction + layout) bucket is *predicted*, never read back: each
``(hop, seed bucket, fanout)`` signature starts from its analytic worst
case (``fp * sum(k_eff)`` edges, capped by the graph — always correct), and
one non-blocking drain of past count vectors (``jax.Array.is_ready`` only,
never a blocking wait) shrinks the guess once to one power-of-two step above
the observed counts. The steady-state loop therefore issues **zero**
device->host syncs; ``count_syncs`` / ``bucket_overflows`` /
``bucket_shrinks`` pin that for the ``sample_native`` CI gate, alongside
``trace_count`` / ``cache_hits`` / ``cache_misses`` for retrace-freeness.
A shrunken guess that a later batch outgrows is detected by the same drain
(``bucket_overflows``) and reset to the worst case; the 2x headroom above
the observed counts makes that a monitored never-event in practice.

Prefetch overlap needs no thread: both stages are async-dispatched JAX
computations, so the loader simply dispatches batch k+1's sampling before
the consumer executes batch k — the two pipelines interleave as separate
streams of enqueued device work.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.graph import HeteroGraph
from repro.kernels import sampling_ops as SO
from repro.kernels.layout import pow2ceil
from repro.sampling.sampler import (FanoutSpec, hop_base_key,
                                    normalize_fanout)


@dataclasses.dataclass
class DeviceBlock:
    """Metadata summary of one device-sampled hop (execution order).

    The counts are the static bucket *capacities* (upper bounds on the real
    counts): the sync-free loop never reads the exact counts back, so the
    summary reports what was allocated, not what was filled. Real entries
    are identified in the tensors themselves by the sentinel pads."""

    num_src: int      # node bucket capacity (>= real nodes in the block)
    num_edges: int    # edge bucket capacity (>= real sampled edges)
    num_dst: int      # output-frontier capacity (exact for the seed hop)
    node_ids: jnp.ndarray   # [n_pad] sorted global ids, sentinel N pads


@dataclasses.dataclass
class DeviceBlockSequence:
    """Device-path stand-in for ``sampler.BlockSequence``: carries the seed
    bookkeeping the consumers need (label slicing, per-hop summaries) without
    materializing host ``Block``/``HeteroGraph`` objects."""

    blocks: List[DeviceBlock]
    seeds: np.ndarray       # requested seed IDs, order and dupes preserved
    num_nodes: int          # full-graph N (the pad sentinel)

    @property
    def num_hops(self) -> int:
        return len(self.blocks)

    def slice_labels(self, labels: np.ndarray) -> np.ndarray:
        """Labels aligned with the block forward's output (one row per
        requested seed, in request order) — same contract as the host."""
        return np.asarray(labels)[self.seeds]

    def describe(self) -> str:
        lines = [f"DeviceBlockSequence(seeds={len(self.seeds)})"]
        for i, b in enumerate(self.blocks):
            lines.append(f"  hop {i}: {b.num_src} nodes -> {b.num_dst} dst, "
                         f"{b.num_edges} edges (device)")
        return "\n".join(lines)


class DeviceSampler:
    """Jit-compiled fanout sampling + layout build over a device CSC.

    ``sample_minibatch`` is the whole device pipeline for one batch; the
    loader's ``backend="device"`` path calls it instead of
    ``FanoutSampler.sample`` + ``build_minibatch``.
    """

    def __init__(self, hg: HeteroGraph, fanouts: Sequence[FanoutSpec],
                 seed: int = 0, *, tile: int = 32, node_block: int = 32,
                 backend: str = "xla"):
        if not fanouts:
            raise ValueError("need at least one hop fanout")
        if tile & (tile - 1):
            raise ValueError("device sampling needs a power-of-two tile")
        if hg.num_edges == 0:
            raise ValueError("device sampling needs a graph with edges")
        self.hg = hg
        self.dg = hg.to_device_graph()
        self.fanouts = [normalize_fanout(f, hg.num_etypes) for f in fanouts]
        self.seed = seed
        self.tile = tile
        self.node_block = node_block
        # keys are Pallas-kernel-generated off the XLA backends' default;
        # selection/compaction are XLA sorts either way
        self.key_backend = "xla" if backend == "xla" else "pallas_interpret" \
            if backend == "pallas_interpret" else "pallas"
        self._k_eff = [SO.effective_fanouts(f, self.dg.max_bin)
                       for f in self.fanouts]
        self._jit = {}
        self.trace_count = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.batches_sampled = 0
        # sync-free bucket speculation: per-(hop, frontier bucket, fanout)
        # stage-B bucket guesses, plus the queue of not-yet-inspected count
        # vectors (drained only when already resident on host)
        self._guess = {}          # sig -> (n_pad, e_pad, u_pad)
        self._shrunk = set()      # sigs whose guess already tightened once
        self._pending = collections.deque()  # (sig, used_buckets, counts)
        self.count_syncs = 0
        self.bucket_overflows = 0
        self.bucket_shrinks = 0

    @property
    def num_hops(self) -> int:
        return len(self.fanouts)

    # ------------------------------------------------------------------
    def _compiled(self, key, factory):
        """Explicit jit cache keyed by the static bucket tuple, with trace
        counting *inside* the traced function (the executor idiom): a cache
        hit that somehow retraced would still be counted."""
        fn = self._jit.get(key)
        if fn is None:
            self.cache_misses += 1
            inner = factory()

            def counted(*args, _inner=inner):
                self.trace_count += 1
                obs.metrics().counter("sampler_traces").inc()
                return _inner(*args)

            fn = jax.jit(counted)
            self._jit[key] = fn
        else:
            self.cache_hits += 1
        return fn

    def _bucket(self, count: int) -> int:
        return max(self.tile, pow2ceil(count + 1))

    def _worst_buckets(self, fp: int, k_eff) -> tuple:
        """Analytic stage-B buckets that can never overflow: ``fp`` frontier
        rows each select at most ``sum(k_eff)`` edges (capped by the graph's
        edge count), the union frontier adds at most one node per edge on
        top of the frontier itself (capped by N), and the unique
        (src, etype) pairs are at most the edges."""
        ksum = max(1, int(sum(k_eff)))
        e_w = min(self.hg.num_edges, fp * ksum)
        n_w = min(self.hg.num_nodes, fp + e_w)
        return (self._bucket(n_w), self._bucket(e_w), self._bucket(e_w))

    def drain(self, block: bool = False) -> None:
        """Inspect finished stage-A count vectors and tighten bucket
        guesses. Non-blocking by default — only counts already resident on
        the host (``is_ready``) are read, so the sampling loop stays
        sync-free. ``block=True`` waits for everything outstanding (a
        warmup barrier for benchmarks/tests; each forced wait counts as a
        ``count_syncs`` readback)."""
        while self._pending:
            sig, fp, used, counts = self._pending[0]
            if not counts.is_ready():
                if not block:
                    return
                self.count_syncs += 1
            self._pending.popleft()
            got = tuple(int(x) for x in np.asarray(counts))
            if any(c + 1 > b for c, b in zip(got, used)):
                # a shrunken bucket truncated this batch: report it and
                # fall back to the always-correct worst case
                self.bucket_overflows += 1
                self._guess[sig] = self._worst_buckets(fp, sig[2])
                self._shrunk.discard(sig)
                continue
            if sig in self._shrunk:
                continue
            worst = self._worst_buckets(fp, sig[2])
            # one pow2 step of headroom above the first observed counts;
            # shrink once per signature so steady state never re-buckets
            new = tuple(min(w, 2 * self._bucket(c))
                        for c, w in zip(got, worst))
            if new != self._guess.get(sig, worst):
                self._guess[sig] = new
                self.bucket_shrinks += 1
            self._shrunk.add(sig)

    # ------------------------------------------------------------------
    def sample_minibatch(self, seeds: np.ndarray, batch_index: int = 0,
                         epoch: Optional[int] = None, step: int = 0):
        """Sample + build one device-resident ``MiniBatch``.

        Randomness is keyed identically to the host sampler —
        ``hop_base_key(seed, batch_index, hop, epoch)`` — so the two paths
        select the same edge multisets for the same stream position.
        """
        from repro.sampling.loader import MiniBatch  # local: avoid cycle

        seeds = np.asarray(seeds, dtype=np.int32)
        if seeds.ndim != 1 or seeds.size == 0:
            raise ValueError("seeds must be a non-empty 1-D int array")
        if seeds.min() < 0 or seeds.max() >= self.hg.num_nodes:
            raise ValueError("seed node id out of range")
        dg = self.dg
        nhops = self.num_hops
        b = int(seeds.shape[0])
        f0 = pow2ceil(b)

        prep = self._compiled(
            ("prep", b, f0),
            lambda: SO.make_prep_seeds(dg.num_nodes, f0))
        frontier, seed_perm = prep(jnp.asarray(seeds))

        self.drain()      # non-blocking: fold in any finished count vectors

        hops = []         # sampling order (outermost first)
        num_dst = [None] * nhops
        prev_cap = None   # node capacity of the previous hop's union
        for hop in range(nhops):
            k_eff = self._k_eff[nhops - 1 - hop]
            kmax = max(1, max(k_eff))
            fp = int(frontier.shape[0])
            base = jnp.asarray(
                hop_base_key(self.seed, int(batch_index), hop, epoch))
            with obs.span("sample_device", step=step, hop=hop):
                fn_a = self._compiled(
                    ("A", fp, k_eff, self.key_backend),
                    lambda k_eff=k_eff, fp=fp: SO.make_sample_hop(
                        dg, k_eff, fp, self.key_backend))
                union, sel_src, sel_valid, counts = fn_a(
                    dg.csc_indptr, dg.csc_src, frontier, base)
            # sync-free bucket pick: use the signature's current guess
            # (worst case until a drained count vector tightened it) and
            # queue the counts for a later non-blocking inspection. The
            # signature carries the *seed* bucket, not the frontier bucket:
            # real counts are invariant to padding, so a guess learned
            # before an earlier hop shrank its frontier stays valid after.
            sig = (hop, f0, k_eff)
            guess = self._guess.setdefault(
                sig, self._worst_buckets(fp, k_eff))
            n_pad, e_pad, u_pad = guess
            self._pending.append((sig, fp, guess, counts))
            with obs.span("layout_device", step=step, hop=hop):
                fn_b = self._compiled(
                    ("B", fp, kmax, n_pad, e_pad, u_pad),
                    lambda fp=fp, kmax=kmax, n_pad=n_pad, e_pad=e_pad,
                    u_pad=u_pad: SO.make_build_block(
                        dg, fp, kmax, n_pad, e_pad, u_pad,
                        self.tile, self.node_block))
                gt, kl, node_ids, dst_local, input_gather = fn_b(
                    union, sel_src, sel_valid, frontier, dg.node_type)
            hops.append(dict(gt=gt, kl=kl, node_ids=node_ids,
                             dst_local=dst_local, input_gather=input_gather,
                             num_src=n_pad, num_edges=e_pad))
            num_dst[hop] = prev_cap if prev_cap is not None else None
            prev_cap = n_pad
            frontier = node_ids

        # execution order: innermost (last sampled) hop first
        hops.reverse()
        num_dst.reverse()
        blocks = [DeviceBlock(num_src=h["num_src"], num_edges=h["num_edges"],
                              num_dst=(d if d is not None
                                       else int(np.unique(seeds).size)),
                              node_ids=h["node_ids"])
                  for h, d in zip(hops, num_dst)]
        seq = DeviceBlockSequence(blocks=blocks, seeds=seeds,
                                  num_nodes=self.hg.num_nodes)
        self.batches_sampled += 1
        return MiniBatch(
            step=step,
            seq=seq,
            tensors=[h["gt"] for h in hops],
            layouts=[h["kl"] for h in hops],
            input_ids=hops[0]["input_gather"],
            dst_locals=[h["dst_local"] for h in hops],
            seed_perm=seed_perm,
        )

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "batches_sampled": self.batches_sampled,
            "trace_count": self.trace_count,
            "jit_cache_hits": self.cache_hits,
            "jit_cache_misses": self.cache_misses,
            "compiled_programs": len(self._jit),
            "count_syncs": self.count_syncs,
            "bucket_overflows": self.bucket_overflows,
            "bucket_shrinks": self.bucket_shrinks,
            "pending_counts": len(self._pending),
        }
