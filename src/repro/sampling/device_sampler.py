"""Device-native fanout sampler: sample -> layout -> execute without host
NumPy in the steady-state loop.

``DeviceSampler`` reproduces ``FanoutSampler``'s exact selection — both rank
candidate in-edges by the shared counter-based keys of
``sampler.edge_sample_keys`` over the shared destination-sorted candidate
positions, keyed by the same ``hop_base_key(seed, batch_index, hop, epoch)``
— but evaluates it as jit-compiled programs over a device-resident CSC
(``HeteroGraph.to_device_graph``), and builds each block's ``GraphTensors``
*and* ``KernelLayouts`` on device (``kernels/sampling_ops.py``). The
``MiniBatch`` it emits is a drop-in for the host loader's: same pytree
types, same hop chaining, same seed-order restoration.

Shape discipline (the retrace-freeness contract): every per-hop program is
compiled for a static (frontier bucket, fanout, count-bucket) tuple. Stage A
(selection) is shaped by the frontier bucket alone; the host reads back one
3-vector of counts per hop — the only device->host sync — and rounds them to
power-of-two buckets that select the stage-B (compaction + layout) program.
Recurring traffic recurs over a small bucket set, so after warmup every
batch replays already-traced programs; ``trace_count`` / ``cache_hits`` /
``cache_misses`` expose that for the ``sample_native`` CI gate.

Prefetch overlap needs no thread: both stages are async-dispatched JAX
computations, so the loader simply dispatches batch k+1's sampling before
the consumer executes batch k — the two pipelines interleave as separate
streams of enqueued device work.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.graph import HeteroGraph
from repro.kernels import sampling_ops as SO
from repro.kernels.layout import pow2ceil
from repro.sampling.sampler import (FanoutSpec, hop_base_key,
                                    normalize_fanout)


@dataclasses.dataclass
class DeviceBlock:
    """Metadata summary of one device-sampled hop (execution order)."""

    num_src: int      # real (unpadded) nodes in the block
    num_edges: int    # real sampled edges
    num_dst: int      # real output-frontier nodes
    node_ids: jnp.ndarray   # [n_pad] sorted global ids, sentinel N pads


@dataclasses.dataclass
class DeviceBlockSequence:
    """Device-path stand-in for ``sampler.BlockSequence``: carries the seed
    bookkeeping the consumers need (label slicing, per-hop summaries) without
    materializing host ``Block``/``HeteroGraph`` objects."""

    blocks: List[DeviceBlock]
    seeds: np.ndarray       # requested seed IDs, order and dupes preserved
    num_nodes: int          # full-graph N (the pad sentinel)

    @property
    def num_hops(self) -> int:
        return len(self.blocks)

    def slice_labels(self, labels: np.ndarray) -> np.ndarray:
        """Labels aligned with the block forward's output (one row per
        requested seed, in request order) — same contract as the host."""
        return np.asarray(labels)[self.seeds]

    def describe(self) -> str:
        lines = [f"DeviceBlockSequence(seeds={len(self.seeds)})"]
        for i, b in enumerate(self.blocks):
            lines.append(f"  hop {i}: {b.num_src} nodes -> {b.num_dst} dst, "
                         f"{b.num_edges} edges (device)")
        return "\n".join(lines)


class DeviceSampler:
    """Jit-compiled fanout sampling + layout build over a device CSC.

    ``sample_minibatch`` is the whole device pipeline for one batch; the
    loader's ``backend="device"`` path calls it instead of
    ``FanoutSampler.sample`` + ``build_minibatch``.
    """

    def __init__(self, hg: HeteroGraph, fanouts: Sequence[FanoutSpec],
                 seed: int = 0, *, tile: int = 32, node_block: int = 32,
                 backend: str = "xla"):
        if not fanouts:
            raise ValueError("need at least one hop fanout")
        if tile & (tile - 1):
            raise ValueError("device sampling needs a power-of-two tile")
        if hg.num_edges == 0:
            raise ValueError("device sampling needs a graph with edges")
        self.hg = hg
        self.dg = hg.to_device_graph()
        self.fanouts = [normalize_fanout(f, hg.num_etypes) for f in fanouts]
        self.seed = seed
        self.tile = tile
        self.node_block = node_block
        # keys are Pallas-kernel-generated off the XLA backends' default;
        # selection/compaction are XLA sorts either way
        self.key_backend = "xla" if backend == "xla" else "pallas_interpret" \
            if backend == "pallas_interpret" else "pallas"
        self._k_eff = [SO.effective_fanouts(f, self.dg.max_bin)
                       for f in self.fanouts]
        self._jit = {}
        self.trace_count = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.batches_sampled = 0

    @property
    def num_hops(self) -> int:
        return len(self.fanouts)

    # ------------------------------------------------------------------
    def _compiled(self, key, factory):
        """Explicit jit cache keyed by the static bucket tuple, with trace
        counting *inside* the traced function (the executor idiom): a cache
        hit that somehow retraced would still be counted."""
        fn = self._jit.get(key)
        if fn is None:
            self.cache_misses += 1
            inner = factory()

            def counted(*args, _inner=inner):
                self.trace_count += 1
                obs.metrics().counter("sampler_traces").inc()
                return _inner(*args)

            fn = jax.jit(counted)
            self._jit[key] = fn
        else:
            self.cache_hits += 1
        return fn

    def _bucket(self, count: int) -> int:
        return max(self.tile, pow2ceil(count + 1))

    # ------------------------------------------------------------------
    def sample_minibatch(self, seeds: np.ndarray, batch_index: int = 0,
                         epoch: Optional[int] = None, step: int = 0):
        """Sample + build one device-resident ``MiniBatch``.

        Randomness is keyed identically to the host sampler —
        ``hop_base_key(seed, batch_index, hop, epoch)`` — so the two paths
        select the same edge multisets for the same stream position.
        """
        from repro.sampling.loader import MiniBatch  # local: avoid cycle

        seeds = np.asarray(seeds, dtype=np.int32)
        if seeds.ndim != 1 or seeds.size == 0:
            raise ValueError("seeds must be a non-empty 1-D int array")
        if seeds.min() < 0 or seeds.max() >= self.hg.num_nodes:
            raise ValueError("seed node id out of range")
        dg = self.dg
        nhops = self.num_hops
        b = int(seeds.shape[0])
        f0 = pow2ceil(b)

        prep = self._compiled(
            ("prep", b, f0),
            lambda: SO.make_prep_seeds(dg.num_nodes, f0))
        frontier, seed_perm = prep(jnp.asarray(seeds))

        hops = []         # sampling order (outermost first)
        num_dst = [None] * nhops
        prev_real = None  # real node count of the previous hop's union
        for hop in range(nhops):
            k_eff = self._k_eff[nhops - 1 - hop]
            kmax = max(1, max(k_eff))
            fp = int(frontier.shape[0])
            base = jnp.asarray(
                hop_base_key(self.seed, int(batch_index), hop, epoch))
            with obs.span("sample_device", step=step, hop=hop):
                fn_a = self._compiled(
                    ("A", fp, k_eff, self.key_backend),
                    lambda k_eff=k_eff, fp=fp: SO.make_sample_hop(
                        dg, k_eff, fp, self.key_backend))
                union, sel_src, sel_valid, counts = fn_a(
                    dg.csc_indptr, dg.csc_src, frontier, base)
                # the loop's only device->host sync: three ints that pick
                # the next static bucket (integer rounding, not layout work)
                n_next, e_cnt, u_cnt = (int(x) for x in np.asarray(counts))
            n_pad = self._bucket(n_next)
            e_pad = self._bucket(e_cnt)
            u_pad = self._bucket(u_cnt)
            with obs.span("layout_device", step=step, hop=hop):
                fn_b = self._compiled(
                    ("B", fp, kmax, n_pad, e_pad, u_pad),
                    lambda fp=fp, kmax=kmax, n_pad=n_pad, e_pad=e_pad,
                    u_pad=u_pad: SO.make_build_block(
                        dg, fp, kmax, n_pad, e_pad, u_pad,
                        self.tile, self.node_block))
                gt, kl, node_ids, dst_local, input_gather = fn_b(
                    union, sel_src, sel_valid, frontier, dg.node_type)
            hops.append(dict(gt=gt, kl=kl, node_ids=node_ids,
                             dst_local=dst_local, input_gather=input_gather,
                             num_src=n_next, num_edges=e_cnt))
            num_dst[hop] = prev_real if prev_real is not None else None
            prev_real = n_next
            frontier = node_ids

        # execution order: innermost (last sampled) hop first
        hops.reverse()
        num_dst.reverse()
        blocks = [DeviceBlock(num_src=h["num_src"], num_edges=h["num_edges"],
                              num_dst=(d if d is not None
                                       else int(np.unique(seeds).size)),
                              node_ids=h["node_ids"])
                  for h, d in zip(hops, num_dst)]
        seq = DeviceBlockSequence(blocks=blocks, seeds=seeds,
                                  num_nodes=self.hg.num_nodes)
        self.batches_sampled += 1
        return MiniBatch(
            step=step,
            seq=seq,
            tensors=[h["gt"] for h in hops],
            layouts=[h["kl"] for h in hops],
            input_ids=hops[0]["input_gather"],
            dst_locals=[h["dst_local"] for h in hops],
            seed_perm=seed_perm,
        )

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "batches_sampled": self.batches_sampled,
            "trace_count": self.trace_count,
            "jit_cache_hits": self.cache_hits,
            "jit_cache_misses": self.cache_misses,
            "compiled_programs": len(self._jit),
        }
