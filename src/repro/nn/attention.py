"""GQA attention with qk-norm, logit softcap, sliding windows, cross-attention
and KV-cache decode. Grouped einsums keep the KV heads un-replicated (no
[B,S,H,hd] materialization — the GQA memory saving is the point of GQA).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.lm.config import LMConfig, LayerSpec
from repro.nn.common import (dense_init, mesh_ctx, rms_norm, rope,
                              rp_einsum, shard, softcap)


def init_attention(key, cfg: LMConfig, dtype, cross: bool = False) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype),
        "wk": dense_init(ks[1], (d, kv, hd), dtype),
        "wv": dense_init(ks[2], (d, kv, hd), dtype),
        "wo": dense_init(ks[3], (h, hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _mask(q_pos, k_pos, window: Optional[int], causal: bool):
    """[Q, S] boolean mask (True = attend). Positions are 1-D and shared
    across the batch — a [B,Q,S] mask would be carried through the layer
    scan (measured: 2.6 GiB/device at 4k train)."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def attention(
    params: Dict,
    x: jnp.ndarray,                 # [B, Q, D]
    cfg: LMConfig,
    spec: LayerSpec,
    q_positions: jnp.ndarray,       # [Q] (shared across batch)
    *,
    memory: Optional[jnp.ndarray] = None,      # cross-attn K/V source [B, M, D]
    cross_kv: Optional[Dict] = None,           # cached cross K/V (decode)
    store_cross: bool = False,                 # prefill: emit cross K/V cache
    kv_cache: Optional[Dict] = None,           # {"k","v": [B, S, KV, hd]}
    cache_index: Optional[jnp.ndarray] = None, # scalar write position
    causal: bool = True,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    h, kv_heads, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b, q_len, _ = x.shape
    is_cross = memory is not None or cross_kv is not None

    q = jnp.einsum("bqd,dhk->bqhk", x, params["wq"])
    if cross_kv is not None:
        # cached cross-attention K/V: the encoder/frontend memory is static,
        # so decode never recomputes (or re-encodes) it — §Perf v-G
        k, v = cross_kv["k"], cross_kv["v"]
    else:
        kv_src = memory if memory is not None else x
        k = jnp.einsum("bsd,dhk->bshk", kv_src, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_src, params["wv"])

    if cfg.qk_norm and "q_norm" in params:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if not is_cross:
        q = rope(q, q_positions, cfg.rope_theta)
        k = rope(k, q_positions, cfg.rope_theta)

    new_cache = None
    if is_cross and store_cross:
        new_cache = {"k": k, "v": v}
    if kv_cache is not None and not is_cross:
        # write current K/V at cache_index, attend over the whole cache
        kc = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, cache_index, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": kc, "v": vc}
        k, v = kc, vc
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    elif is_cross:
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    else:
        k_pos = q_positions

    # NOTE: no explicit sharding constraint on k/v here — the cache input
    # shardings (decode) and wk/wv weight shardings (train/prefill) propagate;
    # an explicit constraint was measured to force involuntary SPMD remat.

    # v-C: sequence-sharded KV cache decode — partial softmax per sequence
    # shard combined with an O(B·H·hd) psum instead of the O(B·H·S)
    # partial-score all-reduce of the head_dim-sharded baseline.
    ctx = mesh_ctx()
    if (
        kv_cache is not None and q_len == 1
        and ctx is not None and getattr(ctx, "seq_shard_kv_decode", False)
        and k.shape[1] % ctx.tp == 0
    ):
        out = _seqshard_decode_attention(
            q, k, v, q_positions, spec.window, cfg, ctx)
        out = rp_einsum("bqhk,hkd->bqd", out, params["wo"])
        return out, new_cache

    # grouped-query attention without replicating KV heads
    g = h // kv_heads
    qg = q.reshape(b, q_len, kv_heads, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = softcap(scores, cfg.attn_softcap)

    mask = _mask(q_positions, k_pos, spec.window,
                 causal and not is_cross)          # [Q, S]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    out = out.reshape(b, q_len, h, hd)
    out = shard("attn_out_heads", out)
    out = rp_einsum("bqhk,hkd->bqd", out, params["wo"])
    return out, new_cache


def _seqshard_decode_attention(q, k, v, q_positions, window, cfg, ctx):
    """One-token attention over a sequence-sharded KV cache.

    Each model-axis member computes softmax stats over its local S/tp slice;
    a flash-style (max, numerator, denominator) combine then runs as a tiny
    psum across the axis. Collective wire per layer: O(B·H·hd) instead of
    the baseline's O(B·H·S) partial-score all-reduce (EXPERIMENTS §Perf v-C).
    """
    b, _, h, hd = q.shape
    kv_heads = k.shape[2]
    g = h // kv_heads
    ax = ctx.tp_axis
    dpb = ctx.batch_dims(b)
    bspec = dpb if dpb is not None else None

    qspec = P(bspec, None, None, None)
    kspec = P(bspec, ax, None, None)
    pspec = P(None)

    def body(q_l, k_l, v_l, q_pos):
        bl, _, _, _ = q_l.shape
        s_l = k_l.shape[1]
        shard_i = jax.lax.axis_index(ax)
        k_pos = shard_i * s_l + jnp.arange(s_l, dtype=jnp.int32)
        qg = q_l.reshape(bl, 1, kv_heads, g, hd)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_l).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(hd))
        scores = softcap(scores, cfg.attn_softcap)
        valid = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            valid &= k_pos[None, :] > (q_pos[:, None] - window)
        scores = jnp.where(valid[None, None, None], scores, -1e30)
        m_l = jnp.max(scores, axis=-1)                       # [b,kv,g,1]
        p = jnp.exp(scores - m_l[..., None])
        num_l = jnp.einsum("bkgqs,bskd->bkgqd", p, v_l.astype(jnp.float32))
        den_l = jnp.sum(p, axis=-1)
        m_g = jax.lax.pmax(m_l, ax)
        corr = jnp.exp(m_l - m_g)
        num = jax.lax.psum(num_l * corr[..., None], ax)
        den = jax.lax.psum(den_l * corr, ax)
        out = num / jnp.maximum(den, 1e-38)[..., None]       # [b,kv,g,1,hd]
        out = out.transpose(0, 3, 1, 2, 4).reshape(bl, 1, h, hd)
        return out.astype(q_l.dtype)

    fn = shard_map(body, mesh=ctx.mesh,
                   in_specs=(qspec, kspec, kspec, pspec),
                   out_specs=qspec, check_vma=False)
    return fn(q, k, v, q_positions)
