"""Mamba2 layer via the SSD (state-space duality) chunked algorithm
(Dao & Gu, arXiv:2405.21060), pure JAX.

Recurrence (per head h, state n, head-dim p):
    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t + D · x_t

The chunked form computes, per chunk of Q tokens, an intra-chunk quadratic
"attention-like" term (MXU-friendly batched GEMMs) plus an inter-chunk
recurrence over chunk states (lax.scan over l/Q steps) — this is the
TPU-native mapping: the quadratic term saturates the MXU while the scan
carries only [b,h,p,n] states.

``ssd_sequential`` is the step-by-step oracle used by tests.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.lm.config import LMConfig
from repro.nn.common import dense_init, rms_norm, shard


def init_mamba(key, cfg: LMConfig, dtype) -> Dict:
    """Input projections are kept as separate matrices (z / x / BC / dt)
    rather than one packed [D, 2*din+2g*ns+nh] matrix: TP then shards each
    output dim cleanly with no mid-shard slice boundaries (DESIGN.md §5)."""
    d = cfg.d_model
    din, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    g, kk = cfg.ssm_groups, cfg.ssm_conv
    ks = jax.random.split(key, 7)
    return {
        "wi_z": dense_init(ks[6], (d, din), dtype),
        "wi_x": dense_init(ks[1], (d, din), dtype),
        "wi_bc": dense_init(ks[2], (d, 2 * g * ns), dtype),
        "wi_dt": dense_init(ks[3], (d, nh), dtype),
        "conv_w_x": dense_init(ks[4], (kk, din), dtype, fan_in=kk),
        "conv_w_bc": dense_init(ks[5], (kk, 2 * g * ns), dtype, fan_in=kk),
        "conv_b_x": jnp.zeros((din,), dtype),
        "conv_b_bc": jnp.zeros((2 * g * ns,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.ones((din,), dtype),
        "wo": dense_init(ks[0], (din, d), dtype, fan_in=din),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time. xbc: [B, L, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):  # K is 4: unrolled taps, XLA fuses
        out = out + pad[:, i : i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def ssd_chunked(
    xh: jnp.ndarray,    # [b, l, h, p]
    dt: jnp.ndarray,    # [b, l, h]  (post-softplus)
    a: jnp.ndarray,     # [h]        (negative)
    bm: jnp.ndarray,    # [b, l, h, n]  (already expanded over heads)
    cm: jnp.ndarray,    # [b, l, h, n]
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,   # [b, h, p, n]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, l, h, p = xh.shape
    n = bm.shape[-1]
    pad = (-l) % chunk
    if pad:
        # zero-pad the tail; dt = 0 makes padded steps identity state
        # updates (exp(0)=1 decay, zero input contribution)
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xh, dt, bm, cm = zp(xh), zp(dt), zp(bm), zp(cm)
        y, state = ssd_chunked(xh, dt, a, bm, cm, chunk, init_state)
        return y[:, :l], state
    c, q = l // chunk, chunk
    f32 = jnp.float32
    x_ = xh.reshape(b, c, q, h, p).astype(f32)
    dt_ = dt.reshape(b, c, q, h).astype(f32)
    b_ = bm.reshape(b, c, q, h, n).astype(f32)
    c_ = cm.reshape(b, c, q, h, n).astype(f32)

    da = dt_ * a.astype(f32)                      # [b,c,q,h]
    da_cs = jnp.cumsum(da, axis=2)                # inclusive cumsum

    # intra-chunk (quadratic, MXU): L[i,j] = exp(cs_i - cs_j) for i >= j
    diff = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]   # [b,c,i,j,h]
    tri = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    decay = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", c_, b_)
    m = scores * decay * dt_[:, :, None, :, :]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", m, x_)

    # chunk-final states: S_c = Σ_j exp(cs_Q - cs_j) dt_j B_j ⊗ x_j
    decay_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)           # [b,c,q,h]
    s_c = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", dt_ * decay_end, b_, x_)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                  # [b,c,h]

    # inter-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), f32)

    def step(state, inp):
        s_chunk, cd = inp                         # [b,h,p,n], [b,h]
        out_state = state * cd[:, :, None, None] + s_chunk
        return out_state, state                   # emit state *entering* chunk

    final_state, states_in = jax.lax.scan(
        step,
        init_state.astype(f32),
        (jnp.moveaxis(s_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    states_in = jnp.moveaxis(states_in, 0, 1)     # [b,c,h,p,n]

    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", c_, states_in,
                       jnp.exp(da_cs))
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y.astype(xh.dtype), final_state


def ssd_sequential(xh, dt, a, bm, cm, init_state=None):
    """Step-by-step oracle for tests."""
    b, l, h, p = xh.shape
    n = bm.shape[-1]
    f32 = jnp.float32
    state = (jnp.zeros((b, h, p, n), f32) if init_state is None
             else init_state.astype(f32))

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp                 # [b,h,p], [b,h], [b,h,n] x2
        da = jnp.exp(dt_t * a)[:, :, None, None]
        state = state * da + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt_t, b_t, x_t)
        y_t = jnp.einsum("bhn,bhpn->bhp", c_t, state)
        return state, y_t

    xs = (jnp.moveaxis(xh.astype(f32), 1, 0), jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(bm.astype(f32), 1, 0), jnp.moveaxis(cm.astype(f32), 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype), state


# ---------------------------------------------------------------------------
# full Mamba2 layer
# ---------------------------------------------------------------------------
def mamba_forward(
    params: Dict,
    x: jnp.ndarray,                # [B, L, D]
    cfg: LMConfig,
    cache: Optional[Dict] = None,  # {"conv": [B,K-1,C], "state": [B,h,p,n]}
    return_cache: bool = False,    # prefill: emit decode-ready cache
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    din, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    g, hd, kk = cfg.ssm_groups, cfg.ssm_head_dim, cfg.ssm_conv
    bsz, l, _ = x.shape
    z = x @ params["wi_z"]
    xin = x @ params["wi_x"]
    bc = x @ params["wi_bc"]
    dt = x @ params["wi_dt"]

    new_cache = None
    if cache is None:
        conv_tail = jnp.concatenate([xin, bc], -1)[:, -(kk - 1):]
        xin = _causal_conv(xin, params["conv_w_x"], params["conv_b_x"])
        bc = _causal_conv(bc, params["conv_w_bc"], params["conv_b_bc"])
        init_state = None
    else:
        # decode: single token, rolling conv window + recurrent state
        assert l == 1
        cur = jnp.concatenate([xin, bc], -1)
        window = jnp.concatenate([cache["conv"], cur], axis=1)   # [B, K, C]
        conv_w = jnp.concatenate(
            [params["conv_w_x"], params["conv_w_bc"]], -1).astype(jnp.float32)
        conv_b = jnp.concatenate(
            [params["conv_b_x"], params["conv_b_bc"]], -1).astype(jnp.float32)
        conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), conv_w)
        conv_out = jax.nn.silu(conv_out + conv_b)[:, None, :].astype(x.dtype)
        xin, bc = conv_out[..., :din], conv_out[..., din:]
        new_conv = window[:, 1:]
        init_state = cache["state"]

    bmat = bc[..., : g * ns].reshape(bsz, l, g, ns)
    cmat = bc[..., g * ns :].reshape(bsz, l, g, ns)
    heads_per_group = nh // g
    bmat = jnp.repeat(bmat, heads_per_group, axis=2)
    cmat = jnp.repeat(cmat, heads_per_group, axis=2)

    xh = xin.reshape(bsz, l, nh, hd)
    xh = shard("ssm_heads", xh)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])

    if cache is None:
        y, final_state = ssd_chunked(xh, dt, a, bmat, cmat, cfg.ssm_chunk)
    else:
        y, final_state = ssd_sequential(xh, dt, a, bmat, cmat, init_state)
        new_cache = {"conv": new_conv, "state": final_state}

    y = y + (params["D_skip"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(bsz, l, din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["gate_norm"], cfg.norm_eps)
    out = y @ params["wo"]
    if cache is None and return_cache:
        new_cache = {"conv": conv_tail, "state": final_state}
    return out, new_cache
