"""Mixture-of-Experts FFN — the Hector GEMM template as an LM feature.

The expert layer **is** an edgewise typed linear layer in the paper's sense
(DESIGN.md §4): tokens = edges, experts = edge types, router = type
assignment, gate = the fused per-row scalar, capacity padding = tile-aligned
segments. The jit path below is the capacity-bucketed segment-MM formulation
(static shapes, EP/TP-shardable batched GEMM whose FLOPs equal the *routed*
compute, not the E/k× dense-masked blowup); on real TPU hardware the same
routed layout feeds ``kernels/segment_mm.py``.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.nn.common import dense_init, mesh_ctx, shard


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, num_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (num_experts, d_model, d_ff), dtype),
        "w_up": dense_init(ks[2], (num_experts, d_model, d_ff), dtype),
        "w_down": dense_init(ks[3], (num_experts, d_ff, d_model), dtype,
                             fan_in=d_ff),
    }


def capacity(tokens: int, num_experts: int, k: int, factor: float,
             multiple: int = 8) -> int:
    c = math.ceil(tokens * k * factor / num_experts)
    return max(multiple, ((c + multiple - 1) // multiple) * multiple)


def moe_ffn(
    params: Dict,
    x: jnp.ndarray,                # [B, S, D]
    num_experts: int,
    k: int,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, Dict]:
    """Returns (output [B,S,D], aux metrics {load_balance_loss, dropped}).

    When the active sharding context enables EP (v-B) and the mesh divides
    the expert count, dispatch goes through the shard_map all-to-all path:
    routing/positions stay LOCAL per data shard (no cross-device cumsum) and
    only the capacity-bounded dispatch buffer rides the wire. The GSPMD
    dense-dispatch fallback below was measured at 37.6 TB/device/step of
    involuntary collectives on moonshot train_4k (EXPERIMENTS §Perf)."""
    ctx = mesh_ctx()
    if (ctx is not None and getattr(ctx, "moe_ep", False)
            and (num_experts % ctx.tp == 0 or ctx.tp % num_experts == 0)
            and ctx.batch_dims(x.shape[0]) is not None):
        return _moe_ffn_ep(params, x, num_experts, k, capacity_factor, ctx)
    return _moe_ffn_dense(params, x, num_experts, k, capacity_factor)


def _moe_ffn_dense(params, x, num_experts, k, capacity_factor):
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    e = num_experts
    cap = capacity(t, e, k, capacity_factor)

    logits = (xf.astype(jnp.float32) @ params["router"])        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                          # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, expert-slot) within its expert's capacity:
    # cumulative count over the token-major order (deterministic drop policy)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)             # [T, k, E]
    slot_flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(slot_flat, axis=0) - slot_flat              # [T*k, E]
    pos = jnp.sum(pos * slot_flat, axis=-1).reshape(t, k)        # [T, k]
    keep = pos < cap
    dropped = 1.0 - keep.mean()

    # dispatch: scatter kept rows into the [E, cap, D] segment buffer
    idx_flat = idx.reshape(-1)
    pos_flat = jnp.where(keep, pos, cap).reshape(-1)             # cap = trash row
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[idx_flat, pos_flat].add(
        jnp.repeat(xf, k, axis=0).reshape(t * k, d)
        * keep.reshape(-1, 1).astype(x.dtype)
    )
    buf = buf[:, :cap]
    buf = shard("moe_dispatch", buf)

    # per-expert segment GEMMs (the typed linear layer)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = shard("moe_hidden", h)
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y = shard("moe_dispatch", y)

    # combine: gather each (token, slot) row, fuse the per-row gate scalar
    pos_g = jnp.minimum(pos, cap - 1)
    out = y[idx, pos_g]                                          # [T, k, D]
    out = out * (gate * keep).astype(out.dtype)[..., None]
    out = out.sum(axis=1).reshape(b, s, d)

    # Switch-style load-balance aux loss
    me = probs.mean(axis=0)
    ce = (onehot.sum(axis=1) > 0).astype(jnp.float32).mean(axis=0)
    lb_loss = e * jnp.sum(me * ce)
    return out, {"lb_loss": lb_loss, "dropped": dropped}


# ---------------------------------------------------------------------------
# v-B: expert-parallel dispatch via shard_map all-to-all
# ---------------------------------------------------------------------------
def _route_and_fill(xf, router, e, k, cap):
    """Local routing -> ([e, cap, d] buffer, idx, pos, gate, keep, aux)."""
    t, d = xf.shape
    logits = xf.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)
    slot_flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(slot_flat, axis=0) - slot_flat
    pos = jnp.sum(pos * slot_flat, axis=-1).reshape(t, k)
    keep = pos < cap
    idx_flat = idx.reshape(-1)
    pos_flat = jnp.where(keep, pos, cap).reshape(-1)
    buf = jnp.zeros((e, cap + 1, d), xf.dtype)
    buf = buf.at[idx_flat, pos_flat].add(
        jnp.repeat(xf, k, axis=0).reshape(t * k, d)
        * keep.reshape(-1, 1).astype(xf.dtype))
    me = probs.mean(axis=0)
    ce = (onehot.sum(axis=1) > 0).astype(jnp.float32).mean(axis=0)
    aux = {"lb_loss": e * jnp.sum(me * ce),
           "dropped": 1.0 - keep.mean()}
    return buf[:, :cap], idx, pos, gate, keep, aux


def _moe_ffn_ep(params, x, num_experts, k, capacity_factor, ctx):
    b, s, d = x.shape
    e, tp, ax = num_experts, ctx.tp, ctx.tp_axis
    if e % tp == 0:
        e_local, dup = e // tp, 1
    else:
        # expert-replicated EP (E < tp, tp % E == 0): each expert is owned
        # by ``dup`` members, each handling a distinct slice of its capacity
        # rows. Weight repeat below shards to exactly one expert per member.
        e_local, dup = 1, tp // e
    dpb = ctx.batch_dims(b)
    b_local = b // ctx.dp if dpb == ctx.dp_axes else b // ctx.mesh.shape[dpb[0]]
    t_local = b_local * s
    if t_local % tp:
        return _moe_ffn_dense(params, x, num_experts, k, capacity_factor)
    # activations are replicated across the model axis: each member routes
    # ONLY its token slice (without this, every member dispatches a duplicate
    # copy and expert FLOPs blow up tp x — measured in §Perf v-B iteration 1).
    t_slice = t_local // tp
    cap = capacity(t_slice, e, k, capacity_factor, multiple=8 * dup)

    xspec = P(dpb, None, None)
    wspec3 = P(ax, None, None)

    def body(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        me = jax.lax.axis_index(ax)
        xf = jax.lax.dynamic_slice_in_dim(
            xl.reshape(bl * sl, d), me * t_slice, t_slice, axis=0)
        buf, idx, pos, gate, keep, aux = _route_and_fill(xf, router, e, k, cap)
        # dispatch: (expert, capacity-slice) blocks -> owning shards
        buf4 = buf.reshape(tp, e_local * cap // dup, d)[:, None]
        recv = jax.lax.all_to_all(buf4, ax, split_axis=0, concat_axis=0)
        tok = recv.reshape(tp, e_local, cap // dup, d)
        tok = tok.transpose(1, 0, 2, 3).reshape(e_local, tp * cap // dup, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", tok, wg))
        h = h * jnp.einsum("ecd,edf->ecf", tok, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd)
        # combine: reverse all-to-all back to the source shards
        y4 = y.reshape(e_local, tp, cap // dup, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y4[:, None], ax, split_axis=0, concat_axis=0)
        y_local = back.reshape(e, cap, d)
        pos_g = jnp.minimum(pos, cap - 1)
        out = y_local[idx, pos_g] * (gate * keep).astype(y_local.dtype)[..., None]
        out = out.sum(axis=1)                        # [t_slice, d]
        # reassemble the token dim across the model axis
        out = jax.lax.all_gather(out, ax, axis=0, tiled=True).reshape(bl, sl, d)
        aux = {kk: jax.lax.pmean(vv, dpb + (ax,)) for kk, vv in aux.items()}
        return out, aux

    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if dup > 1:
        wg = jnp.repeat(wg, dup, axis=0)
        wu = jnp.repeat(wu, dup, axis=0)
        wd = jnp.repeat(wd, dup, axis=0)
    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(xspec, P(), wspec3, wspec3, wspec3),
        out_specs=(xspec, P()),
        check_vma=False,
    )
    return fn(x, params["router"], wg, wu, wd)
