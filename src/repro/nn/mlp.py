"""Dense gated-SiLU MLP."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.nn.common import dense_init, rp_einsum, shard


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype, fan_in=d_ff),
    }


def mlp(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard("ffn_hidden", h)
    return rp_einsum("bsf,fd->bsd", h, params["w_down"])
