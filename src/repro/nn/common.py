"""Shared NN primitives: norms, RoPE, initializers, sharding hooks."""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# sharding context: model code annotates logical tensors; the launcher
# installs a resolver mapping logical names -> sharding constraints.
# ---------------------------------------------------------------------------
_SHARDING_CTX: contextvars.ContextVar[Optional[Callable]] = contextvars.ContextVar(
    "repro_sharding_ctx", default=None
)


@contextlib.contextmanager
def sharding_context(resolver: Callable[[str, jnp.ndarray], jnp.ndarray]):
    token = _SHARDING_CTX.set(resolver)
    try:
        yield
    finally:
        _SHARDING_CTX.reset(token)


def shard(name: str, x: jnp.ndarray) -> jnp.ndarray:
    """Apply the active logical sharding constraint (identity outside pjit)."""
    resolver = _SHARDING_CTX.get()
    if resolver is None:
        return x
    return resolver(name, x)


def mesh_ctx():
    """The active resolver object (carries mesh/axis info for shard_map
    paths like EP-MoE and sequence-sharded decode attention), or None."""
    return _SHARDING_CTX.get()


def rp_einsum(pattern: str, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Row-parallel einsum (contraction dim TP-sharded -> partial sums are
    all-reduced). v-D: with ``bf16_reduce`` active, partials are produced in
    the model dtype so the all-reduce rides the wire at 2 bytes/elt instead
    of XLA's hoisted-f32 4 bytes/elt (EXPERIMENTS §Perf)."""
    ctx = mesh_ctx()
    if ctx is not None and getattr(ctx, "bf16_reduce", False):
        return jnp.einsum(pattern, a, b, preferred_element_type=a.dtype)
    return jnp.einsum(pattern, a, b)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm with f32 accumulation. ``plus_one`` = Gemma-style (1+w)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (x * w).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply RoPE. x: [B, S, H, hd]; positions: [S] int32 (batch-shared)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs        # [S, half]
    cos = jnp.cos(ang)[None, :, None, :]                        # [1, S, 1, half]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(max(1, fan)))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
