"""qwen3-14b [dense] — qk_norm, GQA. 40L d_model=5120 40H (kv=8) d_ff=17408
vocab=151936 head_dim=128 [hf:Qwen/Qwen3-8B family]."""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

CONFIG = LMConfig(
    name="qwen3-14b",
    family="dense",
    stages=(Stage((LayerSpec(kind="self_attn"),), 40),),
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    sub_quadratic=False,
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
