"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536, ssm_state=128.
Period-8 block: attention at position 3 (1 attn : 7 mamba), MoE on every
other layer [arXiv:2403.19887].
"""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

_PATTERN = tuple(
    LayerSpec(
        kind="self_attn" if i == 3 else "mamba",
        moe=(i % 2 == 1),
    )
    for i in range(8)
)

CONFIG = LMConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    stages=(Stage(_PATTERN, 4),),              # 4 x 8 = 32 layers
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    num_experts=16,
    experts_per_tok=2,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=False,
    sub_quadratic=True,
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
