"""llama-3.2-vision-11b [vlm] — cross-attention image layers.

40L (32 self + 8 cross inserted every 5th) d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256 head_dim=128. Vision frontend is a STUB: input_specs
provides precomputed patch embeddings [B, 1600, 1280] projected to d_model
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

_SELF = LayerSpec(kind="self_attn")
_CROSS = LayerSpec(kind="cross_attn")

CONFIG = LMConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    stages=(Stage((_SELF, _SELF, _SELF, _SELF, _CROSS), 8),),   # 40 layers
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    frontend_tokens=1600,
    frontend_dim=1280,
    tie_embeddings=False,
    sub_quadratic=False,
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
