"""Architecture registry: the 10 assigned LM configs + the paper's RGNN
models, with ``reduced()`` smoke-test variants.

``get_config(arch_id)`` returns the exact published full config;
``get_reduced(arch_id)`` returns a structurally identical small config
(same stage patterns, tiny dims) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.lm.config import LMConfig, LayerSpec, Stage, SHAPES, ShapeCell

from repro.configs import (  # noqa: E402
    jamba_v0_1_52b,
    qwen3_4b,
    gemma2_2b,
    qwen3_14b,
    gemma3_4b,
    mamba2_780m,
    grok_1_314b,
    moonshot_v1_16b_a3b,
    llama_3_2_vision_11b,
    whisper_medium,
)

_MODULES = {
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "qwen3-4b": qwen3_4b,
    "gemma2-2b": gemma2_2b,
    "qwen3-14b": qwen3_14b,
    "gemma3-4b": gemma3_4b,
    "mamba2-780m": mamba2_780m,
    "grok-1-314b": grok_1_314b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
    "whisper-medium": whisper_medium,
}

ARCHS: List[str] = list(_MODULES)


def get_config(arch: str) -> LMConfig:
    return _MODULES[arch].CONFIG


def get_reduced(arch: str) -> LMConfig:
    return _MODULES[arch].reduced()


def _shrink_stage(st: Stage, repeats: int = 1) -> Stage:
    return Stage(st.pattern, min(st.repeats, repeats))


def shrink(cfg: LMConfig, **overrides) -> LMConfig:
    """Generic reduced config: same family/pattern, tiny dims."""
    kv = min(cfg.num_kv_heads, 2)
    small = dict(
        stages=tuple(_shrink_stage(s) for s in cfg.stages),
        d_model=64,
        num_heads=4,
        num_kv_heads=kv if 4 % kv == 0 else 2,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        head_dim=16,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_tok=min(cfg.experts_per_tok, 2) if cfg.num_experts else 0,
        moe_d_ff=64 if cfg.moe_d_ff else None,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=8,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=16 if cfg.encoder_seq else 0,
        frontend_tokens=8 if cfg.frontend_tokens else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        dtype="float32",
    )
    small.update(overrides)
    # shrink windows inside patterns
    new_stages = []
    for st in small["stages"]:
        pat = tuple(
            dataclasses.replace(l, window=None if l.window is None else 8)
            for l in st.pattern
        )
        new_stages.append(Stage(pat, st.repeats))
    small["stages"] = tuple(new_stages)
    return dataclasses.replace(cfg, **small)


# arch -> shape-cell applicability (DESIGN.md §6)
def applicable_shapes(arch: str) -> List[str]:
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells
