"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 head_dim=256,
window=4096 on local layers, attn softcap 50, final logit softcap 30
[arXiv:2408.00118]."""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

_LOCAL = LayerSpec(kind="self_attn", window=4096)
_GLOBAL = LayerSpec(kind="self_attn", window=None)

CONFIG = LMConfig(
    name="gemma2-2b",
    family="dense",
    stages=(Stage((_LOCAL, _GLOBAL), 13),),    # 26 layers
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256_000,
    head_dim=256,
    attn_softcap=50.0,
    logit_softcap=30.0,
    scale_embed=True,
    tie_embeddings=True,
    # local:global 1:1 — half the layers are sliding-window; global layers
    # are decode-linear with data-sharded KV, so the 500k cell runs.
    sub_quadratic=True,
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
