"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144 head_dim=256,
window=1024 on local layers, qk-norm (Gemma3 replaced softcap with qk-norm)
[hf:google/gemma-3 family]. 34 = 5x(5 local + 1 global) + 4 local.
"""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

_LOCAL = LayerSpec(kind="self_attn", window=1024)
_GLOBAL = LayerSpec(kind="self_attn", window=None)

CONFIG = LMConfig(
    name="gemma3-4b",
    family="dense",
    stages=(
        Stage((_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL), 5),
        Stage((_LOCAL,), 4),
    ),
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262_144,
    head_dim=256,
    qk_norm=True,
    rope_theta=1_000_000.0,
    scale_embed=True,
    tie_embeddings=True,
    sub_quadratic=True,     # 5:1 local:global
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
