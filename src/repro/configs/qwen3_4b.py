"""qwen3-4b [dense] — qk_norm, GQA. 36L d_model=2560 32H (kv=8) d_ff=9728
vocab=151936 head_dim=128 [hf:Qwen/Qwen3-8B family]."""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

CONFIG = LMConfig(
    name="qwen3-4b",
    family="dense",
    stages=(Stage((LayerSpec(kind="self_attn"),), 36),),
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    sub_quadratic=False,
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
