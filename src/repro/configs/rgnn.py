"""The paper's own model configs (RGCN / RGAT / HGT on Table-3 datasets),
exposed alongside the LM architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.graph import HeteroGraph, table3_graph
from repro.models import hgt_program, rgat_program, rgcn_program


@dataclasses.dataclass(frozen=True)
class RGNNConfig:
    name: str
    model: str              # rgcn | rgat | hgt
    dataset: str            # Table-3 dataset name
    in_dim: int = 64        # the paper's evaluation setting (§4.1)
    out_dim: int = 64
    scale: float = 1.0      # dataset scale factor (1.0 = published stats)

    def program(self):
        fn: Callable = {"rgcn": rgcn_program, "rgat": rgat_program,
                        "hgt": hgt_program}[self.model]
        return fn(self.in_dim, self.out_dim)

    def graph(self, seed: int = 0) -> HeteroGraph:
        return table3_graph(self.dataset, scale=self.scale, seed=seed)


RGNN_CONFIGS = {
    f"{m}-{ds}": RGNNConfig(name=f"{m}-{ds}", model=m, dataset=ds)
    for m in ("rgcn", "rgat", "hgt")
    for ds in ("aifb", "am", "bgs", "biokg", "fb15k", "mag", "mutag",
               "wikikg2")
}


def get_rgnn_config(name: str) -> RGNNConfig:
    return RGNN_CONFIGS[name]
