"""moonshot-v1-16b-a3b [moe] — kimi/moonlight-style fine-grained MoE.

48L d_model=2048 16H (kv=16, MHA) expert d_ff=1408 vocab=163840,
MoE 64 experts top-6 on every layer [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    stages=(Stage((LayerSpec(kind="self_attn", moe=True),), 48),),
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,              # expert hidden dim (fine-grained experts)
    vocab_size=163840,
    head_dim=128,
    num_experts=64,
    experts_per_tok=6,
    tie_embeddings=False,
    sub_quadratic=False,
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
