"""whisper-medium [audio] — encoder-decoder; conv frontend STUBBED.

24 encoder + 24 decoder layers, d_model=1024 16H (kv=16) d_ff=4096
vocab=51865 head_dim=64. input_specs provides precomputed frame embeddings
[B, 1500, 1024]; decoder layers are self-attn + cross-attn
[arXiv:2212.04356]. Native decoder context is 448 tokens — noted per cell in
EXPERIMENTS.md where the assigned shapes exceed it."""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

CONFIG = LMConfig(
    name="whisper-medium",
    family="audio",
    stages=(Stage((LayerSpec(kind="self_attn", dec_cross=True),), 24),),
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    encoder_layers=24,
    encoder_seq=1500,
    tie_embeddings=True,
    sub_quadratic=False,
    decoder_only_note="whisper decoder native max context = 448",
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
