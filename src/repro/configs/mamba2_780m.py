"""mamba2-780m [ssm] — attention-free SSD (state-space duality).

48L d_model=1536, ssm_state=128, expand=2 (d_inner=3072, 48 heads x 64),
vocab=50280 (padded 50304), no MLP (d_ff=0) [arXiv:2405.21060]."""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

CONFIG = LMConfig(
    name="mamba2-780m",
    family="ssm",
    stages=(Stage((LayerSpec(kind="mamba"),), 48),),
    d_model=1536,
    num_heads=1,            # no attention layers
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=True,
    sub_quadratic=True,
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
