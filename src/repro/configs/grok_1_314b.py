"""grok-1-314b [moe] — 8 experts top-2.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072 head_dim=128,
MoE on every layer [hf:xai-org/grok-1]."""
from repro.lm.config import LMConfig, LayerSpec, Stage
from repro import configs as _c

CONFIG = LMConfig(
    name="grok-1-314b",
    family="moe",
    stages=(Stage((LayerSpec(kind="self_attn", moe=True),), 64),),
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    num_experts=8,
    experts_per_tok=2,
    attn_softcap=30.0,      # grok caps attention logits
    tie_embeddings=False,
    sub_quadratic=False,
)


def reduced() -> LMConfig:
    return _c.shrink(CONFIG)
