"""GEMM-template Pallas kernels (paper Algorithm 1, TPU adaptation).

``segment_mm_padded``  : Y_p = X_p @ W[T[tile]]  (+ fused per-row scale)
``segment_outer_padded``: dW[g] = sum over tiles of g of X_tile^T @ dY_tile
                          (the backward outer-product GEMM instance, §3.5/§4.4)

Both operate on the tile-aligned ``PaddedSegments`` layout (kernels/layout.py):
rows presorted by type, each type segment padded to whole row tiles, and a
scalar-prefetched ``tile_to_group`` map selecting the weight block per tile —
the TPU analogue of the paper's gather/scatter access schemes folded into the
kernel. VMEM blocking:

  X block  (tile_rows, k)      — full reduction dim in VMEM (k ≤ a few K)
  W block  (1, k, tile_n)      — indexed by tile_to_group[i]
  Y block  (tile_rows, tile_n)

MXU alignment: tile_rows defaults to 128 and tile_n to min(n, 128); callers
pick smaller tiles only for tiny test shapes (interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(t2g_ref, x_ref, w_ref, y_ref):
    acc = jnp.dot(x_ref[...], w_ref[0], preferred_element_type=jnp.float32)
    y_ref[...] = acc.astype(y_ref.dtype)


def _mm_scale_kernel(t2g_ref, x_ref, w_ref, scale_ref, y_ref):
    acc = jnp.dot(x_ref[...], w_ref[0], preferred_element_type=jnp.float32)
    acc = acc * scale_ref[...].astype(jnp.float32)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tile_rows", "tile_n", "interpret")
)
def segment_mm_padded(
    x_p: jnp.ndarray,          # [Rp, k]  padded, type-sorted rows
    w: jnp.ndarray,            # [R, k, n]
    t2g: jnp.ndarray,          # [T] int32, non-decreasing tile -> group
    row_scale_p: jnp.ndarray | None = None,   # [Rp, 1] fused epilogue scale
    *,
    tile_rows: int = 128,
    tile_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    rp, k = x_p.shape
    r, k2, n = w.shape
    assert k == k2, (k, k2)
    assert rp % tile_rows == 0, (rp, tile_rows)
    tile_n = min(tile_n, n)
    assert n % tile_n == 0, (n, tile_n)
    num_tiles = rp // tile_rows
    grid = (num_tiles, n // tile_n)

    in_specs = [
        pl.BlockSpec((tile_rows, k), lambda i, j, t2g: (i, 0)),
        pl.BlockSpec((1, k, tile_n), lambda i, j, t2g: (t2g[i], 0, j)),
    ]
    args = [x_p, w]
    kernel = _mm_kernel
    if row_scale_p is not None:
        in_specs.append(pl.BlockSpec((tile_rows, 1), lambda i, j, t2g: (i, 0)))
        args.append(row_scale_p.reshape(rp, 1))
        kernel = _mm_scale_kernel

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((tile_rows, tile_n), lambda i, j, t2g: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((rp, n), x_p.dtype),
        interpret=interpret,
    )(t2g, *args)


def _mm_gather_tile(gidx_ref, x_ref, tile_rows):
    """Gather this grid step's row tile from the resident source block.

    ``gidx_ref`` is the scalar-prefetched padded gather-index layout
    (kernels/layout.py ``compose_gather_rows``): slot -> source row or -1.
    The gather happens here, inside the kernel, against the full source
    block in VMEM — the TPU analogue of the paper's per-element gather
    access scheme folded into the GEMM template.
    """
    t = pl.program_id(0)
    rows = gidx_ref[pl.ds(t * tile_rows, tile_rows)]
    valid = rows >= 0
    xt = jnp.take(x_ref[...], jnp.where(valid, rows, 0), axis=0)
    return jnp.where(valid[:, None], xt, 0.0).astype(x_ref.dtype)


def _mm_gather_kernel(gidx_ref, t2g_ref, x_ref, w_ref, y_ref, *, tile_rows):
    xt = _mm_gather_tile(gidx_ref, x_ref, tile_rows)
    acc = jnp.dot(xt, w_ref[0], preferred_element_type=jnp.float32)
    y_ref[...] = acc.astype(y_ref.dtype)


def _mm_gather_scale_kernel(gidx_ref, t2g_ref, x_ref, w_ref, scale_ref, y_ref,
                            *, tile_rows):
    xt = _mm_gather_tile(gidx_ref, x_ref, tile_rows)
    acc = jnp.dot(xt, w_ref[0], preferred_element_type=jnp.float32)
    acc = acc * scale_ref[...].astype(jnp.float32)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tile_rows", "tile_n", "interpret")
)
def segment_mm_gather_padded(
    x: jnp.ndarray,            # [Nx, k]  source rows (node feats / uniques)
    w: jnp.ndarray,            # [R, k, n]
    gidx: jnp.ndarray,         # [Rp] int32 padded slot -> source row, or -1
    t2g: jnp.ndarray,          # [T] int32, non-decreasing tile -> group
    row_scale_p: jnp.ndarray | None = None,   # [Rp, 1] fused epilogue scale
    *,
    tile_rows: int = 128,
    tile_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Gather-fused GEMM template: Y_p = X[gidx] @ W[T[tile]].

    Unlike ``segment_mm_padded`` the caller hands over the *ungathered*
    source tensor; the per-row gather runs inside the kernel from the
    scalar-prefetched index layout, so no ``[Rp, k]`` (edge-wide) input copy
    is ever materialized in HBM. The source block stays resident in VMEM
    across grid steps (its index_map is constant).
    """
    nx, k = x.shape
    r, k2, n = w.shape
    assert k == k2, (k, k2)
    (rp,) = gidx.shape
    assert rp % tile_rows == 0, (rp, tile_rows)
    tile_n = min(tile_n, n)
    assert n % tile_n == 0, (n, tile_n)
    num_tiles = rp // tile_rows
    grid = (num_tiles, n // tile_n)

    in_specs = [
        pl.BlockSpec((nx, k), lambda i, j, gidx, t2g: (0, 0)),
        pl.BlockSpec((1, k, tile_n), lambda i, j, gidx, t2g: (t2g[i], 0, j)),
    ]
    args = [x, w]
    kernel = functools.partial(_mm_gather_kernel, tile_rows=tile_rows)
    if row_scale_p is not None:
        in_specs.append(
            pl.BlockSpec((tile_rows, 1), lambda i, j, gidx, t2g: (i, 0)))
        args.append(row_scale_p.reshape(rp, 1))
        kernel = functools.partial(_mm_gather_scale_kernel,
                                   tile_rows=tile_rows)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((tile_rows, tile_n),
                                   lambda i, j, gidx, t2g: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((rp, n), x.dtype),
        interpret=interpret,
    )(gidx, t2g, *args)


def _outer_kernel(meta_ref, x_ref, dy_ref, dw_ref):
    """Accumulating outer product; meta_ref[0] = t2g, meta_ref[1] = is_first."""
    t = pl.program_id(0)
    is_first = meta_ref[1, t]

    @pl.when(is_first == 1)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    acc = jax.lax.dot_general(
        x_ref[...], dy_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    dw_ref[...] += acc[None].astype(dw_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_groups", "tile_rows", "interpret"))
def segment_outer_padded(
    x_p: jnp.ndarray,          # [Rp, k]
    dy_p: jnp.ndarray,         # [Rp, n]
    t2g: jnp.ndarray,          # [T] int32 non-decreasing
    *,
    num_groups: int,
    tile_rows: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """dW[g] = sum_{tiles t of group g} x_t^T @ dy_t  -> [R, k, n] (f32)."""
    rp, k = x_p.shape
    rp2, n = dy_p.shape
    assert rp == rp2
    assert rp % tile_rows == 0
    num_tiles = rp // tile_rows
    # is_first[t] = 1 iff t is the first tile of its group
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), t2g[:-1]])
    is_first = (t2g != prev).astype(jnp.int32)
    meta = jnp.stack([t2g.astype(jnp.int32), is_first])  # [2, T]

    return pl.pallas_call(
        _outer_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(num_tiles,),
            in_specs=[
                pl.BlockSpec((tile_rows, k), lambda t, meta: (t, 0)),
                pl.BlockSpec((tile_rows, n), lambda t, meta: (t, 0)),
            ],
            out_specs=pl.BlockSpec((1, k, n), lambda t, meta: (meta[0, t], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_groups, k, n), jnp.float32),
        interpret=interpret,
    )(meta, x_p, dy_p)
