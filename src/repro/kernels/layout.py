"""Host-side tile-aligned layouts for the two Hector templates on TPU.

GPU Hector applies gather/scatter lists *inside* kernels at per-element
granularity. The MXU wants contiguous (8,128)-aligned tiles, so the TPU
adaptation moves irregularity to **block granularity**:

* ``PaddedSegments`` — for the GEMM template: rows presorted by type are
  padded so every type segment occupies whole row-tiles; a scalar-prefetched
  ``tile_to_group`` map then selects the weight block per tile. This is the
  paper's "presort to enable segment MM" taken one step further (tile-aligned
  so a single kernel sweeps all relations without per-row indirection).

* ``BlockedCSR`` — for the traversal template: destination-sorted edges are
  padded so no edge tile spans two destination-node blocks; a
  ``tile_to_block`` map lets consecutive edge tiles accumulate into the same
  output node block in VMEM (deterministic replacement for GPU atomics).

Both are computed once per graph on the host (NumPy) and are *data layout
choices* in the sense of §3.2.2 — the inter-op IR never sees them.

The ``device_*`` functions at the bottom are the jit-traceable (jax.numpy)
equivalents of ``pad_segments`` / ``compose_gather_rows`` / ``block_csr``
used by the device-native sampling path (``kernels/sampling_ops.py``): same
layout semantics, but the padded row/edge capacity is a *static* argument
(chosen from bucket-rounded counts) so the whole layout build stays inside
one compiled sampling program with fixed shapes.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PaddedSegments:
    """Tile-aligned padded layout for type-segmented rows."""

    tile: int                 # rows per tile (C)
    num_groups: int           # R
    padded_rows: int          # Rp = sum over groups of ceil(s_r / C) * C
    row_map: np.ndarray       # [Rp] int32: original row index, or -1 (pad)
    inv_map: np.ndarray       # [M]  int32: padded position of original row
    tile_to_group: np.ndarray  # [Rp // C] int32
    seg_sizes: np.ndarray     # [R] int32 original segment sizes

    @property
    def num_tiles(self) -> int:
        return self.padded_rows // self.tile

    @property
    def pad_overhead(self) -> float:
        m = int(self.seg_sizes.sum())
        return self.padded_rows / max(1, m)


def pad_segments(seg_ptr: np.ndarray, tile: int) -> PaddedSegments:
    """Build a ``PaddedSegments`` layout from segment offsets [R+1]."""
    seg_ptr = np.asarray(seg_ptr, dtype=np.int64)
    sizes = np.diff(seg_ptr)
    num_groups = len(sizes)
    padded = ((sizes + tile - 1) // tile) * tile
    rp = int(padded.sum())
    row_map = np.full(rp, -1, dtype=np.int32)
    inv_map = np.zeros(int(sizes.sum()), dtype=np.int32)
    t2g = np.zeros(max(1, rp // tile), dtype=np.int32)
    off = 0
    tile_off = 0
    for r in range(num_groups):
        s, p = int(sizes[r]), int(padded[r])
        row_map[off : off + s] = np.arange(seg_ptr[r], seg_ptr[r] + s, dtype=np.int32)
        inv_map[seg_ptr[r] : seg_ptr[r] + s] = np.arange(off, off + s, dtype=np.int32)
        t2g[tile_off : tile_off + p // tile] = r
        off += p
        tile_off += p // tile
    return PaddedSegments(
        tile=tile, num_groups=num_groups, padded_rows=rp,
        row_map=row_map, inv_map=inv_map, tile_to_group=t2g,
        seg_sizes=sizes.astype(np.int32),
    )


def pow2ceil(x: int) -> int:
    """Smallest power of two >= max(1, x)."""
    return 1 << (max(1, int(x)) - 1).bit_length()


def compose_gather_rows(ps: PaddedSegments, idx: np.ndarray) -> np.ndarray:
    """Padded gather-index layout: compose a per-row gather list with the
    tile-aligned padding map.

    ``idx`` maps a canonical row (edge or unique pair) to the source row it
    reads (e.g. ``src`` for BY_EDGE_SRC, ``unique_src`` for BY_UNIQUE_SRC).
    The result maps each *padded* slot directly to that source row (-1 for
    pad slots), so a kernel can scalar-prefetch it and perform the gather in
    its own index space — no ``[rows, k]`` copy is materialized between the
    source tensor and the GEMM (paper §3.3's in-kernel access schemes).
    """
    idx = np.asarray(idx)
    return np.where(
        ps.row_map >= 0, idx[np.maximum(ps.row_map, 0)], -1
    ).astype(np.int32)


def pad_segments_rows(ps: PaddedSegments, target_rows: int) -> PaddedSegments:
    """Grow a ``PaddedSegments`` layout to ``target_rows`` padded rows.

    Extra rows are pure padding (``row_map`` = -1) and extra tiles extend
    the **last** group's run — the tile->group map must stay non-decreasing
    because the accumulating kernels detect a group's first tile via
    ``t != prev``. Pad tiles multiply zero rows and are never read back
    through ``inv_map``. Used by the serving path to bucket layout shapes so
    jit/eager compilation caches hit across mini-batches.
    """
    if target_rows % ps.tile:
        raise ValueError(f"target_rows {target_rows} not a multiple of tile")
    extra = target_rows - ps.padded_rows
    if extra < 0:
        raise ValueError("target smaller than current layout")
    if extra == 0:
        return ps
    return dataclasses.replace(
        ps,
        padded_rows=target_rows,
        row_map=np.concatenate(
            [ps.row_map, np.full(extra, -1, dtype=np.int32)]),
        tile_to_group=np.concatenate(
            [ps.tile_to_group[: ps.padded_rows // ps.tile],
             np.full(extra // ps.tile, ps.num_groups - 1, dtype=np.int32)]),
    )


def pad_blocked_csr(bc: BlockedCSR, target_edges: int) -> BlockedCSR:
    """Grow a ``BlockedCSR`` to ``target_edges`` padded edge slots.

    Extra tiles carry no edges (``edge_map`` = -1, ``local_dst`` points past
    the block) and extend the **last** node block's run, keeping the
    tile->block map non-decreasing (the aggregation kernels re-initialize an
    output block whenever the map changes value); they accumulate exact
    zeros there.
    """
    if target_edges % bc.edge_tile:
        raise ValueError("target_edges not a multiple of edge_tile")
    extra = target_edges - bc.padded_edges
    if extra < 0:
        raise ValueError("target smaller than current layout")
    if extra == 0:
        return bc
    return dataclasses.replace(
        bc,
        padded_edges=target_edges,
        edge_map=np.concatenate(
            [bc.edge_map, np.full(extra, -1, dtype=np.int32)]),
        local_dst=np.concatenate(
            [bc.local_dst, np.full(extra, bc.node_block, dtype=np.int32)]),
        tile_to_block=np.concatenate(
            [bc.tile_to_block[: bc.padded_edges // bc.edge_tile],
             np.full(extra // bc.edge_tile, bc.num_node_blocks - 1,
                     dtype=np.int32)]),
    )


@dataclasses.dataclass(frozen=True)
class BlockedCSR:
    """Tile-aligned padded layout for destination-sorted edges.

    Nodes are grouped in blocks of ``node_block``; the (dst-sorted) edge list
    of each node block is padded to a multiple of ``edge_tile`` so every edge
    tile belongs to exactly one node block.
    """

    edge_tile: int
    node_block: int
    num_nodes: int
    padded_edges: int             # Ep
    edge_map: np.ndarray          # [Ep] int32: dst-sorted edge index, or -1
    local_dst: np.ndarray         # [Ep] int32: dst - block_start (pads -> node_block)
    tile_to_block: np.ndarray     # [Ep // edge_tile] int32
    num_node_blocks: int

    @property
    def num_tiles(self) -> int:
        return self.padded_edges // self.edge_tile


def block_csr(dst_ptr: np.ndarray, edge_tile: int, node_block: int) -> BlockedCSR:
    dst_ptr = np.asarray(dst_ptr, dtype=np.int64)
    num_nodes = len(dst_ptr) - 1
    nb = (num_nodes + node_block - 1) // node_block
    # edges per node block
    blk_start = dst_ptr[np.minimum(np.arange(nb) * node_block, num_nodes)]
    blk_end = dst_ptr[np.minimum((np.arange(nb) + 1) * node_block, num_nodes)]
    sizes = blk_end - blk_start
    padded = ((sizes + edge_tile - 1) // edge_tile) * edge_tile
    ep = int(padded.sum())
    edge_map = np.full(ep, -1, dtype=np.int32)
    local_dst = np.full(ep, node_block, dtype=np.int32)  # pads point past block
    t2b = np.zeros(max(1, ep // edge_tile), dtype=np.int32)

    # dst id of each dst-sorted edge
    dst_of_edge = np.repeat(
        np.arange(num_nodes, dtype=np.int64), np.diff(dst_ptr)
    )
    off = 0
    toff = 0
    for b in range(nb):
        s, p = int(sizes[b]), int(padded[b])
        lo = int(blk_start[b])
        edge_map[off : off + s] = np.arange(lo, lo + s, dtype=np.int32)
        local_dst[off : off + s] = (
            dst_of_edge[lo : lo + s] - b * node_block
        ).astype(np.int32)
        t2b[toff : toff + p // edge_tile] = b
        off += p
        toff += p // edge_tile
    return BlockedCSR(
        edge_tile=edge_tile, node_block=node_block, num_nodes=num_nodes,
        padded_edges=ep, edge_map=edge_map, local_dst=local_dst,
        tile_to_block=t2b, num_node_blocks=nb,
    )


# ---------------------------------------------------------------------------
# device-side (jit-traceable) layout builders
# ---------------------------------------------------------------------------
def _device_tile_runs(pstart: jnp.ndarray, tile: int, num_tiles: int,
                      num_groups: int) -> jnp.ndarray:
    """tile -> group map from tile-aligned padded group starts [G+1].

    Tiles past the populated prefix extend the **last** group's run (the
    ``pad_segments_rows`` growth rule: accumulating kernels need the map
    non-decreasing, and trailing pad tiles must not open a new group).
    """
    boundaries = pstart[1:] // tile                       # [G] end tile of g
    t = jnp.arange(num_tiles, dtype=jnp.int32)
    t2g = jnp.searchsorted(boundaries, t, side="right")
    return jnp.clip(t2g, 0, num_groups - 1).astype(jnp.int32)


def device_pad_segments(seg_ptr: jnp.ndarray, group_of_row: jnp.ndarray,
                        tile: int, padded_rows: int):
    """jnp ``pad_segments`` (+ ``pad_segments_rows`` growth), fixed shapes.

    ``seg_ptr`` [G+1] are the group offsets over ``M = len(group_of_row)``
    type-sorted rows (every row's group in ``group_of_row``, non-decreasing).
    ``padded_rows`` is the static row capacity and must satisfy
    ``padded_rows >= M + G*tile > M + sum_g (tile-1)`` — i.e. large enough
    for the worst-case per-group tile padding — and be a tile multiple.
    Returns ``(row_map [padded_rows], inv_map [M], t2g [padded_rows//tile])``
    with exactly the host semantics: pad slots are -1 in ``row_map`` and
    trailing tiles extend the last group's run.
    """
    if padded_rows % tile:
        raise ValueError("padded_rows must be a tile multiple")
    num_groups = int(seg_ptr.shape[0]) - 1
    m = int(group_of_row.shape[0])
    if padded_rows < m + num_groups * tile:
        raise ValueError(
            f"padded_rows={padded_rows} cannot hold {m} rows with "
            f"{num_groups} groups of up-to-(tile-1) padding each")
    sizes = seg_ptr[1:] - seg_ptr[:-1]
    padded = ((sizes + tile - 1) // tile) * tile
    pstart = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(padded).astype(jnp.int32)])
    rows = jnp.arange(m, dtype=jnp.int32)
    inv_map = (pstart[group_of_row] + (rows - seg_ptr[group_of_row])
               ).astype(jnp.int32)
    row_map = jnp.full(padded_rows, -1, jnp.int32).at[inv_map].set(
        rows, mode="drop")
    t2g = _device_tile_runs(pstart, tile, padded_rows // tile, num_groups)
    return row_map, inv_map, t2g


def device_compose_gather_rows(row_map: jnp.ndarray,
                               idx: jnp.ndarray) -> jnp.ndarray:
    """jnp ``compose_gather_rows``: padded slot -> source row (or -1)."""
    return jnp.where(row_map >= 0, idx[jnp.maximum(row_map, 0)],
                     -1).astype(jnp.int32)


def device_block_csr(dst_ptr: jnp.ndarray, dst_sorted: jnp.ndarray,
                     edge_tile: int, node_block: int, padded_edges: int):
    """jnp ``block_csr``, fixed shapes.

    ``dst_ptr`` [N+1] / ``dst_sorted`` [E] describe the destination-sorted
    edges (N, E static). ``padded_edges`` is the static slot capacity and
    must satisfy ``padded_edges >= E + num_node_blocks*edge_tile`` (worst
    case per-block tile padding) and be a tile multiple. Returns
    ``(edge_map_d [padded_edges], local_dst [padded_edges], t2b)`` where
    ``edge_map_d`` holds **dst-sorted** edge indices (compose with
    ``perm_dst`` for canonical order, as ``ops.blocked_csr_dev`` does), pads
    are -1 / ``node_block``, and trailing tiles extend the last block's run.
    """
    if padded_edges % edge_tile:
        raise ValueError("padded_edges must be an edge_tile multiple")
    num_nodes = int(dst_ptr.shape[0]) - 1
    e = int(dst_sorted.shape[0])
    nb = (num_nodes + node_block - 1) // node_block
    if padded_edges < e + nb * edge_tile:
        raise ValueError(
            f"padded_edges={padded_edges} cannot hold {e} edges over "
            f"{nb} node blocks")
    bidx = jnp.minimum(jnp.arange(nb + 1, dtype=jnp.int32) * node_block,
                       num_nodes)
    bptr = dst_ptr[bidx]                                   # [nb+1]
    sizes = bptr[1:] - bptr[:-1]
    padded = ((sizes + edge_tile - 1) // edge_tile) * edge_tile
    pstart = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(padded).astype(jnp.int32)])
    b_of = (dst_sorted // node_block).astype(jnp.int32)    # [E]
    slot = pstart[b_of] + (jnp.arange(e, dtype=jnp.int32) - bptr[b_of])
    edge_map_d = jnp.full(padded_edges, -1, jnp.int32).at[slot].set(
        jnp.arange(e, dtype=jnp.int32), mode="drop")
    local_dst = jnp.full(padded_edges, node_block, jnp.int32).at[slot].set(
        (dst_sorted - b_of * node_block).astype(jnp.int32), mode="drop")
    t2b = _device_tile_runs(pstart, edge_tile, padded_edges // edge_tile, nb)
    return edge_map_d, local_dst, t2b
