"""Traversal-template Pallas kernels (paper Algorithm 2, TPU adaptation).

GPU Hector aggregates into destination rows with atomics (and identifies the
resulting latency bound in §4.4). TPU Pallas grids are sequential per core,
so we instead use the ``BlockedCSR`` layout (kernels/layout.py): edges sorted
by destination, padded so each edge tile belongs to one destination-node
block, and consecutive edge tiles of a block **accumulate into the same VMEM
output block** (deterministic, contention-free).

Kernels (all derived traversal-template instances):

``seg_stats_padded``        per-destination (max, sum-exp) in ONE pass using
                            online-softmax rescaling — the paper's
                            "partial result aggregation" adapted to TPU.
``seg_softmax_agg_padded``  out[v] = Σ_e softmax(score)_e · msg_e
                            (fused edge-softmax + weighted aggregation: the
                            canonical fused traversal region of Listing 1).
``seg_weighted_agg_padded`` out[v] = Σ_e scale_e · msg_e (RGCN-style).

The scatter "one-hot × message" contraction maps the per-edge scatter onto
the MXU (a [node_block × tile] one-hot matmul) instead of per-element stores.

``*_gather_padded`` variants additionally fold the message gather into the
kernel: instead of materializing the padded dst-sorted ``[Ep, d]`` message
copy in HBM before the call, the caller passes messages in their storage
order (canonical edge order, or the compact unique-pair table) plus a
scalar-prefetched padded row-index map (slot -> message row, -1 for pads);
each grid step gathers its tile from the VMEM-resident message block —
the paper's in-kernel gather access scheme applied to the traversal
template.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _stats_kernel(meta_ref, scores_ref, dst_ref, mx_ref, den_ref, *, node_block):
    t = pl.program_id(0)
    is_first = meta_ref[1, t]

    @pl.when(is_first == 1)
    def _init():
        mx_ref[...] = jnp.full_like(mx_ref, _NEG_INF)
        den_ref[...] = jnp.zeros_like(den_ref)

    s = scores_ref[0, :].astype(jnp.float32)          # [tile]
    dst = dst_ref[0, :]                               # [tile], pads == node_block
    tile = s.shape[0]
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (node_block, tile), 0)
    mask = node_ids == dst[None, :]                   # [NB, tile]
    masked = jnp.where(mask, s[None, :], _NEG_INF)
    m_tile = jnp.max(masked, axis=1)                  # [NB]

    m_old = mx_ref[0, :]
    m_new = jnp.maximum(m_old, m_tile)
    # online rescale; guard -inf - -inf
    old_factor = jnp.where(m_old <= _NEG_INF, 0.0, jnp.exp(m_old - m_new))
    t_den = jnp.sum(
        jnp.where(mask, jnp.exp(masked - m_new[:, None]), 0.0), axis=1
    )
    mx_ref[0, :] = m_new
    den_ref[0, :] = den_ref[0, :] * old_factor + t_den


@functools.partial(
    jax.jit, static_argnames=("node_block", "num_node_blocks", "interpret")
)
def seg_stats_padded(
    scores_p: jnp.ndarray,     # [T, tile] dst-sorted padded scores (pads: any)
    local_dst_p: jnp.ndarray,  # [T, tile] int32 local dst (pads: node_block)
    t2b: jnp.ndarray,          # [T] int32 non-decreasing tile -> node block
    *,
    node_block: int,
    num_node_blocks: int,
    interpret: bool = False,
):
    num_tiles, tile = scores_p.shape
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), t2b[:-1]])
    meta = jnp.stack([t2b.astype(jnp.int32), (t2b != prev).astype(jnp.int32)])

    mx, den = pl.pallas_call(
        functools.partial(_stats_kernel, node_block=node_block),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(num_tiles,),
            in_specs=[
                pl.BlockSpec((1, tile), lambda t, meta: (t, 0)),
                pl.BlockSpec((1, tile), lambda t, meta: (t, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, node_block), lambda t, meta: (meta[0, t], 0)),
                pl.BlockSpec((1, node_block), lambda t, meta: (meta[0, t], 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((num_node_blocks, node_block), jnp.float32),
            jax.ShapeDtypeStruct((num_node_blocks, node_block), jnp.float32),
        ],
        interpret=interpret,
    )(meta, scores_p, local_dst_p)
    return mx, den


def _softmax_agg_kernel(meta_ref, scores_ref, dst_ref, msg_ref, mx_ref, den_ref,
                        out_ref, *, node_block):
    t = pl.program_id(0)
    is_first = meta_ref[1, t]

    @pl.when(is_first == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = scores_ref[0, :].astype(jnp.float32)          # [tile]
    dst = dst_ref[0, :]                               # [tile]
    tile = s.shape[0]
    valid = dst < node_block
    dst_c = jnp.where(valid, dst, 0)
    mx = mx_ref[0, :]
    den = den_ref[0, :]
    att = jnp.exp(s - mx[dst_c]) / jnp.maximum(den[dst_c], 1e-38)
    att = jnp.where(valid, att, 0.0)                  # [tile]

    node_ids = jax.lax.broadcasted_iota(jnp.int32, (node_block, tile), 0)
    onehot = (node_ids == dst[None, :]).astype(jnp.float32)
    contrib = jax.lax.dot(
        onehot, att[:, None] * msg_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )                                                 # [NB, d]
    out_ref[...] += contrib.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("node_block", "num_node_blocks", "interpret")
)
def seg_softmax_agg_padded(
    scores_p: jnp.ndarray,     # [T, tile]
    msg_p: jnp.ndarray,        # [T*tile, d]  dst-sorted padded messages
    local_dst_p: jnp.ndarray,  # [T, tile]
    t2b: jnp.ndarray,          # [T]
    mx: jnp.ndarray,           # [NBk, NB]  from seg_stats_padded
    den: jnp.ndarray,          # [NBk, NB]
    *,
    node_block: int,
    num_node_blocks: int,
    interpret: bool = False,
) -> jnp.ndarray:
    num_tiles, tile = scores_p.shape
    d = msg_p.shape[-1]
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), t2b[:-1]])
    meta = jnp.stack([t2b.astype(jnp.int32), (t2b != prev).astype(jnp.int32)])

    return pl.pallas_call(
        functools.partial(_softmax_agg_kernel, node_block=node_block),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(num_tiles,),
            in_specs=[
                pl.BlockSpec((1, tile), lambda t, meta: (t, 0)),
                pl.BlockSpec((1, tile), lambda t, meta: (t, 0)),
                pl.BlockSpec((tile, d), lambda t, meta: (t, 0)),
                pl.BlockSpec((1, node_block), lambda t, meta: (meta[0, t], 0)),
                pl.BlockSpec((1, node_block), lambda t, meta: (meta[0, t], 0)),
            ],
            out_specs=pl.BlockSpec(
                (node_block, d), lambda t, meta: (meta[0, t], 0)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((num_node_blocks * node_block, d),
                                       msg_p.dtype),
        interpret=interpret,
    )(meta, scores_p, local_dst_p, msg_p, mx, den)


def _gather_msg_tile(mmap_ref, msg_ref, tile):
    """In-kernel message gather: this grid step's tile of rows from the
    VMEM-resident message block, via the scalar-prefetched slot -> row map
    (-1 slots produce zero rows)."""
    t = pl.program_id(0)
    rows = mmap_ref[pl.ds(t * tile, tile)]
    valid = rows >= 0
    mt = jnp.take(msg_ref[...], jnp.where(valid, rows, 0), axis=0)
    return jnp.where(valid[:, None], mt.astype(jnp.float32), 0.0)


def _softmax_agg_gather_kernel(mmap_ref, meta_ref, scores_ref, dst_ref,
                               msg_ref, mx_ref, den_ref, out_ref, *,
                               node_block):
    t = pl.program_id(0)
    is_first = meta_ref[1, t]

    @pl.when(is_first == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = scores_ref[0, :].astype(jnp.float32)          # [tile]
    dst = dst_ref[0, :]                               # [tile]
    tile = s.shape[0]
    valid = dst < node_block
    dst_c = jnp.where(valid, dst, 0)
    mx = mx_ref[0, :]
    den = den_ref[0, :]
    att = jnp.exp(s - mx[dst_c]) / jnp.maximum(den[dst_c], 1e-38)
    att = jnp.where(valid, att, 0.0)                  # [tile]

    msg_t = _gather_msg_tile(mmap_ref, msg_ref, tile)  # [tile, d]
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (node_block, tile), 0)
    onehot = (node_ids == dst[None, :]).astype(jnp.float32)
    contrib = jax.lax.dot(
        onehot, att[:, None] * msg_t, preferred_element_type=jnp.float32,
    )                                                 # [NB, d]
    out_ref[...] += contrib.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("node_block", "num_node_blocks", "interpret")
)
def seg_softmax_agg_gather_padded(
    scores_p: jnp.ndarray,     # [T, tile] dst-sorted padded scores
    msg: jnp.ndarray,          # [Em, d]  messages in storage order
    mmap: jnp.ndarray,         # [T*tile] int32 slot -> message row, or -1
    local_dst_p: jnp.ndarray,  # [T, tile]
    t2b: jnp.ndarray,          # [T]
    mx: jnp.ndarray,           # [NBk, NB]  from seg_stats_padded
    den: jnp.ndarray,          # [NBk, NB]
    *,
    node_block: int,
    num_node_blocks: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Gather-fused fused-softmax aggregation: messages are gathered inside
    the kernel from their storage-order block (canonical edges or the
    compact unique table), never materialized per padded slot in HBM."""
    num_tiles, tile = scores_p.shape
    em, d = msg.shape
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), t2b[:-1]])
    meta = jnp.stack([t2b.astype(jnp.int32), (t2b != prev).astype(jnp.int32)])

    return pl.pallas_call(
        functools.partial(_softmax_agg_gather_kernel, node_block=node_block),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(num_tiles,),
            in_specs=[
                pl.BlockSpec((1, tile), lambda t, mmap, meta: (t, 0)),
                pl.BlockSpec((1, tile), lambda t, mmap, meta: (t, 0)),
                pl.BlockSpec((em, d), lambda t, mmap, meta: (0, 0)),
                pl.BlockSpec((1, node_block),
                             lambda t, mmap, meta: (meta[0, t], 0)),
                pl.BlockSpec((1, node_block),
                             lambda t, mmap, meta: (meta[0, t], 0)),
            ],
            out_specs=pl.BlockSpec(
                (node_block, d), lambda t, mmap, meta: (meta[0, t], 0)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((num_node_blocks * node_block, d),
                                       msg.dtype),
        interpret=interpret,
    )(mmap, meta, scores_p, local_dst_p, msg, mx, den)


def _weighted_agg_gather_kernel(mmap_ref, meta_ref, scale_ref, dst_ref,
                                msg_ref, out_ref, *, node_block):
    t = pl.program_id(0)
    is_first = meta_ref[1, t]

    @pl.when(is_first == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[0, :]
    tile = dst.shape[0]
    valid = dst < node_block
    scale = jnp.where(valid, scale_ref[0, :].astype(jnp.float32), 0.0)
    msg_t = _gather_msg_tile(mmap_ref, msg_ref, tile)
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (node_block, tile), 0)
    onehot = (node_ids == dst[None, :]).astype(jnp.float32)
    contrib = jax.lax.dot(
        onehot, scale[:, None] * msg_t, preferred_element_type=jnp.float32,
    )
    out_ref[...] += contrib.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("node_block", "num_node_blocks", "interpret")
)
def seg_weighted_agg_gather_padded(
    scale_p: jnp.ndarray,      # [T, tile] per-edge scalar (pads: 0)
    msg: jnp.ndarray,          # [Em, d]  messages in storage order
    mmap: jnp.ndarray,         # [T*tile] int32 slot -> message row, or -1
    local_dst_p: jnp.ndarray,  # [T, tile]
    t2b: jnp.ndarray,          # [T]
    *,
    node_block: int,
    num_node_blocks: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Gather-fused weighted aggregation (RGCN-style sum/mean numerator)."""
    num_tiles, tile = scale_p.shape
    em, d = msg.shape
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), t2b[:-1]])
    meta = jnp.stack([t2b.astype(jnp.int32), (t2b != prev).astype(jnp.int32)])

    return pl.pallas_call(
        functools.partial(_weighted_agg_gather_kernel, node_block=node_block),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(num_tiles,),
            in_specs=[
                pl.BlockSpec((1, tile), lambda t, mmap, meta: (t, 0)),
                pl.BlockSpec((1, tile), lambda t, mmap, meta: (t, 0)),
                pl.BlockSpec((em, d), lambda t, mmap, meta: (0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (node_block, d), lambda t, mmap, meta: (meta[0, t], 0)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((num_node_blocks * node_block, d),
                                       msg.dtype),
        interpret=interpret,
    )(mmap, meta, scale_p, local_dst_p, msg)


def _weighted_agg_kernel(meta_ref, scale_ref, dst_ref, msg_ref, out_ref, *,
                         node_block):
    t = pl.program_id(0)
    is_first = meta_ref[1, t]

    @pl.when(is_first == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[0, :]
    tile = dst.shape[0]
    valid = dst < node_block
    scale = jnp.where(valid, scale_ref[0, :].astype(jnp.float32), 0.0)
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (node_block, tile), 0)
    onehot = (node_ids == dst[None, :]).astype(jnp.float32)
    contrib = jax.lax.dot(
        onehot, scale[:, None] * msg_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += contrib.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("node_block", "num_node_blocks", "interpret")
)
def seg_weighted_agg_padded(
    scale_p: jnp.ndarray,      # [T, tile] per-edge scalar (pads: 0); ones for plain sum
    msg_p: jnp.ndarray,        # [T*tile, d]
    local_dst_p: jnp.ndarray,  # [T, tile]
    t2b: jnp.ndarray,          # [T]
    *,
    node_block: int,
    num_node_blocks: int,
    interpret: bool = False,
) -> jnp.ndarray:
    num_tiles, tile = scale_p.shape
    d = msg_p.shape[-1]
    prev = jnp.concatenate([jnp.array([-1], jnp.int32), t2b[:-1]])
    meta = jnp.stack([t2b.astype(jnp.int32), (t2b != prev).astype(jnp.int32)])

    return pl.pallas_call(
        functools.partial(_weighted_agg_kernel, node_block=node_block),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(num_tiles,),
            in_specs=[
                pl.BlockSpec((1, tile), lambda t, meta: (t, 0)),
                pl.BlockSpec((1, tile), lambda t, meta: (t, 0)),
                pl.BlockSpec((tile, d), lambda t, meta: (t, 0)),
            ],
            out_specs=pl.BlockSpec(
                (node_block, d), lambda t, meta: (meta[0, t], 0)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((num_node_blocks * node_block, d),
                                       msg_p.dtype),
        interpret=interpret,
    )(meta, scale_p, local_dst_p, msg_p)
