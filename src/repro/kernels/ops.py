"""jit'd wrapper ops around the Pallas templates + XLA fallbacks.

These are the operators the Hector code generator instantiates. Every op has
three interchangeable execution paths selected by ``backend``:

  'xla'               tile-aligned einsum formulation (natively differentiable,
                      GSPMD-shardable; used on CPU and in the multi-pod dry-run)
  'pallas'            the TPU kernel (custom_vjp; backward = template-derived
                      outer-product GEMM + traversal instances, paper §3.5)
  'pallas_interpret'  same kernel body executed in interpret mode (CPU tests)

Numerical contract: all paths match ``kernels/ref.py`` oracles.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro import compat
from repro.kernels import layout as L
from repro.kernels import ref as R
from repro.kernels import segment_mm as SK
from repro.kernels import traversal as TK

Backend = str  # 'xla' | 'pallas' | 'pallas_interpret'


# ---------------------------------------------------------------------------
# device-side layout bundles (pytrees: arrays are leaves, shape metadata is
# static aux data, so whole layouts can flow through jit as arguments and
# still parameterize the kernel factories with plain Python ints)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class PaddedSegmentsDev:
    row_map: jnp.ndarray      # [Rp]
    inv_map: jnp.ndarray      # [M]
    t2g: jnp.ndarray          # [T]
    tile: int
    num_groups: int


jtu.register_pytree_node(
    PaddedSegmentsDev,
    lambda p: ((p.row_map, p.inv_map, p.t2g), (p.tile, p.num_groups)),
    lambda aux, ch: PaddedSegmentsDev(*ch, *aux),
)


@dataclasses.dataclass(frozen=True, eq=False)
class BlockedCSRDev:
    edge_map: jnp.ndarray         # [Ep] canonical edge index or -1
    edge_map_unique: jnp.ndarray  # [Ep] compact (unique-pair) row or -1
    local_dst: jnp.ndarray        # [T, tile]
    t2b: jnp.ndarray              # [T]
    edge_tile: int
    node_block: int
    num_node_blocks: int
    num_nodes: int


jtu.register_pytree_node(
    BlockedCSRDev,
    lambda b: ((b.edge_map, b.edge_map_unique, b.local_dst, b.t2b),
               (b.edge_tile, b.node_block, b.num_node_blocks, b.num_nodes)),
    lambda aux, ch: BlockedCSRDev(*ch, *aux),
)


def padded_segments_dev(ps: L.PaddedSegments) -> PaddedSegmentsDev:
    return PaddedSegmentsDev(
        row_map=jnp.asarray(ps.row_map),
        inv_map=jnp.asarray(ps.inv_map),
        t2g=jnp.asarray(ps.tile_to_group),
        tile=ps.tile,
        num_groups=ps.num_groups,
    )


def blocked_csr_dev(
    bc: L.BlockedCSR, perm_dst: np.ndarray,
    edge_to_unique: Optional[np.ndarray] = None,
) -> BlockedCSRDev:
    """Compose dst-sorted edge_map with perm_dst -> canonical edge indices.

    With ``edge_to_unique`` given, also precompute the slot -> compact-row
    map (``edge_map_unique``), so traversal kernels can gather COMPACT-layout
    messages straight from the unique-pair table in-kernel.
    """
    edge_map = np.where(
        bc.edge_map >= 0, np.asarray(perm_dst)[np.maximum(bc.edge_map, 0)], -1
    ).astype(np.int32)
    if edge_to_unique is None:
        edge_map_u = edge_map
    else:
        e2u = np.asarray(edge_to_unique)
        edge_map_u = np.where(
            edge_map >= 0, e2u[np.maximum(edge_map, 0)], -1
        ).astype(np.int32)
    t = bc.num_tiles
    return BlockedCSRDev(
        edge_map=jnp.asarray(edge_map),
        edge_map_unique=jnp.asarray(edge_map_u),
        local_dst=jnp.asarray(bc.local_dst.reshape(t, bc.edge_tile)),
        t2b=jnp.asarray(bc.tile_to_block),
        edge_tile=bc.edge_tile,
        node_block=bc.node_block,
        num_node_blocks=bc.num_node_blocks,
        num_nodes=bc.num_nodes,
    )


@dataclasses.dataclass(frozen=True)
class _Static:
    """Wrap static metadata (e.g. shape tuples) riding inside custom_vjp
    residuals: the payload lives in the pytree *treedef*, so it stays a
    plain Python value under jit instead of becoming a traced leaf."""

    value: tuple


jtu.register_pytree_node(
    _Static, lambda s: ((), s.value), lambda aux, _: _Static(aux))


def pad_rows(x: jnp.ndarray, row_map: jnp.ndarray,
             fill: float = 0.0) -> jnp.ndarray:
    """Gather rows into the padded layout; pad rows get ``fill``."""
    valid = (row_map >= 0)
    xp = x[jnp.maximum(row_map, 0)]
    if x.ndim == 1:
        return jnp.where(valid, xp, fill)
    return jnp.where(valid[:, None], xp, fill)


# ---------------------------------------------------------------------------
# segment MM (the GEMM template)
# ---------------------------------------------------------------------------
def _segment_mm_xla_padded(x_p, w, t2g, scale_p, tile):
    t = t2g.shape[0]
    xt = x_p.reshape(t, tile, x_p.shape[-1])
    wt = w[t2g]                                    # [T, k, n]
    y = jnp.einsum("tck,tkn->tcn", xt, wt,
                   preferred_element_type=jnp.float32)
    y = y.reshape(t * tile, -1).astype(x_p.dtype)
    if scale_p is not None:
        y = y * scale_p
    return y


def _fit_tile_n(n: int, tile_n: int) -> int:
    """Largest usable column tile: ``tile_n`` capped at ``n``, falling back
    to ``n`` itself when it does not divide evenly."""
    tn = min(tile_n, n)
    return n if n % tn else tn


def _fit_tile_rows(lay_tile: int, tile_rows: Optional[int]) -> int:
    """Effective kernel row tile: a requested sub-tile of the layout tile
    (each sub-tile then still lies within one type segment), or the layout
    tile itself when unset/incompatible."""
    if tile_rows is None or tile_rows <= 0 or lay_tile % tile_rows:
        return lay_tile
    return tile_rows


def _subtile_t2g(t2g: jnp.ndarray, lay_tile: int, tile_rows: int):
    """Expand the tile->group map to sub-tile granularity (each layout tile
    splits into ``lay_tile // tile_rows`` kernel tiles of the same group,
    so the map stays non-decreasing and group-aligned)."""
    if tile_rows == lay_tile:
        return t2g
    return jnp.repeat(t2g, lay_tile // tile_rows)


@functools.lru_cache(maxsize=None)
def _make_pallas_segment_mm(tile_rows: int, tile_n: int, num_groups: int,
                            with_scale: bool, interpret: bool):
    kw = dict(tile_rows=tile_rows, tile_n=tile_n, interpret=interpret)

    @jax.custom_vjp
    def f(x_p, w, scale_p, t2g):
        y = SK.segment_mm_padded(x_p, w, t2g, scale_p if with_scale else None,
                                 **kw)
        return y

    def fwd(x_p, w, scale_p, t2g):
        y_pre = SK.segment_mm_padded(x_p, w, t2g, None, **kw)
        y = y_pre * scale_p if with_scale else y_pre
        return y, (x_p, w, scale_p, t2g, y_pre)

    def bwd(res, dy):
        x_p, w, scale_p, t2g, y_pre = res
        dys = dy * scale_p if with_scale else dy
        w_t = jnp.swapaxes(w, 1, 2)
        dx = SK.segment_mm_padded(
            dys, w_t, t2g, None,
            tile_rows=tile_rows, tile_n=_fit_tile_n(w.shape[1], tile_n),
            interpret=interpret,
        )
        dw = SK.segment_outer_padded(
            x_p, dys, t2g, num_groups=num_groups, tile_rows=tile_rows,
            interpret=interpret,
        )
        # groups with zero rows own no tiles -> their dW block is never
        # visited (uninitialized); mask them to exact zeros.
        present = compat.segment_sum(
            jnp.ones_like(t2g), t2g, num_groups
        ) > 0
        dw = jnp.where(present[:, None, None], dw, 0.0).astype(w.dtype)
        if with_scale:
            dscale = jnp.sum(dy * y_pre, axis=1, keepdims=True).astype(scale_p.dtype)
        else:
            dscale = jnp.zeros_like(scale_p)
        dt2g = np.zeros(t2g.shape, dtype=jax.dtypes.float0)
        return dx, dw, dscale, dt2g

    f.defvjp(fwd, bwd)
    return f


def segment_mm(
    x_sorted: jnp.ndarray,                  # [M, k] type-sorted rows
    w: jnp.ndarray,                         # [R, k, n]
    lay: PaddedSegmentsDev,
    row_scale: Optional[jnp.ndarray] = None,  # [M]
    backend: Backend = "xla",
    tile_n: int = 128,
    tile_rows: Optional[int] = None,        # sub-tile of lay.tile (tuner knob)
) -> jnp.ndarray:
    """Y = X @ W[type] (+ per-row scale), X presorted by type. -> [M, n]."""
    if x_sorted.shape[0] == 0:
        # empty block (e.g. a sampled hop with no edges): no tiles to sweep
        return jnp.zeros((0, w.shape[-1]), x_sorted.dtype)
    x_p = pad_rows(x_sorted, lay.row_map)
    scale_p = None
    if row_scale is not None:
        scale_p = pad_rows(row_scale, lay.row_map)[:, None]
    tr = _fit_tile_rows(lay.tile, tile_rows)
    t2g = _subtile_t2g(lay.t2g, lay.tile, tr)
    if backend == "xla":
        y_p = _segment_mm_xla_padded(x_p, w, t2g, scale_p, tr)
    else:
        interpret = backend == "pallas_interpret"
        tn = _fit_tile_n(w.shape[-1], tile_n)
        f = _make_pallas_segment_mm(tr, tn, lay.num_groups,
                                    scale_p is not None, interpret)
        if scale_p is None:
            scale_p = jnp.ones((x_p.shape[0], 1), x_p.dtype)
        y_p = f(x_p, w, scale_p, t2g)
    return y_p[lay.inv_map]


def gather_mm(
    feats: jnp.ndarray,                     # [N, k] node features
    w: jnp.ndarray,                         # [R, k, n]
    gather_idx: jnp.ndarray,                # [M] e.g. src / unique_src
    lay: PaddedSegmentsDev,
    row_scale: Optional[jnp.ndarray] = None,
    backend: Backend = "xla",
) -> jnp.ndarray:
    """Full GEMM template: Y = X[G] @ W[T] (+ scale). Gather runs as an XLA
    fused gather feeding the kernel (TPU adaptation, DESIGN.md §3)."""
    return segment_mm(feats[gather_idx], w, lay, row_scale, backend)


@functools.lru_cache(maxsize=None)
def _make_pallas_segment_mm_gather(tile_rows: int, tile_n: int,
                                   num_groups: int, with_scale: bool,
                                   interpret: bool):
    kw = dict(tile_rows=tile_rows, tile_n=tile_n, interpret=interpret)

    @jax.custom_vjp
    def f(x, w, scale_p, gidx, t2g):
        return SK.segment_mm_gather_padded(
            x, w, gidx, t2g, scale_p if with_scale else None, **kw)

    def fwd(x, w, scale_p, gidx, t2g):
        y_pre = SK.segment_mm_gather_padded(x, w, gidx, t2g, None, **kw)
        y = y_pre * scale_p if with_scale else y_pre
        return y, (x, w, scale_p, gidx, t2g, y_pre)

    def bwd(res, dy):
        x, w, scale_p, gidx, t2g, y_pre = res
        dys = dy * scale_p if with_scale else dy
        w_t = jnp.swapaxes(w, 1, 2)
        # template-derived backward: a GEMM instance over padded dY rows,
        # then the gather access scheme transposes into a scatter-add that
        # routes each padded row's gradient back to its source row.
        dxg = SK.segment_mm_padded(
            dys, w_t, t2g, None,
            tile_rows=tile_rows, tile_n=_fit_tile_n(w.shape[1], tile_n),
            interpret=interpret,
        )
        valid = gidx >= 0
        dx = jnp.zeros_like(x).at[jnp.where(valid, gidx, 0)].add(
            jnp.where(valid[:, None], dxg, 0.0).astype(x.dtype))
        # dW needs X in padded-row order; materialized here only, i.e. only
        # on the training path — the forward/serving path never builds it.
        x_p = jnp.where(valid[:, None], x[jnp.maximum(gidx, 0)], 0)
        dw = SK.segment_outer_padded(
            x_p, dys, t2g, num_groups=num_groups, tile_rows=tile_rows,
            interpret=interpret,
        )
        present = compat.segment_sum(jnp.ones_like(t2g), t2g, num_groups) > 0
        dw = jnp.where(present[:, None, None], dw, 0.0).astype(w.dtype)
        if with_scale:
            dscale = jnp.sum(dy * y_pre, axis=1,
                             keepdims=True).astype(scale_p.dtype)
        else:
            dscale = jnp.zeros_like(scale_p)
        f0 = jax.dtypes.float0
        return (dx, dw, dscale, np.zeros(gidx.shape, f0),
                np.zeros(t2g.shape, f0))

    f.defvjp(fwd, bwd)
    return f


def segment_mm_gather(
    x_src: jnp.ndarray,                     # [Nx, k] ungathered source rows
    w: jnp.ndarray,                         # [R, k, n]
    lay: PaddedSegmentsDev,
    gather_rows: jnp.ndarray,               # [Rp] slot -> source row, or -1
    row_scale: Optional[jnp.ndarray] = None,  # [M] canonical per-row scale
    backend: Backend = "xla",
    tile_n: int = 128,
    tile_rows: Optional[int] = None,        # sub-tile of lay.tile (tuner knob)
) -> jnp.ndarray:
    """Y = X[G] @ W[type] with the gather folded into the kernel. -> [M, n].

    ``gather_rows`` is the padded gather-index layout
    (``layout.compose_gather_rows``): it composes the access-scheme gather
    list (edge src / edge dst / unique src) with the tile padding map, so on
    the Pallas backends the ``[M, k]``/``[Rp, k]`` input copy that
    ``gather_mm`` materializes never exists — each kernel grid step reads
    its rows straight out of the VMEM-resident source block. The XLA
    backend keeps the materialized formulation (XLA fuses the gather
    itself).
    """
    n = w.shape[-1]
    m = int(lay.inv_map.shape[0])
    if m == 0:
        # empty block (e.g. a sampled hop with no edges): no tiles to sweep
        return jnp.zeros((0, n), x_src.dtype)
    scale_p = None
    if row_scale is not None:
        scale_p = pad_rows(row_scale, lay.row_map)[:, None]
    tr = _fit_tile_rows(lay.tile, tile_rows)
    t2g = _subtile_t2g(lay.t2g, lay.tile, tr)
    if backend == "xla":
        valid = gather_rows >= 0
        x_p = jnp.where(valid[:, None],
                        x_src[jnp.maximum(gather_rows, 0)], 0)
        y_p = _segment_mm_xla_padded(x_p, w, t2g, scale_p, tr)
    else:
        interpret = backend == "pallas_interpret"
        tn = _fit_tile_n(n, tile_n)
        f = _make_pallas_segment_mm_gather(tr, tn, lay.num_groups,
                                           scale_p is not None, interpret)
        if scale_p is None:
            scale_p = jnp.ones((gather_rows.shape[0], 1), x_src.dtype)
        y_p = f(x_src, w, scale_p, gather_rows, t2g)
    return y_p[lay.inv_map]


# ---------------------------------------------------------------------------
# traversal ops
# ---------------------------------------------------------------------------
def _pad_edges(x: jnp.ndarray, bc: BlockedCSRDev, fill: float) -> jnp.ndarray:
    """Canonical edge tensor -> padded dst-sorted layout."""
    valid = bc.edge_map >= 0
    xp = x[jnp.maximum(bc.edge_map, 0)]
    if x.ndim == 1:
        xp = jnp.where(valid, xp, fill)
        return xp.reshape(-1, bc.edge_tile)
    return jnp.where(valid[:, None], xp, fill)


@functools.lru_cache(maxsize=None)
def _make_pallas_softmax_agg(node_block: int, num_node_blocks: int,
                             num_nodes: int, interpret: bool):
    kw = dict(node_block=node_block, num_node_blocks=num_node_blocks,
              interpret=interpret)

    @jax.custom_vjp
    def f(scores, msg, dst, bc_edge_map, bc_local_dst, bc_t2b):
        scores_p = jnp.where(
            bc_edge_map >= 0, scores[jnp.maximum(bc_edge_map, 0)], TK._NEG_INF
        ).reshape(-1, bc_local_dst.shape[-1])
        msg_p = jnp.where(
            (bc_edge_map >= 0)[:, None],
            msg[jnp.maximum(bc_edge_map, 0)], 0.0,
        )
        mx, den = TK.seg_stats_padded(scores_p, bc_local_dst, bc_t2b, **kw)
        out = TK.seg_softmax_agg_padded(
            scores_p, msg_p, bc_local_dst, bc_t2b, mx, den, **kw
        )
        return out[:num_nodes]

    def fwd(scores, msg, dst, bc_edge_map, bc_local_dst, bc_t2b):
        shapes = _Static((bc_edge_map.shape, bc_local_dst.shape,
                          bc_t2b.shape))
        out = f(scores, msg, dst, bc_edge_map, bc_local_dst, bc_t2b)
        att = R.edge_softmax_ref(scores, dst, num_nodes)
        return out, (att, msg, dst, shapes)

    def bwd_full(res, dout):
        att, msg, dst, shapes = res
        g = dout[dst]
        dmsg = (att[:, None] * g).astype(msg.dtype)
        datt = jnp.sum(msg * g, axis=-1)
        c = compat.segment_sum(att * datt, dst, num_nodes)
        dscores = (att * (datt - c[dst])).astype(att.dtype)
        f0 = jax.dtypes.float0
        em, ld, tb = shapes.value
        return (
            dscores, dmsg,
            np.zeros(dst.shape, dtype=f0),
            np.zeros(em, dtype=f0),
            np.zeros(ld, dtype=f0),
            np.zeros(tb, dtype=f0),
        )

    f.defvjp(fwd, bwd_full)
    return f


@functools.lru_cache(maxsize=None)
def _make_pallas_softmax_agg_gather(node_block: int, num_node_blocks: int,
                                    num_nodes: int, identity_rows: bool,
                                    interpret: bool):
    """``identity_rows=True`` specializes for canonical-order messages:
    the backward computes dmsg directly instead of an identity
    gather/scatter pair."""
    kw = dict(node_block=node_block, num_node_blocks=num_node_blocks,
              interpret=interpret)

    @jax.custom_vjp
    def f(scores, msg, dst, msg_rows, bc_edge_map, mmap, bc_local_dst,
          bc_t2b):
        # scores are 1-D scalars: padding them stays outside the kernel
        # (cheap); the feature-wide message gather moves inside it.
        scores_p = jnp.where(
            bc_edge_map >= 0, scores[jnp.maximum(bc_edge_map, 0)],
            TK._NEG_INF,
        ).reshape(-1, bc_local_dst.shape[-1])
        mx, den = TK.seg_stats_padded(scores_p, bc_local_dst, bc_t2b, **kw)
        out = TK.seg_softmax_agg_gather_padded(
            scores_p, msg, mmap, bc_local_dst, bc_t2b, mx, den, **kw
        )
        return out[:num_nodes]

    def fwd(scores, msg, dst, msg_rows, bc_edge_map, mmap, bc_local_dst,
            bc_t2b):
        shapes = _Static((msg_rows.shape, bc_edge_map.shape, mmap.shape,
                          bc_local_dst.shape, bc_t2b.shape))
        out = f(scores, msg, dst, msg_rows, bc_edge_map, mmap,
                bc_local_dst, bc_t2b)
        att = R.edge_softmax_ref(scores, dst, num_nodes)
        return out, (att, msg, dst, msg_rows, shapes)

    def bwd(res, dout):
        att, msg, dst, msg_rows, shapes = res
        g = dout[dst]                                # [E, d]
        contrib = (att[:, None] * g).astype(msg.dtype)
        if identity_rows:
            msg_e = msg
            dmsg = contrib
        else:                                        # training path only
            msg_e = jnp.take(msg, msg_rows, axis=0)
            dmsg = jnp.zeros_like(msg).at[msg_rows].add(contrib)
        datt = jnp.sum(msg_e * g, axis=-1)
        c = compat.segment_sum(att * datt, dst, num_nodes)
        dscores = (att * (datt - c[dst])).astype(att.dtype)
        f0 = jax.dtypes.float0
        mr, em, mm, ld, tb = shapes.value
        return (dscores, dmsg,
                np.zeros(dst.shape, f0), np.zeros(mr, f0),
                np.zeros(em, f0), np.zeros(mm, f0),
                np.zeros(ld, f0), np.zeros(tb, f0))

    f.defvjp(fwd, bwd)
    return f


def _msg_slot_map(bc: BlockedCSRDev,
                  msg_rows: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Padded slot -> message-row map for in-kernel message gathers."""
    if msg_rows is None:
        return bc.edge_map
    return jnp.where(
        bc.edge_map >= 0, msg_rows[jnp.maximum(bc.edge_map, 0)], -1)


def edge_softmax_agg(
    scores: jnp.ndarray,        # [E] canonical order
    msg: jnp.ndarray,           # [Em, d] in storage order (see msg_rows)
    dst: jnp.ndarray,           # [E] canonical destination ids
    num_nodes: int,
    bc: Optional[BlockedCSRDev] = None,
    backend: Backend = "xla",
    msg_rows: Optional[jnp.ndarray] = None,   # [E] edge -> msg row, or None
    msg_slot_map: Optional[jnp.ndarray] = None,  # [Ep] precomposed slot map
    fuse_gather: bool = True,
) -> jnp.ndarray:
    """out[v] = Σ_{e→v} softmax(scores)_e · msg_e — the fused traversal region.

    ``msg_rows`` lets messages live in a compact storage (e.g. the unique
    (src, etype) table with ``edge_to_unique`` as the map); with
    ``fuse_gather`` (Pallas backends) the per-edge message gather happens
    inside the kernel via the slot map, so no dst-sorted ``[Ep, d]`` copy is
    materialized. ``fuse_gather=False`` keeps the materialized-gather kernel
    (equivalence baseline).
    """
    if dst.shape[0] == 0:
        return jnp.zeros((num_nodes, msg.shape[-1]), msg.dtype)
    if backend == "xla" or bc is None:
        msg_e = msg if msg_rows is None else msg[msg_rows]
        return R.softmax_agg_ref(scores, msg_e, dst, num_nodes)
    interpret = backend == "pallas_interpret"
    if fuse_gather:
        rows = (msg_rows if msg_rows is not None
                else jnp.arange(dst.shape[0], dtype=jnp.int32))
        if msg_slot_map is None:
            msg_slot_map = _msg_slot_map(bc, msg_rows)
        f = _make_pallas_softmax_agg_gather(bc.node_block,
                                            bc.num_node_blocks,
                                            num_nodes, msg_rows is None,
                                            interpret)
        return f(scores, msg, dst, rows, bc.edge_map, msg_slot_map,
                 bc.local_dst, bc.t2b)
    msg_e = msg if msg_rows is None else msg[msg_rows]
    f = _make_pallas_softmax_agg(bc.node_block, bc.num_node_blocks,
                                 num_nodes, interpret)
    return f(scores, msg_e, dst, bc.edge_map, bc.local_dst, bc.t2b)


@functools.lru_cache(maxsize=None)
def _make_pallas_weighted_agg(node_block: int, num_node_blocks: int,
                              num_nodes: int, interpret: bool):
    kw = dict(node_block=node_block, num_node_blocks=num_node_blocks,
              interpret=interpret)

    @jax.custom_vjp
    def f(scale, msg, dst, bc_edge_map, bc_local_dst, bc_t2b):
        scale_p = jnp.where(
            bc_edge_map >= 0, scale[jnp.maximum(bc_edge_map, 0)], 0.0
        ).reshape(-1, bc_local_dst.shape[-1])
        msg_p = jnp.where(
            (bc_edge_map >= 0)[:, None],
            msg[jnp.maximum(bc_edge_map, 0)], 0.0,
        )
        out = TK.seg_weighted_agg_padded(scale_p, msg_p, bc_local_dst,
                                         bc_t2b, **kw)
        return out[:num_nodes]

    def fwd(scale, msg, dst, bc_edge_map, bc_local_dst, bc_t2b):
        shapes = _Static((bc_edge_map.shape, bc_local_dst.shape,
                          bc_t2b.shape))
        out = f(scale, msg, dst, bc_edge_map, bc_local_dst, bc_t2b)
        return out, (scale, msg, dst, shapes)

    def bwd(res, dout):
        scale, msg, dst, shapes = res
        g = dout[dst]
        dmsg = (scale[:, None] * g).astype(msg.dtype)
        dscale = jnp.sum(msg * g, axis=-1).astype(scale.dtype)
        f0 = jax.dtypes.float0
        em, ld, tb = shapes.value
        return (dscale, dmsg, np.zeros(dst.shape, f0),
                np.zeros(em, f0), np.zeros(ld, f0), np.zeros(tb, f0))

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _make_pallas_weighted_agg_gather(node_block: int, num_node_blocks: int,
                                     num_nodes: int, identity_rows: bool,
                                     interpret: bool):
    kw = dict(node_block=node_block, num_node_blocks=num_node_blocks,
              interpret=interpret)

    @jax.custom_vjp
    def f(scale, msg, dst, msg_rows, bc_edge_map, mmap, bc_local_dst,
          bc_t2b):
        scale_p = jnp.where(
            bc_edge_map >= 0, scale[jnp.maximum(bc_edge_map, 0)], 0.0
        ).reshape(-1, bc_local_dst.shape[-1])
        out = TK.seg_weighted_agg_gather_padded(
            scale_p, msg, mmap, bc_local_dst, bc_t2b, **kw)
        return out[:num_nodes]

    def fwd(scale, msg, dst, msg_rows, bc_edge_map, mmap, bc_local_dst,
            bc_t2b):
        shapes = _Static((msg_rows.shape, bc_edge_map.shape, mmap.shape,
                          bc_local_dst.shape, bc_t2b.shape))
        out = f(scale, msg, dst, msg_rows, bc_edge_map, mmap, bc_local_dst,
                bc_t2b)
        return out, (scale, msg, dst, msg_rows, shapes)

    def bwd(res, dout):
        scale, msg, dst, msg_rows, shapes = res
        g = dout[dst]
        contrib = (scale[:, None] * g).astype(msg.dtype)
        if identity_rows:
            msg_e = msg
            dmsg = contrib
        else:                                        # training path only
            msg_e = jnp.take(msg, msg_rows, axis=0)
            dmsg = jnp.zeros_like(msg).at[msg_rows].add(contrib)
        dscale = jnp.sum(msg_e * g, axis=-1).astype(scale.dtype)
        f0 = jax.dtypes.float0
        mr, em, mm, ld, tb = shapes.value
        return (dscale, dmsg,
                np.zeros(dst.shape, f0), np.zeros(mr, f0),
                np.zeros(em, f0), np.zeros(mm, f0),
                np.zeros(ld, f0), np.zeros(tb, f0))

    f.defvjp(fwd, bwd)
    return f


def weighted_agg(
    scale: Optional[jnp.ndarray],   # [E] or None
    msg: jnp.ndarray,               # [Em, d] in storage order (see msg_rows)
    dst: jnp.ndarray,
    num_nodes: int,
    bc: Optional[BlockedCSRDev] = None,
    backend: Backend = "xla",
    msg_rows: Optional[jnp.ndarray] = None,
    msg_slot_map: Optional[jnp.ndarray] = None,
    fuse_gather: bool = True,
) -> jnp.ndarray:
    """out[v] = Σ_{e→v} scale_e · msg_e (gather semantics as edge_softmax_agg)."""
    if dst.shape[0] == 0:
        return jnp.zeros((num_nodes, msg.shape[-1]), msg.dtype)
    if backend == "xla" or bc is None:
        msg_e = msg if msg_rows is None else msg[msg_rows]
        return R.weighted_agg_ref(scale, msg_e, dst, num_nodes)
    if scale is None:
        scale = jnp.ones(dst.shape[0], msg.dtype)
    interpret = backend == "pallas_interpret"
    if fuse_gather:
        rows = (msg_rows if msg_rows is not None
                else jnp.arange(dst.shape[0], dtype=jnp.int32))
        if msg_slot_map is None:
            msg_slot_map = _msg_slot_map(bc, msg_rows)
        f = _make_pallas_weighted_agg_gather(bc.node_block,
                                             bc.num_node_blocks,
                                             num_nodes, msg_rows is None,
                                             interpret)
        return f(scale, msg, dst, rows, bc.edge_map, msg_slot_map,
                 bc.local_dst, bc.t2b)
    msg_e = msg if msg_rows is None else msg[msg_rows]
    f = _make_pallas_weighted_agg(bc.node_block, bc.num_node_blocks,
                                  num_nodes, interpret)
    return f(scale, msg_e, dst, bc.edge_map, bc.local_dst, bc.t2b)


def edge_softmax(scores: jnp.ndarray, dst: jnp.ndarray,
                 num_nodes: int) -> jnp.ndarray:
    """Per-edge stabilized softmax over incoming-edge groups (XLA)."""
    return R.edge_softmax_ref(scores, dst, num_nodes)
