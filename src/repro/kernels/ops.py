"""jit'd wrapper ops around the Pallas templates + XLA fallbacks.

These are the operators the Hector code generator instantiates. Every op has
three interchangeable execution paths selected by ``backend``:

  'xla'               tile-aligned einsum formulation (natively differentiable,
                      GSPMD-shardable; used on CPU and in the multi-pod dry-run)
  'pallas'            the TPU kernel (custom_vjp; backward = template-derived
                      outer-product GEMM + traversal instances, paper §3.5)
  'pallas_interpret'  same kernel body executed in interpret mode (CPU tests)

Numerical contract: all paths match ``kernels/ref.py`` oracles.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import layout as L
from repro.kernels import ref as R
from repro.kernels import segment_mm as SK
from repro.kernels import traversal as TK

Backend = str  # 'xla' | 'pallas' | 'pallas_interpret'


# ---------------------------------------------------------------------------
# device-side layout bundles
# ---------------------------------------------------------------------------
class PaddedSegmentsDev(NamedTuple):
    row_map: jnp.ndarray      # [Rp]
    inv_map: jnp.ndarray      # [M]
    t2g: jnp.ndarray          # [T]
    tile: int
    num_groups: int


class BlockedCSRDev(NamedTuple):
    edge_map: jnp.ndarray     # [Ep] canonical edge index or -1
    local_dst: jnp.ndarray    # [T, tile]
    t2b: jnp.ndarray          # [T]
    edge_tile: int
    node_block: int
    num_node_blocks: int
    num_nodes: int


def padded_segments_dev(ps: L.PaddedSegments) -> PaddedSegmentsDev:
    return PaddedSegmentsDev(
        row_map=jnp.asarray(ps.row_map),
        inv_map=jnp.asarray(ps.inv_map),
        t2g=jnp.asarray(ps.tile_to_group),
        tile=ps.tile,
        num_groups=ps.num_groups,
    )


def blocked_csr_dev(bc: L.BlockedCSR, perm_dst: np.ndarray) -> BlockedCSRDev:
    """Compose dst-sorted edge_map with perm_dst -> canonical edge indices."""
    edge_map = np.where(
        bc.edge_map >= 0, np.asarray(perm_dst)[np.maximum(bc.edge_map, 0)], -1
    ).astype(np.int32)
    t = bc.num_tiles
    return BlockedCSRDev(
        edge_map=jnp.asarray(edge_map),
        local_dst=jnp.asarray(bc.local_dst.reshape(t, bc.edge_tile)),
        t2b=jnp.asarray(bc.tile_to_block),
        edge_tile=bc.edge_tile,
        node_block=bc.node_block,
        num_node_blocks=bc.num_node_blocks,
        num_nodes=bc.num_nodes,
    )


def pad_rows(x: jnp.ndarray, row_map: jnp.ndarray,
             fill: float = 0.0) -> jnp.ndarray:
    """Gather rows into the padded layout; pad rows get ``fill``."""
    valid = (row_map >= 0)
    xp = x[jnp.maximum(row_map, 0)]
    if x.ndim == 1:
        return jnp.where(valid, xp, fill)
    return jnp.where(valid[:, None], xp, fill)


# ---------------------------------------------------------------------------
# segment MM (the GEMM template)
# ---------------------------------------------------------------------------
def _segment_mm_xla_padded(x_p, w, t2g, scale_p, tile):
    t = t2g.shape[0]
    xt = x_p.reshape(t, tile, x_p.shape[-1])
    wt = w[t2g]                                    # [T, k, n]
    y = jnp.einsum("tck,tkn->tcn", xt, wt,
                   preferred_element_type=jnp.float32)
    y = y.reshape(t * tile, -1).astype(x_p.dtype)
    if scale_p is not None:
        y = y * scale_p
    return y


@functools.lru_cache(maxsize=None)
def _make_pallas_segment_mm(tile_rows: int, tile_n: int, num_groups: int,
                            with_scale: bool, interpret: bool):
    kw = dict(tile_rows=tile_rows, tile_n=tile_n, interpret=interpret)

    @jax.custom_vjp
    def f(x_p, w, scale_p, t2g):
        y = SK.segment_mm_padded(x_p, w, t2g, scale_p if with_scale else None,
                                 **kw)
        return y

    def fwd(x_p, w, scale_p, t2g):
        y_pre = SK.segment_mm_padded(x_p, w, t2g, None, **kw)
        y = y_pre * scale_p if with_scale else y_pre
        return y, (x_p, w, scale_p, t2g, y_pre)

    def bwd(res, dy):
        x_p, w, scale_p, t2g, y_pre = res
        dys = dy * scale_p if with_scale else dy
        w_t = jnp.swapaxes(w, 1, 2)
        dx = SK.segment_mm_padded(
            dys, w_t, t2g, None,
            tile_rows=tile_rows, tile_n=min(tile_n, w.shape[1]),
            interpret=interpret,
        )
        dw = SK.segment_outer_padded(
            x_p, dys, t2g, num_groups=num_groups, tile_rows=tile_rows,
            interpret=interpret,
        )
        # groups with zero rows own no tiles -> their dW block is never
        # visited (uninitialized); mask them to exact zeros.
        present = jax.ops.segment_sum(
            jnp.ones_like(t2g), t2g, num_segments=num_groups
        ) > 0
        dw = jnp.where(present[:, None, None], dw, 0.0).astype(w.dtype)
        if with_scale:
            dscale = jnp.sum(dy * y_pre, axis=1, keepdims=True).astype(scale_p.dtype)
        else:
            dscale = jnp.zeros_like(scale_p)
        dt2g = np.zeros(t2g.shape, dtype=jax.dtypes.float0)
        return dx, dw, dscale, dt2g

    f.defvjp(fwd, bwd)
    return f


def segment_mm(
    x_sorted: jnp.ndarray,                  # [M, k] type-sorted rows
    w: jnp.ndarray,                         # [R, k, n]
    lay: PaddedSegmentsDev,
    row_scale: Optional[jnp.ndarray] = None,  # [M]
    backend: Backend = "xla",
    tile_n: int = 128,
) -> jnp.ndarray:
    """Y = X @ W[type] (+ per-row scale), X presorted by type. -> [M, n]."""
    if x_sorted.shape[0] == 0:
        # empty block (e.g. a sampled hop with no edges): no tiles to sweep
        return jnp.zeros((0, w.shape[-1]), x_sorted.dtype)
    x_p = pad_rows(x_sorted, lay.row_map)
    scale_p = None
    if row_scale is not None:
        scale_p = pad_rows(row_scale, lay.row_map)[:, None]
    if backend == "xla":
        y_p = _segment_mm_xla_padded(x_p, w, lay.t2g, scale_p, lay.tile)
    else:
        interpret = backend == "pallas_interpret"
        n = w.shape[-1]
        tn = n if n % min(tile_n, n) else min(tile_n, n)
        if n % tn:
            tn = n
        f = _make_pallas_segment_mm(lay.tile, tn, lay.num_groups,
                                    scale_p is not None, interpret)
        if scale_p is None:
            scale_p = jnp.ones((x_p.shape[0], 1), x_p.dtype)
        y_p = f(x_p, w, scale_p, lay.t2g)
    return y_p[lay.inv_map]


def gather_mm(
    feats: jnp.ndarray,                     # [N, k] node features
    w: jnp.ndarray,                         # [R, k, n]
    gather_idx: jnp.ndarray,                # [M] e.g. src / unique_src
    lay: PaddedSegmentsDev,
    row_scale: Optional[jnp.ndarray] = None,
    backend: Backend = "xla",
) -> jnp.ndarray:
    """Full GEMM template: Y = X[G] @ W[T] (+ scale). Gather runs as an XLA
    fused gather feeding the kernel (TPU adaptation, DESIGN.md §3)."""
    return segment_mm(feats[gather_idx], w, lay, row_scale, backend)


# ---------------------------------------------------------------------------
# traversal ops
# ---------------------------------------------------------------------------
def _pad_edges(x: jnp.ndarray, bc: BlockedCSRDev, fill: float) -> jnp.ndarray:
    """Canonical edge tensor -> padded dst-sorted layout."""
    valid = bc.edge_map >= 0
    xp = x[jnp.maximum(bc.edge_map, 0)]
    if x.ndim == 1:
        xp = jnp.where(valid, xp, fill)
        return xp.reshape(-1, bc.edge_tile)
    return jnp.where(valid[:, None], xp, fill)


@functools.lru_cache(maxsize=None)
def _make_pallas_softmax_agg(node_block: int, num_node_blocks: int,
                             num_nodes: int, interpret: bool):
    kw = dict(node_block=node_block, num_node_blocks=num_node_blocks,
              interpret=interpret)

    @jax.custom_vjp
    def f(scores, msg, dst, bc_edge_map, bc_local_dst, bc_t2b):
        scores_p = jnp.where(
            bc_edge_map >= 0, scores[jnp.maximum(bc_edge_map, 0)], TK._NEG_INF
        ).reshape(-1, bc_local_dst.shape[-1])
        msg_p = jnp.where(
            (bc_edge_map >= 0)[:, None],
            msg[jnp.maximum(bc_edge_map, 0)], 0.0,
        )
        mx, den = TK.seg_stats_padded(scores_p, bc_local_dst, bc_t2b, **kw)
        out = TK.seg_softmax_agg_padded(
            scores_p, msg_p, bc_local_dst, bc_t2b, mx, den, **kw
        )
        return out[:num_nodes]

    res_shapes = {}

    def fwd(scores, msg, dst, bc_edge_map, bc_local_dst, bc_t2b):
        res_shapes["edge_map"] = bc_edge_map.shape
        res_shapes["local_dst"] = bc_local_dst.shape
        res_shapes["t2b"] = bc_t2b.shape
        out = f(scores, msg, dst, bc_edge_map, bc_local_dst, bc_t2b)
        att = R.edge_softmax_ref(scores, dst, num_nodes)
        return out, (att, msg, dst)

    def bwd_full(res, dout):
        att, msg, dst = res
        g = dout[dst]
        dmsg = (att[:, None] * g).astype(msg.dtype)
        datt = jnp.sum(msg * g, axis=-1)
        c = jax.ops.segment_sum(att * datt, dst, num_segments=num_nodes)
        dscores = (att * (datt - c[dst])).astype(att.dtype)
        f0 = jax.dtypes.float0
        return (
            dscores, dmsg,
            np.zeros(dst.shape, dtype=f0),
            np.zeros(res_shapes["edge_map"], dtype=f0),
            np.zeros(res_shapes["local_dst"], dtype=f0),
            np.zeros(res_shapes["t2b"], dtype=f0),
        )

    f.defvjp(fwd, bwd_full)
    return f


def edge_softmax_agg(
    scores: jnp.ndarray,        # [E] canonical order
    msg: jnp.ndarray,           # [E, d] canonical order
    dst: jnp.ndarray,           # [E] canonical destination ids
    num_nodes: int,
    bc: Optional[BlockedCSRDev] = None,
    backend: Backend = "xla",
) -> jnp.ndarray:
    """out[v] = Σ_{e→v} softmax(scores)_e · msg_e — the fused traversal region."""
    if msg.shape[0] == 0:
        return jnp.zeros((num_nodes, msg.shape[-1]), msg.dtype)
    if backend == "xla" or bc is None:
        return R.softmax_agg_ref(scores, msg, dst, num_nodes)
    interpret = backend == "pallas_interpret"
    f = _make_pallas_softmax_agg(bc.node_block, bc.num_node_blocks,
                                 num_nodes, interpret)
    return f(scores, msg, dst, bc.edge_map, bc.local_dst, bc.t2b)


@functools.lru_cache(maxsize=None)
def _make_pallas_weighted_agg(node_block: int, num_node_blocks: int,
                              num_nodes: int, interpret: bool):
    kw = dict(node_block=node_block, num_node_blocks=num_node_blocks,
              interpret=interpret)

    @jax.custom_vjp
    def f(scale, msg, dst, bc_edge_map, bc_local_dst, bc_t2b):
        scale_p = jnp.where(
            bc_edge_map >= 0, scale[jnp.maximum(bc_edge_map, 0)], 0.0
        ).reshape(-1, bc_local_dst.shape[-1])
        msg_p = jnp.where(
            (bc_edge_map >= 0)[:, None],
            msg[jnp.maximum(bc_edge_map, 0)], 0.0,
        )
        out = TK.seg_weighted_agg_padded(scale_p, msg_p, bc_local_dst,
                                         bc_t2b, **kw)
        return out[:num_nodes]

    shapes = {}

    def fwd(scale, msg, dst, bc_edge_map, bc_local_dst, bc_t2b):
        shapes["m"] = (bc_edge_map.shape, bc_local_dst.shape, bc_t2b.shape)
        out = f(scale, msg, dst, bc_edge_map, bc_local_dst, bc_t2b)
        return out, (scale, msg, dst)

    def bwd(res, dout):
        scale, msg, dst = res
        g = dout[dst]
        dmsg = (scale[:, None] * g).astype(msg.dtype)
        dscale = jnp.sum(msg * g, axis=-1).astype(scale.dtype)
        f0 = jax.dtypes.float0
        em, ld, tb = shapes["m"]
        return (dscale, dmsg, np.zeros(dst.shape, f0),
                np.zeros(em, f0), np.zeros(ld, f0), np.zeros(tb, f0))

    f.defvjp(fwd, bwd)
    return f


def weighted_agg(
    scale: Optional[jnp.ndarray],   # [E] or None
    msg: jnp.ndarray,               # [E, d]
    dst: jnp.ndarray,
    num_nodes: int,
    bc: Optional[BlockedCSRDev] = None,
    backend: Backend = "xla",
) -> jnp.ndarray:
    """out[v] = Σ_{e→v} scale_e · msg_e."""
    if msg.shape[0] == 0:
        return jnp.zeros((num_nodes, msg.shape[-1]), msg.dtype)
    if backend == "xla" or bc is None:
        return R.weighted_agg_ref(scale, msg, dst, num_nodes)
    if scale is None:
        scale = jnp.ones(msg.shape[0], msg.dtype)
    interpret = backend == "pallas_interpret"
    f = _make_pallas_weighted_agg(bc.node_block, bc.num_node_blocks,
                                  num_nodes, interpret)
    return f(scale, msg, dst, bc.edge_map, bc.local_dst, bc.t2b)


def edge_softmax(scores: jnp.ndarray, dst: jnp.ndarray,
                 num_nodes: int) -> jnp.ndarray:
    """Per-edge stabilized softmax over incoming-edge groups (XLA)."""
    return R.edge_softmax_ref(scores, dst, num_nodes)
