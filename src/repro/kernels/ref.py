"""Pure-jnp oracles for every Pallas kernel (correctness references).

These are deliberately simple (per-row weight gather, compat.segment_*) and
O(E·d·f) regardless of layout — the kernels must match them bit-for-bit in
f32 (tolerance for bf16).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import compat


def segment_mm_ref(x: jnp.ndarray, w: jnp.ndarray, seg_ids: jnp.ndarray,
                   row_scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """y[i] = (row_scale[i] *) x[i] @ w[seg_ids[i]].

    x: [M, k]; w: [R, k, n]; seg_ids: [M] int; row_scale: [M] or None.
    """
    y = jnp.einsum("mk,mkn->mn", x, w[seg_ids])
    if row_scale is not None:
        y = y * row_scale[:, None]
    return y


def gather_mm_ref(feats: jnp.ndarray, w: jnp.ndarray, gather_idx: jnp.ndarray,
                  seg_ids: jnp.ndarray,
                  row_scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Full GEMM template: Y = (X[G] @ W[T]) with optional per-row scale."""
    return segment_mm_ref(feats[gather_idx], w, seg_ids, row_scale)


def segment_softmax_stats_ref(scores: jnp.ndarray, dst: jnp.ndarray,
                              num_nodes: int):
    """Per-destination max and sum-exp (the stabilized edge-softmax stats)."""
    mx = compat.segment_max(scores, dst, num_nodes)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)  # nodes with no incoming edges
    den = compat.segment_sum(jnp.exp(scores - mx[dst]), dst,
                             num_nodes)
    return mx, den


def edge_softmax_ref(scores: jnp.ndarray, dst: jnp.ndarray, num_nodes: int):
    mx, den = segment_softmax_stats_ref(scores, dst, num_nodes)
    return jnp.exp(scores - mx[dst]) / jnp.maximum(den[dst], 1e-38)


def softmax_agg_ref(scores: jnp.ndarray, msg: jnp.ndarray, dst: jnp.ndarray,
                    num_nodes: int) -> jnp.ndarray:
    """out[v] = sum_{e: dst(e)=v} softmax(scores)_e * msg[e]."""
    att = edge_softmax_ref(scores, dst, num_nodes)
    return compat.segment_sum(att[:, None] * msg, dst, num_nodes)


def weighted_agg_ref(scale: jnp.ndarray | None, msg: jnp.ndarray,
                     dst: jnp.ndarray, num_nodes: int) -> jnp.ndarray:
    """out[v] = sum_{e: dst(e)=v} scale_e * msg[e] (plain traversal agg)."""
    contrib = msg if scale is None else scale[:, None] * msg
    return compat.segment_sum(contrib, dst, num_nodes)
