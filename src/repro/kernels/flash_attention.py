"""Flash attention Pallas kernel (beyond-paper optimization, §Perf v-F).

Motivation from the dry-run roofline: after v-E, qwen3-14b prefill_32k is
memory-bound on the [B,H,Sq,Sk] score materialization (~21 GB/layer/device).
This kernel keeps scores in VMEM with online-softmax accumulation — the
classic flash schedule adapted to TPU: grid over (batch, head, q-tile), K/V
resident in VMEM (S_local · hd · 2B; ≤ 8 MB at the 32k-per-shard sequence
sharding this framework uses), fori_loop over K tiles on the MXU.

Supports: causal masking, sliding windows (Gemma local layers), logit
softcap (Gemma/Grok), GQA (per-head K/V indexing via the h -> h//g block
index map — KV heads are never replicated), q position offset (decode).

Validated in interpret mode against kernels/ref.py (tests/test_flash.py);
compiled path targets real TPU only.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kt: int, scale: float,
            causal: bool, window: Optional[int], softcap: Optional[float],
            q_offset: int):
    qt, hd = q_ref.shape[1], q_ref.shape[3]
    s_len = k_ref.shape[1]
    qi = pl.program_id(2)

    q = q_ref[0, :, 0, :].astype(jnp.float32)            # [qt, hd]
    q_pos = q_offset + qi * qt + jax.lax.broadcasted_iota(
        jnp.int32, (qt, kt), 0)

    def body(i, carry):
        acc, m, den = carry
        ks = k_ref[0, pl.ds(i * kt, kt), 0, :].astype(jnp.float32)
        vs = v_ref[0, pl.ds(i * kt, kt), 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, ks, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [qt, kt]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = i * kt + jax.lax.broadcasted_iota(jnp.int32, (qt, kt), 1)
        mask = jnp.ones((qt, kt), bool)
        if causal:
            mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        den = den * corr + jnp.sum(p, axis=1)
        acc = acc * corr[:, None] + jax.lax.dot(
            p, vs, preferred_element_type=jnp.float32)
        return acc, m_new, den

    acc0 = jnp.zeros((qt, hd), jnp.float32)
    m0 = jnp.full((qt,), _NEG, jnp.float32)
    den0 = jnp.zeros((qt,), jnp.float32)
    acc, m, den = jax.lax.fori_loop(0, s_len // kt, body, (acc0, m0, den0))
    out = acc / jnp.maximum(den, 1e-38)[:, None]
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "q_offset", "q_tile", "k_tile",
    "interpret"))
def flash_attention(
    q: jnp.ndarray,          # [B, Sq, H, hd]
    k: jnp.ndarray,          # [B, Sk, KV, hd]
    v: jnp.ndarray,          # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    q_tile: int = 128,
    k_tile: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    q_tile = min(q_tile, sq)
    k_tile = min(k_tile, sk)
    assert sq % q_tile == 0 and sk % k_tile == 0
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _kernel, kt=k_tile, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // q_tile),
        in_specs=[
            pl.BlockSpec((1, q_tile, 1, hd), lambda bi, hi, qi: (bi, qi, hi, 0)),
            pl.BlockSpec((1, sk, 1, hd), lambda bi, hi, qi: (bi, 0, hi // g, 0)),
            pl.BlockSpec((1, sk, 1, hd), lambda bi, hi, qi: (bi, 0, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_tile, 1, hd),
                               lambda bi, hi, qi: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
