"""Device-native sampling kernels: fanout selection + layout build on device.

The host ``FanoutSampler`` ranks every candidate in-edge of the frontier by a
counter-based hash key and keeps the ``fanout[etype]`` smallest per
(destination, etype) bin. This module evaluates the *same* selection as two
jit-compiled stages over the device-resident CSC (``core.graph.DeviceGraph``),
with every shape static so steady-state sampling never retraces:

* **stage A** (``make_sample_hop``): per frontier node × etype, enumerate the
  CSC candidate window ``[Fp, R, C]`` (C = the graph's max per-(dst, etype)
  in-degree), key it with ``edge_sample_keys`` (identical positions, identical
  keys as the host — the parity contract), and keep the K smallest keys per
  bin via a stable argsort; also emit the sorted frontier∪sources union and a
  3-vector of (next-frontier, edge, unique-pair) counts — the only values the
  host reads back, to pick the next stage's static bucket.

* **stage B** (``make_build_block``): fixed-shape compaction of the union
  into the block's sorted-unique node set, canonical etype-sorted edge arrays
  with all ``HeteroGraph`` products (dst-CSR, compact-materialization map),
  and the complete ``KernelLayouts`` pytree via the ``device_*`` builders in
  ``kernels/layout.py`` — the device replacement for the loader's host-side
  ``build_minibatch`` layout pass.

Padding discipline: pad nodes sort after real nodes (sentinel id = N), pad
edges carry etype R-1 and connect the first pad node to itself, so every
type-sorted invariant the kernels rely on (non-decreasing etype/ntype/dst,
tile-to-group maps) holds by construction and pad rows only ever feed pad
rows.

The candidate-key generation also has a Pallas formulation
(``candidate_keys``): it is the one stage that is pure elementwise math over
a tile-regular ``[rows, C]`` window, so it maps onto a trivial VMEM-blocked
kernel; selection/compaction stay XLA (sorts and scatters, which Pallas TPU
has no primitive advantage for).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codegen import KernelLayouts
from repro.core.graph import DeviceGraph, GraphTensors
from repro.kernels import layout as L
from repro.kernels import ops as K
from repro.sampling.sampler import FULL_NEIGHBORHOOD, edge_sample_keys, mix32

_U32_MAX = np.uint32(0xFFFFFFFF)
_I32_MAX = np.int32(2**31 - 1)


def effective_fanouts(fanout: np.ndarray, max_bin: int) -> Tuple[int, ...]:
    """Resolve a per-etype fanout vector against the candidate window width:
    ``FULL_NEIGHBORHOOD`` (and any cap beyond the widest bin) becomes C —
    no bin has more than C candidates, so keeping C keys is exact."""
    c = max(1, int(max_bin))
    return tuple(c if int(k) == FULL_NEIGHBORHOOD else min(int(k), c)
                 for k in fanout)


# ---------------------------------------------------------------------------
# candidate keys (XLA + Pallas formulations)
# ---------------------------------------------------------------------------
def _keys_kernel(base_ref, start_ref, cnt_ref, out_ref):
    col = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)
    pos = start_ref[...] + col                      # [tile_rows, C]
    keys = mix32(pos.astype(jnp.uint32) ^ base_ref[0])
    out_ref[...] = jnp.where(col < cnt_ref[...], keys, _U32_MAX)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def _candidate_keys_pallas(starts2, cnts2, base_arr, *, width, interpret):
    rows = starts2.shape[0]
    tile_rows = 8 if rows % 8 == 0 else 1
    return pl.pallas_call(
        _keys_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(rows // tile_rows,),
            in_specs=[
                pl.BlockSpec((tile_rows, 1), lambda i, base: (i, 0)),
                pl.BlockSpec((tile_rows, 1), lambda i, base: (i, 0)),
            ],
            out_specs=pl.BlockSpec((tile_rows, width), lambda i, base: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows, width), jnp.uint32),
        interpret=interpret,
    )(base_arr, starts2, cnts2)


def candidate_keys(starts: jnp.ndarray, cnts: jnp.ndarray, base_key,
                   width: int, backend: str = "xla") -> jnp.ndarray:
    """Masked per-candidate sort keys over the CSC windows.

    ``starts``/``cnts`` are ``[..., 1]``-broadcastable bin starts and sizes
    (any leading shape); returns ``[..., width]`` uint32 keys, invalid
    candidates pinned to ``0xFFFFFFFF`` so they sort last.
    """
    if backend == "xla":
        col = jnp.arange(width, dtype=jnp.int32)
        pos = starts[..., None] + col
        keys = edge_sample_keys(base_key, pos)
        return jnp.where(col < cnts[..., None], keys, _U32_MAX)
    lead = starts.shape
    base_arr = jnp.asarray(base_key, jnp.uint32).reshape(1)
    out = _candidate_keys_pallas(
        starts.reshape(-1, 1), cnts.reshape(-1, 1), base_arr,
        width=width, interpret=(backend == "pallas_interpret"))
    return out.reshape(*lead, width)


# ---------------------------------------------------------------------------
# stage A: per-hop fanout selection
# ---------------------------------------------------------------------------
def make_sample_hop(dg: DeviceGraph, k_eff: Sequence[int], fp: int,
                    backend: str = "xla"):
    """Build the traceable stage-A function for one (frontier bucket, hop
    fanout) configuration.

    ``fn(csc_indptr, csc_src, frontier [fp], base_key) ->
    (union_sorted, sel_src [fp,R,K], sel_valid [fp,R,K], counts [3])`` where
    ``counts = (next-frontier nodes, sampled edges, unique (src,etype)
    pairs)`` — the only device->host readback of the sampling loop.
    """
    n, r = dg.num_nodes, dg.num_etypes
    e = dg.num_edges
    c = max(1, dg.max_bin)
    kvec = tuple(int(k) for k in k_eff)
    kmax = max(1, max(kvec)) if kvec else 1
    if e == 0:
        raise ValueError("device sampling needs a graph with edges")

    def fn(csc_indptr, csc_src, frontier, base_key):
        f = jnp.clip(frontier, 0, n - 1)
        fvalid = frontier < n
        bins = f[:, None] * r + jnp.arange(r, dtype=jnp.int32)[None, :]
        start = csc_indptr[bins]                      # [fp, R]
        cnt = jnp.where(fvalid[:, None], csc_indptr[bins + 1] - start, 0)
        keys = candidate_keys(start, cnt, base_key, c, backend)  # [fp,R,C]
        order = jnp.argsort(keys, axis=-1)[..., :kmax]  # stable: ties by pos
        sel_pos = jnp.take_along_axis(
            start[..., None] + jnp.arange(c, dtype=jnp.int32), order, axis=-1)
        cap = jnp.minimum(cnt, jnp.asarray(kvec, jnp.int32)[None, :])
        sel_valid = jnp.arange(kmax, dtype=jnp.int32) < cap[..., None]
        sel_src = jnp.where(
            sel_valid, csc_src[jnp.clip(sel_pos, 0, e - 1)], n)
        e_cnt = sel_valid.sum(dtype=jnp.int32)

        union = jnp.sort(jnp.concatenate([frontier, sel_src.reshape(-1)]))
        fresh = jnp.concatenate(
            [jnp.ones(1, bool), union[1:] != union[:-1]])
        n_next = ((union < n) & fresh).sum(dtype=jnp.int32)

        pair = jnp.where(sel_valid,
                         sel_src * r + jnp.arange(r, dtype=jnp.int32)[:, None],
                         _I32_MAX)
        sp = jnp.sort(pair.reshape(-1))
        ufresh = jnp.concatenate([jnp.ones(1, bool), sp[1:] != sp[:-1]])
        u_cnt = ((sp < _I32_MAX) & ufresh).sum(dtype=jnp.int32)

        counts = jnp.stack([n_next, e_cnt, u_cnt])
        return union, sel_src, sel_valid, counts

    return fn


# ---------------------------------------------------------------------------
# stage B: block compaction + graph products + kernel layouts
# ---------------------------------------------------------------------------
def make_build_block(dg: DeviceGraph, fp: int, kmax: int, n_pad: int,
                     e_pad: int, u_pad: int, tile: int, node_block: int):
    """Build the traceable stage-B function for one bucket tuple.

    ``fn(union_sorted, sel_src, sel_valid, frontier, node_type) ->
    (GraphTensors, KernelLayouts, node_ids [n_pad], dst_local [fp],
    input_gather [n_pad])`` — a complete device-built block: the exact
    pytrees ``build_minibatch`` produces on the host, with static shapes
    derived from the bucket (``n_pad``/``e_pad``/``u_pad`` are pow2 buckets
    of the stage-A counts; layout row capacities add one worst-case pad tile
    per group so the device ``pad_segments``/``block_csr`` always fit).
    """
    n, r, t = dg.num_nodes, dg.num_etypes, dg.num_ntypes
    for name, v in (("n_pad", n_pad), ("e_pad", e_pad), ("u_pad", u_pad)):
        if v % tile:
            raise ValueError(f"{name}={v} must be a tile multiple")
    nb = (n_pad + node_block - 1) // node_block
    rp_e, rp_u, rp_n = e_pad + r * tile, u_pad + r * tile, n_pad + t * tile
    ep_csr = e_pad + nb * tile
    lf = fp * r * kmax

    def fn(union, sel_src, sel_valid, frontier, node_type_g):
        # ---- node compaction: sorted unique reals, then sentinel pads ----
        fresh = jnp.concatenate([jnp.ones(1, bool), union[1:] != union[:-1]])
        fo = (union < n) & fresh
        rank = jnp.cumsum(fo).astype(jnp.int32) - 1
        n_cnt = fo.sum(dtype=jnp.int32)
        node_ids = jnp.full(n_pad, n, jnp.int32).at[
            jnp.where(fo, rank, n_pad)].set(union, mode="drop")
        node_type = jnp.where(
            node_ids < n, node_type_g[jnp.clip(node_ids, 0, n - 1)], t - 1
        ).astype(jnp.int32)
        ntype_ptr = jnp.concatenate([
            jnp.zeros(1, jnp.int32),
            jnp.cumsum(jnp.zeros(t, jnp.int32).at[node_type].add(1)),
        ]).astype(jnp.int32)

        # ---- edges: localize, canonical etype sort, pad tail ----
        flat_valid = sel_valid.reshape(lf)
        src_g = jnp.where(flat_valid, sel_src.reshape(lf), n)
        dst_g = jnp.where(
            flat_valid,
            jnp.broadcast_to(frontier[:, None, None],
                             (fp, r, kmax)).reshape(lf), n)
        et_f = jnp.broadcast_to(
            jnp.arange(r, dtype=jnp.int32)[None, :, None],
            (fp, r, kmax)).reshape(lf)
        src_l = jnp.searchsorted(node_ids, src_g).astype(jnp.int32)
        dst_l = jnp.searchsorted(node_ids, dst_g).astype(jnp.int32)
        sortkey = jnp.where(flat_valid, et_f, r)
        order = jnp.argsort(sortkey)            # stable: valid first, by et
        e_cnt = flat_valid.sum(dtype=jnp.int32)
        posn = jnp.arange(lf, dtype=jnp.int32)
        dest = jnp.where(posn < e_cnt, posn, e_pad)
        in_range = jnp.arange(e_pad, dtype=jnp.int32) < e_cnt
        # pad edges: first pad node -> itself, etype R-1 (keeps every
        # type-sorted invariant; never read back through the gathers)
        src_c = jnp.where(
            in_range,
            jnp.zeros(e_pad, jnp.int32).at[dest].set(src_l[order],
                                                     mode="drop"), n_cnt)
        dst_c = jnp.where(
            in_range,
            jnp.zeros(e_pad, jnp.int32).at[dest].set(dst_l[order],
                                                     mode="drop"), n_cnt)
        et_c = jnp.where(
            in_range,
            jnp.zeros(e_pad, jnp.int32).at[dest].set(
                sortkey[order].astype(jnp.int32), mode="drop"), r - 1)
        etype_ptr = jnp.concatenate([
            jnp.zeros(1, jnp.int32),
            jnp.cumsum(jnp.zeros(r, jnp.int32).at[et_c].add(1)),
        ]).astype(jnp.int32)

        # ---- destination-sorted view ----
        perm_dst = jnp.argsort(dst_c).astype(jnp.int32)     # stable
        dst_sorted = dst_c[perm_dst]
        dst_ptr = jnp.concatenate([
            jnp.zeros(1, jnp.int32),
            jnp.cumsum(jnp.zeros(n_pad, jnp.int32).at[dst_c].add(1)),
        ]).astype(jnp.int32)

        # ---- compact materialization map (unique (src, etype) pairs) ----
        ukey = et_c * n_pad + src_c          # etype-major, pad pair largest
        sk = jnp.sort(ukey)
        ufresh = jnp.concatenate([jnp.ones(1, bool), sk[1:] != sk[:-1]])
        urank = jnp.cumsum(ufresh).astype(jnp.int32) - 1
        u_tot = ufresh.sum(dtype=jnp.int32)
        padkey = (r - 1) * n_pad + n_cnt
        ukeys = jnp.where(
            jnp.arange(u_pad, dtype=jnp.int32) < u_tot,
            jnp.zeros(u_pad, jnp.int32).at[
                jnp.where(ufresh, urank, u_pad)].set(sk, mode="drop"),
            padkey)
        unique_etype = (ukeys // n_pad).astype(jnp.int32)
        unique_src = (ukeys % n_pad).astype(jnp.int32)
        unique_etype_ptr = jnp.concatenate([
            jnp.zeros(1, jnp.int32),
            jnp.cumsum(jnp.zeros(r, jnp.int32).at[unique_etype].add(1)),
        ]).astype(jnp.int32)
        edge_to_unique = jnp.searchsorted(ukeys, ukey).astype(jnp.int32)

        gt = GraphTensors(
            src=src_c, dst=dst_c, etype=et_c, etype_ptr=etype_ptr,
            node_type=node_type, ntype_ptr=ntype_ptr, perm_dst=perm_dst,
            dst_sorted=dst_sorted, dst_ptr=dst_ptr, unique_src=unique_src,
            unique_etype=unique_etype, unique_etype_ptr=unique_etype_ptr,
            edge_to_unique=edge_to_unique,
            num_nodes=n_pad, num_ntypes=t, num_etypes=r,
        )

        # ---- kernel layouts, entirely on device ----
        e_rm, e_inv, e_t2g = L.device_pad_segments(etype_ptr, et_c, tile,
                                                   rp_e)
        u_rm, u_inv, u_t2g = L.device_pad_segments(
            unique_etype_ptr, unique_etype, tile, rp_u)
        n_rm, n_inv, n_t2g = L.device_pad_segments(ntype_ptr, node_type,
                                                   tile, rp_n)
        em_d, local_dst, t2b = L.device_block_csr(
            dst_ptr, dst_sorted, tile, node_block, ep_csr)
        edge_map = jnp.where(em_d >= 0, perm_dst[jnp.maximum(em_d, 0)], -1)
        edge_map_u = jnp.where(
            edge_map >= 0, edge_to_unique[jnp.maximum(edge_map, 0)], -1)
        kl = KernelLayouts(
            edge_seg=K.PaddedSegmentsDev(e_rm, e_inv, e_t2g, tile, r),
            unique_seg=K.PaddedSegmentsDev(u_rm, u_inv, u_t2g, tile, r),
            node_seg=K.PaddedSegmentsDev(n_rm, n_inv, n_t2g, tile, t),
            blocked=K.BlockedCSRDev(
                edge_map=edge_map, edge_map_unique=edge_map_u,
                local_dst=local_dst.reshape(-1, tile), t2b=t2b,
                edge_tile=tile, node_block=node_block,
                num_node_blocks=nb, num_nodes=n_pad),
            edge_src_rows=L.device_compose_gather_rows(e_rm, src_c),
            edge_dst_rows=L.device_compose_gather_rows(e_rm, dst_c),
            unique_src_rows=L.device_compose_gather_rows(u_rm, unique_src),
            dst_deg=(dst_ptr[1:] - dst_ptr[:-1]).astype(jnp.float32),
        )

        dst_local = jnp.searchsorted(node_ids, frontier).astype(jnp.int32)
        input_gather = jnp.where(node_ids < n, node_ids, 0)
        return gt, kl, node_ids, dst_local, input_gather

    return fn


# ---------------------------------------------------------------------------
# seed preparation (sorted-unique frontier, fixed shape, no readback)
# ---------------------------------------------------------------------------
def make_prep_seeds(num_nodes: int, fp: int):
    """``fn(seeds [B]) -> (frontier [fp], seed_perm [B])``: the sorted unique
    seed frontier (sentinel-padded) and each seed's row in it — the device
    mirror of the host's ``np.unique`` + ``searchsorted`` seed prologue."""

    def fn(seeds):
        su = jnp.sort(seeds)
        fo = jnp.concatenate([jnp.ones(1, bool), su[1:] != su[:-1]])
        rank = jnp.cumsum(fo).astype(jnp.int32) - 1
        frontier = jnp.full(fp, num_nodes, jnp.int32).at[
            jnp.where(fo, rank, fp)].set(su, mode="drop")
        seed_perm = jnp.searchsorted(frontier, seeds).astype(jnp.int32)
        return frontier, seed_perm

    return fn
