"""``repro.obs`` — the unified observability layer.

Three layers, one switchboard:

* **metrics** (``registry.py``): counters / gauges / streaming histograms
  with p50/p90/p99, labeled, JSON-exportable — ``obs.metrics()`` is the one
  handle every component reports through.
* **tracing** (``tracing.py``): nested ``with obs.span("sample")`` phase
  spans with host wall clock and explicit device sync points, exported as
  a Chrome-trace JSON plus per-phase time tables.
* **profiling** (``profile.py``, imported lazily): per-op plan timing on
  the tuner's measurement harness — ``CompiledRGNN.profile()`` and the
  drivers' ``--profile`` flag.

The switchboard is **off by default and zero-overhead when off**: every
``obs.span(...)`` returns a shared no-op span and ``obs.metrics()`` the
shared null registry, so instrumented library code costs one attribute
read per event. Nothing here ever runs inside jitted code — enabling or
disabling observability cannot change trace behavior or compiled shapes.

Drivers opt in with a scope::

    with obs.scope(metrics=True, tracing=True) as sc:
        ...serve loop...
        sc.tracer.write("trace.json")
        sc.registry.export("metrics.json")

Scopes nest; on exit a scope folds its counters/histograms/spans into the
enclosing enabled scope (so ``benchmarks/run.py`` sees the union of every
benchmark's metrics while each ``serve()`` call keeps exact local counts).
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                NULL_REGISTRY, SCHEMA_VERSION)
from repro.obs.tracing import NULL_SPAN, Span, SpanTracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "SpanTracer",
    "Span", "SCHEMA_VERSION", "metrics", "tracer", "span", "scope",
    "metrics_enabled", "tracing_enabled", "enabled",
]


class ObsState:
    """One activation frame: which layers are on, and their sinks."""

    __slots__ = ("metrics_on", "tracing_on", "registry", "tracer", "parent")

    def __init__(self, metrics_on: bool = False, tracing_on: bool = False,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[SpanTracer] = None,
                 parent: Optional["ObsState"] = None):
        self.metrics_on = metrics_on
        self.tracing_on = tracing_on
        self.registry = registry
        self.tracer = tracer
        self.parent = parent


# process-global (NOT thread-local): the prefetch loader's producer thread
# must observe the scope the driver thread opened
_ROOT = ObsState()
_current = _ROOT


def metrics_enabled() -> bool:
    return _current.metrics_on


def tracing_enabled() -> bool:
    return _current.tracing_on


def enabled() -> bool:
    return _current.metrics_on or _current.tracing_on


def metrics():
    """The active metrics registry, or the shared no-op null registry when
    metrics are disabled. Always safe to call from any layer."""
    st = _current
    return st.registry if st.metrics_on else NULL_REGISTRY


def tracer() -> Optional[SpanTracer]:
    """The active span tracer (None when tracing is disabled)."""
    st = _current
    return st.tracer if st.tracing_on else None


def span(name: str, **args):
    """A phase span context manager; the shared no-op span when tracing is
    disabled (one attribute read, no allocation)."""
    st = _current
    if not st.tracing_on:
        return NULL_SPAN
    return st.tracer.span(name, **args)


@contextlib.contextmanager
def scope(metrics: bool = True, tracing: bool = False,
          max_events: int = 200_000) -> Iterator[ObsState]:
    """Activate observability for a ``with`` region.

    A fresh registry/tracer is installed (the previous state is restored on
    exit); on exit, recorded metrics and spans are folded into the
    enclosing scope if one is active, so nested scopes compose
    bottom-up.
    """
    global _current
    st = ObsState(
        metrics_on=metrics,
        tracing_on=tracing,
        registry=MetricsRegistry() if metrics else None,
        tracer=SpanTracer(max_events=max_events) if tracing else None,
        parent=_current,
    )
    _current = st
    try:
        yield st
    finally:
        _current = st.parent
        parent = st.parent
        if st.registry is not None and parent.metrics_on:
            parent.registry.absorb(st.registry)
        if st.tracer is not None and parent.tracing_on:
            parent.tracer.absorb(st.tracer)


@contextlib.contextmanager
def disabled() -> Iterator[None]:
    """Force-disable observability for a region (used by tests and by
    overhead baselines: guarantees the null fast paths are taken)."""
    global _current
    prev = _current
    _current = ObsState(parent=prev)
    try:
        yield
    finally:
        _current = prev
