"""Per-op plan profiler: a Fig.-9-style kernel-time breakdown.

The paper's headline evidence is a per-kernel time attribution (Fig. 9).
This module reproduces that view for any compiled plan by **prefix
differencing**: the jitted prefix of ops ``0..i`` is timed on the tuner's
measurement harness (``tune.tuner.measure_group`` — compile + warmup, then
iterations interleaved round-robin across every prefix so clock drift
cancels out of the differences), and op *i* is charged
``t(prefix_i) - t(prefix_{i-1})``.

Why prefixes and not isolated per-op timing: the production executors run
the whole plan as ONE jitted callable, where XLA fuses across op
boundaries and dead-code-eliminates intermediates no later op reads. An op
timed in isolation pays its own dispatch and materializes everything it
writes, so isolated times can sum to far more than the fused whole — the
breakdown would not add up. Prefix differences telescope: their sum IS the
whole-plan time (up to measurement noise and clamping of negative diffs),
so the attribution is consistent with the end-to-end number by
construction. Each prefix returns only its **live frontier** — the values
ops beyond the cut actually read (recorded by stepping the plan eagerly
through ``codegen.execute_op`` with read tracking) — so a prefix performs
exactly the fused work the full plan has performed by that point.

Entry points:

* ``profile_plan``           — one lowered plan on one graph
* ``profile_block_sequence`` — a sampled mini-batch through all hops (the
  serving hot path; what ``CompiledRGNN.profile(...)`` and
  ``launch/serve_rgnn.py --profile`` render)
* ``profile_minibatch``      — convenience entry over an engine + MiniBatch
* ``profile_train_step``     — forward / backward / optimizer attribution
  for the fused compiled SGD step (phases host-side spans cannot split,
  because the whole step is one jitted callable)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set

import jax
import jax.numpy as jnp

from repro.core import codegen
from repro.core.ir import intra_op as O
from repro.tune.tuner import measure_group


def _op_label(op) -> str:
    if isinstance(op, O.GemmSpec):
        return f"gemm:{op.out}[{op.gather.name.lower()}]"
    if isinstance(op, O.TraversalSpec):
        kinds = {s.kind for s in op.stmts}
        tag = "softmax" if "segment_max" in kinds else \
            "agg" if "segment_sum" in kinds else "ew"
        return f"traversal:{op.stmts[-1].out}[{tag}]"
    if isinstance(op, O.WeightProductSpec):
        return f"wprod:{op.out}"
    return type(op).__name__


def _op_category(op) -> str:
    if isinstance(op, O.GemmSpec):
        return "gemm"
    if isinstance(op, O.TraversalSpec):
        return "traversal"
    if isinstance(op, O.WeightProductSpec):
        return "wprod"
    return "other"


@dataclasses.dataclass
class OpTime:
    """One attributed op instance. ``seconds`` is the prefix difference
    (clamped at 0); ``prefix_seconds`` the cumulative fused time of the
    plan up to and including this op."""

    index: int
    category: str         # gemm | traversal | wprod | glue
    label: str
    seconds: float
    prefix_seconds: float
    hop: int = 0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PlanProfile:
    """Per-op breakdown of one plan (or a block sequence of plans — then
    ``ops`` carries entries from every hop, tagged by ``hop``)."""

    ops: List[OpTime]
    total_seconds: float          # whole plan/sequence, same harness
    backend: str

    @property
    def sum_op_seconds(self) -> float:
        return sum(o.seconds for o in self.ops)

    @property
    def coverage(self) -> float:
        """sum(per-op) / whole-plan. Telescoping makes this ~1.0; drift
        beyond noise means the attribution disagrees with the end-to-end
        measurement."""
        return self.sum_op_seconds / self.total_seconds \
            if self.total_seconds > 0 else float("nan")

    def by_category(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o.category] = out.get(o.category, 0.0) + o.seconds
        return out

    def table(self) -> str:
        """The Fig.-9-style breakdown: one row per op instance, fraction
        of the attributed total, then category subtotals and the coverage
        ratio against the whole-plan measurement."""
        tot = max(self.sum_op_seconds, 1e-12)
        lines = [f"{'op':<40} {'hop':>3} {'time us':>10} {'frac':>6}"]
        for o in self.ops:
            lines.append(f"{o.label:<40} {o.hop:>3} "
                         f"{o.seconds * 1e6:>10.1f} "
                         f"{o.seconds / tot:>6.1%}")
        lines.append("-" * 62)
        for cat, t in sorted(self.by_category().items(),
                             key=lambda kv: -kv[1]):
            lines.append(f"{cat:<44} {t * 1e6:>10.1f} {t / tot:>6.1%}")
        lines.append(
            f"{'sum(ops)':<44} {self.sum_op_seconds * 1e6:>10.1f}")
        lines.append(
            f"{'whole plan':<44} {self.total_seconds * 1e6:>10.1f}   "
            f"(coverage {self.coverage:.0%})")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "backend": self.backend,
            "total_us": self.total_seconds * 1e6,
            "sum_op_us": self.sum_op_seconds * 1e6,
            "coverage": self.coverage,
            "by_category_us": {k: v * 1e6
                               for k, v in self.by_category().items()},
            "ops": [o.to_json() for o in self.ops],
        }


# ---------------------------------------------------------------------------
# read/write recording (liveness for the prefix frontiers)
# ---------------------------------------------------------------------------
class _RecordingEnv(codegen._Env):
    """Environment that records which previously-written names each op
    reads (only names present in ``vals`` count — params and scalars are
    always available and never attributed)."""

    def __init__(self, plan, gt, params, feats):
        super().__init__(plan, gt, params, feats)
        self.reads: Set[str] = set()

    def get(self, name: str):
        if name in self.vals:
            self.reads.add(name)
        return super().get(name)


class _RecordingDict(dict):
    """Derived-weight-product table that records key reads."""

    def __init__(self):
        super().__init__()
        self.reads: Set[str] = set()

    def get(self, k, default=None):
        if k in self:
            self.reads.add(k)
        return super().get(k, default)


@dataclasses.dataclass
class _OpRecord:
    op: object
    wrote_env: List[str]
    wrote_der: List[str]
    reads_env: Set[str]
    reads_der: Set[str]


def _record_plan(plan, params, gt, kl, feats, backend, decisions):
    """Step the plan eagerly, recording per-op reads and writes; returns
    (records, final env) — the liveness input for the prefix frontiers."""
    env = _RecordingEnv(plan, gt, params, feats)
    derived = _RecordingDict()
    records: List[_OpRecord] = []
    for op in plan.ops:
        before_env = dict(env.vals)
        before_der = dict(derived)
        env.reads = set()
        derived.reads = set()
        codegen.execute_op(op, env, derived, gt, kl, backend, decisions)
        records.append(_OpRecord(
            op=op,
            wrote_env=[k for k, v in env.vals.items()
                       if before_env.get(k) is not v],
            wrote_der=[k for k, v in derived.items()
                       if before_der.get(k) is not v],
            reads_env=env.reads,
            reads_der=derived.reads,
        ))
    return records, env


def _frontiers(records: List[_OpRecord], outputs: Sequence[str],
               inputs: Sequence[str] = ()):
    """For each cut i, the live frontier: names written by ops <= i that
    are read by ops > i, or are plan outputs. ``inputs`` are names present
    before op 0 (the layer's input features); a third list marks which of
    them are still read past each cut — a prefix that drops a live input
    from its outputs lets XLA dead-code-eliminate the upstream compute
    that produced it. Returns three parallel lists of tuples (env names,
    derived names, input names), one per op."""
    n = len(records)
    # reads strictly after cut i, computed right-to-left
    after_env = [set() for _ in range(n)]
    after_der = [set() for _ in range(n)]
    reads_env_after: Set[str] = set(outputs)
    reads_der_after: Set[str] = set()
    for i in range(n - 1, -1, -1):
        after_env[i] = set(reads_env_after)
        after_der[i] = set(reads_der_after)
        reads_env_after |= records[i].reads_env
        reads_der_after |= records[i].reads_der
    live_env, live_der, live_inp = [], [], []
    written_env: Set[str] = set()
    written_der: Set[str] = set()
    inputs = set(inputs)
    for i, r in enumerate(records):
        written_env |= set(r.wrote_env)
        written_der |= set(r.wrote_der)
        live_env.append(tuple(sorted((written_env - inputs)
                                     & after_env[i])))
        live_der.append(tuple(sorted(written_der & after_der[i])))
        live_inp.append(tuple(sorted(inputs & after_env[i])))
    return live_env, live_der, live_inp


def _isotonic(xs: Sequence[float]) -> List[float]:
    """Monotone non-decreasing fit (pool adjacent violators). True prefix
    times are non-decreasing by construction; a measured dip is noise.
    Clamping each negative difference at 0 would one-sidedly inflate the
    sum — pooling averages the dip with its neighbours instead, so the
    fitted differences still telescope to (roughly) the final prefix."""
    pools: List[List[float]] = []   # [sum, count]
    for x in xs:
        cur = [float(x), 1]
        while pools and pools[-1][0] * cur[1] > cur[0] * pools[-1][1]:
            prev = pools.pop()
            cur = [prev[0] + cur[0], prev[1] + cur[1]]
        pools.append(cur)
    out: List[float] = []
    for s, c in pools:
        out.extend([s / c] * c)
    return out


# ---------------------------------------------------------------------------
# single-plan profiling
# ---------------------------------------------------------------------------
def profile_plan(plan, params, gt, kl, feats, *, backend: str = "xla",
                 decisions=None, warmup: int = 1,
                 iters: int = 3) -> PlanProfile:
    """Per-op breakdown of one lowered plan on one graph."""
    records, _ = _record_plan(plan, params, gt, kl, feats, backend,
                              decisions)
    # the input features are jit arguments here, so they cannot be
    # dead-code-eliminated — no need to carry them in the frontier
    live_env, live_der, _ = _frontiers(records, plan.outputs)

    def prefix_fn(upto):
        le, ld = live_env[upto], live_der[upto]

        def run(params_, gt_, kl_, feats_):
            env = codegen._Env(plan, gt_, params_, feats_)
            derived: Dict[str, jnp.ndarray] = {}
            for op in plan.ops[:upto + 1]:
                codegen.execute_op(op, env, derived, gt_, kl_, backend,
                                   decisions)
            return ([env.vals[k] for k in le]
                    + [derived[k] for k in ld])
        return run

    args = (params, gt, kl, feats)
    calls = [(jax.jit(prefix_fn(i)), args) for i in range(len(records))]
    calls.append((jax.jit(lambda p, g, k, f: codegen.execute_plan(
        plan, p, g, f, k, backend, decisions)), args))
    times = measure_group(calls, warmup=warmup, iters=iters)
    whole = times.pop()
    fit = _isotonic(times)

    ops: List[OpTime] = []
    prev = 0.0
    for i, (r, t, ft) in enumerate(zip(records, times, fit)):
        ops.append(OpTime(index=i, category=_op_category(r.op),
                          label=_op_label(r.op),
                          seconds=max(ft - prev, 0.0), prefix_seconds=t))
        prev = ft
    return PlanProfile(ops=ops, total_seconds=whole, backend=backend)


# ---------------------------------------------------------------------------
# sampled block sequence (the serving hot path)
# ---------------------------------------------------------------------------
def profile_block_sequence(plans: Sequence, params: Sequence, gts, kls,
                           dst_locals, seed_perm, feats, *,
                           backend: str = "xla", activation: str = "relu",
                           decisions=None, warmup: int = 1,
                           iters: int = 3) -> PlanProfile:
    """Per-op breakdown of one sampled mini-batch through every hop's
    block — the exact computation ``BlockExecutor`` compiles, attributed
    op instance by op instance via prefix differencing. The inter-hop
    frontier narrowing + activation and the final seed gather appear as
    ``glue`` rows."""
    act = codegen._ACTIVATIONS[activation]
    last = len(plans) - 1

    # eager pass: per-hop read/write records + liveness frontiers
    hop_recs, hop_live = [], []
    cur = dict(feats)
    for i, (plan, p, gt, kl) in enumerate(zip(plans, params, gts, kls)):
        records, env = _record_plan(plan, p, gt, kl, cur, backend,
                                    decisions)
        hop_recs.append(records)
        # the hop's sole downstream consumer is the glue, which reads the
        # plan's first output. The hop *input* features must ride in the
        # frontier too while later ops still read them: for hops > 0 they
        # are the previous hops' computed output, and a prefix that drops
        # them lets XLA dead-code-eliminate everything upstream — the
        # prefix sequence stops telescoping.
        hop_live.append(_frontiers(records, plan.outputs[:1],
                                   inputs=["node:" + k for k in cur]))
        h = env.get(plan.outputs[0])[dst_locals[i]]
        if i < last:
            cur = {"feature": act(h)}

    # step list: every (hop, op) plus one glue step per hop
    steps = []   # (hop, op_index | None for the hop's glue)
    for i, records in enumerate(hop_recs):
        steps += [(i, j) for j in range(len(records))]
        steps.append((i, None))

    def prefix_fn(upto):
        cut_hop, cut_op = steps[upto]

        def run(params_, gts_, kls_, dst_locals_, seed_perm_, feats_):
            cur_ = dict(feats_)
            for i in range(cut_hop + 1):
                plan = plans[i]
                env = codegen._Env(plan, gts_[i], params_[i], cur_)
                derived: Dict[str, jnp.ndarray] = {}
                n_ops = (len(plan.ops) if i < cut_hop or cut_op is None
                         else cut_op + 1)
                for op in plan.ops[:n_ops]:
                    codegen.execute_op(op, env, derived, gts_[i], kls_[i],
                                       backend, decisions)
                if i == cut_hop and cut_op is not None:
                    le = hop_live[i][0][cut_op]
                    ld = hop_live[i][1][cut_op]
                    # hop-0 inputs are jit arguments (cannot be DCEd);
                    # later hops' inputs anchor the upstream hops' work
                    li = hop_live[i][2][cut_op] if i > 0 else ()
                    return ([env.vals[k] for k in le]
                            + [derived[k] for k in ld]
                            + [env.vals[k] for k in li])
                h = env.get(plan.outputs[0])[dst_locals_[i]]
                if i == last:
                    return [h[seed_perm_]]
                cur_ = {"feature": act(h)}
            return [cur_["feature"]]
        return run

    args = (list(params), list(gts), list(kls), list(dst_locals),
            seed_perm, feats)
    calls = [(jax.jit(prefix_fn(s)), args) for s in range(len(steps))]
    calls.append((jax.jit(
        lambda p, g, k, d, s_, f: codegen.execute_block_sequence(
            plans, p, g, k, d, s_, f, backend=backend,
            activation=activation, decisions=decisions)), args))
    times = measure_group(calls, warmup=warmup, iters=iters)
    whole = times.pop()
    fit = _isotonic(times)

    ops: List[OpTime] = []
    prev = 0.0
    for (hop, op_idx), t, ft in zip(steps, times, fit):
        if op_idx is None:
            label = ("glue:narrow+seed_gather" if hop == last
                     else f"glue:narrow+{activation}")
            cat, idx = "glue", len(hop_recs[hop])
        else:
            r = hop_recs[hop][op_idx]
            label, cat, idx = _op_label(r.op), _op_category(r.op), op_idx
        ops.append(OpTime(index=idx, category=cat, label=label,
                          seconds=max(ft - prev, 0.0), prefix_seconds=t,
                          hop=hop))
        prev = ft
    return PlanProfile(ops=ops, total_seconds=whole, backend=backend)


def profile_minibatch(engine, params, mb, global_feats, *,
                      warmup: int = 1, iters: int = 3) -> PlanProfile:
    """Convenience entry over an ``RGNNEngine``/``CompiledRGNN`` and a
    ``sampling.MiniBatch`` (the loaders' device-ready bundle)."""
    feats = {"feature": jnp.asarray(global_feats)[mb.input_ids]}
    return profile_block_sequence(
        engine.plans, list(params), list(mb.tensors), list(mb.layouts),
        list(mb.dst_locals), mb.seed_perm, feats,
        backend=engine.cfg.backend, activation=engine.cfg.activation,
        decisions=engine.decisions, warmup=warmup, iters=iters)


# ---------------------------------------------------------------------------
# fused train-step phase attribution
# ---------------------------------------------------------------------------
def profile_train_step(plans: Sequence, opt, state, mb, labels, feats, *,
                       backend: str = "xla", activation: str = "relu",
                       decisions=None, warmup: int = 1,
                       iters: int = 3) -> Dict[str, float]:
    """Forward / backward / optimizer attribution for the compiled sampled
    SGD step. The production step is ONE jitted callable, so host spans
    cannot split it; instead three nested computations are timed with the
    same harness and differenced:

        forward   = t(forward only)
        backward  = t(value_and_grad) - forward
        optimizer = t(full step)      - t(value_and_grad)

    Returns seconds per phase plus the fused total (``total`` is the real
    production step time; the three phases are the attribution).
    """
    from repro.core.executor import softmax_xent

    gts, kls = list(mb.tensors), list(mb.layouts)
    dst_locals, seed_perm = list(mb.dst_locals), mb.seed_perm
    labels = jnp.asarray(labels)

    def fwd(params, f):
        return codegen.execute_block_sequence(
            plans, params, gts, kls, dst_locals, seed_perm, f,
            backend=backend, activation=activation, decisions=decisions)

    def loss_fn(params, f):
        return softmax_xent(fwd(params, f), labels)

    def grad_fn(params, f):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, f)

    def step_fn(state_, f):
        (loss, acc), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state_.params, f)
        return opt.update(grads, state_), loss, acc

    t_fwd, t_grad, t_step = measure_group(
        [(jax.jit(fwd), (state.params, feats)),
         (jax.jit(grad_fn), (state.params, feats)),
         (jax.jit(step_fn), (state, feats))],
        warmup=warmup, iters=iters)
    return {
        "forward": t_fwd,
        "backward": max(t_grad - t_fwd, 0.0),
        "optimizer": max(t_step - t_grad, 0.0),
        "total": t_step,
    }
