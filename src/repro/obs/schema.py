"""Schema validation for the observability JSON artifacts.

Hand-rolled structural checks (no jsonschema dependency) for the two
documents the obs layer exports:

* **Chrome trace** (``SpanTracer.write``): trace-event format — a
  ``traceEvents`` list of ``"M"`` thread-name metadata and ``"X"`` complete
  events with numeric ``ts``/``dur`` in microseconds.
* **Metrics snapshot** (``MetricsRegistry.export``): the
  ``schema_version``-stamped counters/gauges/histograms document.

``validate_*`` return a list of problem strings (empty = valid) so CI gates
(``benchmarks/obs_smoke.py``) can print every violation at once instead of
failing on the first.
"""
from __future__ import annotations

from typing import List

from repro.obs.registry import SCHEMA_VERSION


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_trace(doc) -> List[str]:
    """Structural check of a Chrome trace-event document."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"trace root must be an object, got {type(doc).__name__}"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["trace must carry a 'traceEvents' list"]
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "M"):
            errs.append(f"{where}: unexpected phase type ph={ph!r}")
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            errs.append(f"{where}: missing/empty 'name'")
        for field in ("pid", "tid"):
            if not _is_num(e.get(field)):
                errs.append(f"{where}: '{field}' must be numeric")
        if ph == "X":
            for field in ("ts", "dur"):
                if not _is_num(e.get(field)):
                    errs.append(f"{where}: 'X' event needs numeric "
                                f"'{field}'")
                elif e[field] < 0:
                    errs.append(f"{where}: '{field}' must be >= 0")
    return errs


def validate_metrics(doc) -> List[str]:
    """Structural check of a ``MetricsRegistry.snapshot()`` document."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"metrics root must be an object, got {type(doc).__name__}"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version must be {SCHEMA_VERSION}, "
                    f"got {doc.get('schema_version')!r}")
    for section in ("counters", "gauges", "histograms"):
        items = doc.get(section)
        if not isinstance(items, list):
            errs.append(f"'{section}' must be a list")
            continue
        for i, it in enumerate(items):
            where = f"{section}[{i}]"
            if not isinstance(it, dict):
                errs.append(f"{where}: not an object")
                continue
            if not isinstance(it.get("name"), str) or not it["name"]:
                errs.append(f"{where}: missing/empty 'name'")
            if not isinstance(it.get("labels"), dict):
                errs.append(f"{where}: 'labels' must be an object")
            if section == "counters" and not _is_num(it.get("value")):
                errs.append(f"{where}: counter 'value' must be numeric")
            if section == "gauges" and not _is_num(it.get("value")):
                errs.append(f"{where}: gauge 'value' must be numeric")
            if section == "histograms":
                s = it.get("summary")
                if not isinstance(s, dict):
                    errs.append(f"{where}: histogram needs a 'summary' "
                                f"object")
                    continue
                for field in ("count", "min", "max", "mean",
                              "p50", "p90", "p95", "p99"):
                    if not _is_num(s.get(field)):
                        errs.append(f"{where}: summary '{field}' must be "
                                    f"numeric")
    return errs


def require_phases(doc, phases) -> List[str]:
    """Check that every name in ``phases`` appears as at least one 'X'
    span with dur > 0 (the obs_smoke CI gate: a missing or zero-length
    pipeline phase means the instrumentation regressed)."""
    errs: List[str] = []
    evs = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    for phase in phases:
        spans = [e for e in evs if isinstance(e, dict)
                 and e.get("ph") == "X" and e.get("name") == phase]
        if not spans:
            errs.append(f"required phase span {phase!r} missing from trace")
        elif not any(e.get("dur", 0) > 0 for e in spans):
            errs.append(f"phase span {phase!r} present but all zero-length")
    return errs
