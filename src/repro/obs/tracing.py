"""Phase-attributed span tracer with Chrome-trace export.

``with obs.span("sample"): ...`` records a host-side wall-clock interval,
attributed to the thread that opened it — so the prefetch loader's
``sample``/``layout`` spans land on their own track next to the driver
thread's ``execute`` spans, and ``chrome://tracing`` / Perfetto render the
overlap directly.

Accelerator work is asynchronous, so a span around a dispatched computation
measures dispatch only; spans that should cover device time must end at an
explicit sync point. ``Span.sync(x)`` calls ``jax.block_until_ready`` on
``x`` *inside* the span (and is a no-op passthrough on the disabled-mode
null span, so instrumented code behaves identically either way)::

    with obs.span("execute") as sp:
        logits = executor(params, ...)
        sp.sync(logits)          # device time charged to the span

Spans never run inside compiled code — the tracer is pure host-side Python
with no jax imports on the hot path — so enabling tracing cannot perturb
jit caches or introduce retraces.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, List, Optional


class Span:
    """One open interval. Context manager; reentrant use is not supported
    (open a new span instead)."""

    __slots__ = ("tracer", "name", "args", "t0", "_depth")

    def __init__(self, tracer: "SpanTracer", name: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self._depth = 0

    def __enter__(self) -> "Span":
        self._depth = self.tracer._push(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        self.tracer._pop()
        self.tracer._record(self.name, self.t0, t1, self._depth, self.args)

    def sync(self, x):
        """Block until ``x``'s device computation is done, charging the
        wait to this span; returns ``x``."""
        import jax
        return jax.block_until_ready(x)


class _NullSpan:
    """Disabled-mode span: free to enter/exit, records nothing. ``sync``
    is a passthrough (no implicit device sync in disabled mode — callers
    that need the result synced already block on it themselves)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def sync(self, x):
        return x


NULL_SPAN = _NullSpan()


class SpanTracer:
    """Append-only span log, thread-safe, bounded.

    Timestamps are microseconds since the tracer's epoch (its creation),
    which is what the Chrome trace-event format expects. Completed spans
    are stored as flat dicts; nesting is implicit in the (ts, dur)
    intervals per thread track, exactly how Chrome renders them.
    """

    def __init__(self, max_events: int = 200_000):
        self.max_events = max_events
        self.dropped = 0
        self._epoch = time.perf_counter()
        self._events: List[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: Dict[int, int] = {}       # thread ident -> dense tid
        self._tid_names: Dict[int, str] = {}  # dense tid -> thread name

    # -- span lifecycle (called by Span) --------------------------------
    def span(self, name: str, **args) -> Span:
        return Span(self, name, args)

    def _stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, name: str) -> int:
        st = self._stack()
        st.append(name)
        return len(st) - 1

    def _pop(self) -> None:
        st = self._stack()
        if st:
            st.pop()

    def _tid(self) -> int:
        t = threading.current_thread()
        tid = self._tids.get(t.ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(t.ident, len(self._tids))
                self._tid_names[tid] = t.name
        return tid

    def _record(self, name: str, t0: float, t1: float, depth: int,
                args: dict) -> None:
        ev = {
            "name": name,
            "ts": (t0 - self._epoch) * 1e6,
            "dur": (t1 - t0) * 1e6,
            "tid": self._tid(),
            "depth": depth,
            "args": args,
        }
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    # -- read side ------------------------------------------------------
    @property
    def num_events(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self, name: Optional[str] = None) -> List[dict]:
        with self._lock:
            evs = list(self._events)
        if name is not None:
            evs = [e for e in evs if e["name"] == name]
        return evs

    def phase_totals(self) -> Dict[str, dict]:
        """Aggregate wall time per span name: {name: {count, total_s,
        mean_s, max_s}}. Nested spans each count their own interval."""
        out: Dict[str, dict] = {}
        for e in self.events():
            d = out.setdefault(e["name"], {"count": 0, "total_s": 0.0,
                                           "max_s": 0.0})
            d["count"] += 1
            dur_s = e["dur"] / 1e6
            d["total_s"] += dur_s
            d["max_s"] = max(d["max_s"], dur_s)
        for d in out.values():
            d["mean_s"] = d["total_s"] / d["count"]
        return out

    def phase_table(self) -> str:
        """Fixed-width per-phase time table (the human-readable summary
        the drivers print next to the Chrome trace)."""
        totals = sorted(self.phase_totals().items(),
                        key=lambda kv: -kv[1]["total_s"])
        lines = [f"{'phase':<16} {'count':>6} {'total ms':>10} "
                 f"{'mean ms':>9} {'max ms':>9}"]
        for name, d in totals:
            lines.append(
                f"{name:<16} {d['count']:>6} {d['total_s'] * 1e3:>10.2f} "
                f"{d['mean_s'] * 1e3:>9.3f} {d['max_s'] * 1e3:>9.3f}")
        return "\n".join(lines)

    # -- Chrome trace-event export --------------------------------------
    def chrome_trace(self) -> dict:
        """Trace-event-format document: complete ("X") events per span
        plus thread_name metadata, loadable in chrome://tracing and
        Perfetto."""
        events = []
        with self._lock:
            tid_names = dict(self._tid_names)
            spans = list(self._events)
        for tid, name in sorted(tid_names.items()):
            events.append({
                "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                "args": {"name": name},
            })
        for e in spans:
            events.append({
                "name": e["name"], "ph": "X", "cat": "phase", "pid": 0,
                "tid": e["tid"], "ts": e["ts"], "dur": e["dur"],
                "args": dict(e["args"], depth=e["depth"]),
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    # -- scope merging --------------------------------------------------
    def absorb(self, child: "SpanTracer") -> None:
        """Fold a child scope's spans into this tracer, re-basing their
        timestamps onto this tracer's epoch (thread tracks are re-mapped
        by the child's recorded thread names)."""
        shift = (child._epoch - self._epoch) * 1e6
        with child._lock:
            child_events = list(child._events)
            child_names = dict(child._tid_names)
        with self._lock:
            remap: Dict[int, int] = {}
            for ctid, cname in child_names.items():
                # reuse an existing track with the same thread name
                ntid = next((tid for tid, name in self._tid_names.items()
                             if name == cname), None)
                if ntid is None:
                    ntid = (max(self._tid_names) + 1) if self._tid_names \
                        else 0
                    self._tid_names[ntid] = cname
                remap[ctid] = ntid
            for e in child_events:
                if len(self._events) >= self.max_events:
                    self.dropped += 1
                    continue
                e2 = dict(e)
                e2["ts"] = e["ts"] + shift
                e2["tid"] = remap.get(e["tid"], e["tid"])
                self._events.append(e2)
        self.dropped += child.dropped
