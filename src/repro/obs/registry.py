"""Metrics registry: counters, gauges, and streaming histograms.

One handle — ``obs.metrics()`` — unifies every counter the stack used to
keep as ad-hoc attributes (`_CachedExecutor` hits/traces, loader LRU hit
rates, tuner measurement counts) plus the latency histograms the serving
and training drivers report from. Instruments are keyed by (name, labels):
the same ``counter("cache_hits", cache="block_cache")`` call from any layer
lands on the same object.

Design constraints (why this is not a prometheus client):

* **Zero overhead when disabled.** ``obs.metrics()`` returns the shared
  ``NULL_REGISTRY`` whose instruments are no-op singletons — disabled-mode
  instrumentation costs one attribute read and a call into a ``pass`` body.
  Nothing is ever recorded.
* **Event granularity is per batch / per cache access**, never per element
  or inside compiled code, so the enabled-mode cost is a dict lookup and an
  integer add on the host path.
* **Histograms are streaming** with exact count/sum/min/max and a bounded
  deterministic reservoir for percentiles (no wall-clock or global-RNG
  dependence, so runs are reproducible and tests can pin quantiles).
* **Registries merge**: a scoped registry (one ``serve()`` call) folds its
  instruments into the enclosing scope on exit, so a benchmark driver sees
  the union of every phase it ran while each call still gets exact local
  counts.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Tuple

SCHEMA_VERSION = 1

_QUANTILES = (50.0, 90.0, 95.0, 99.0)


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default) over a sorted
    list. Empty input -> NaN; single sample -> that sample."""
    n = len(sorted_vals)
    if n == 0:
        return float("nan")
    if n == 1:
        return float(sorted_vals[0])
    pos = (q / 100.0) * (n - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac)


class Counter:
    """Monotonic event counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_json(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_json(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "value": self.value}


class Histogram:
    """Streaming distribution: exact count/sum/min/max plus a bounded
    reservoir for percentiles.

    Up to ``max_samples`` every observation is kept (percentiles are then
    exact); past that, a deterministic LCG drives classic reservoir
    sampling, keeping a uniform sample without touching the global RNG.
    """

    __slots__ = ("name", "labels", "max_samples", "count", "total",
                 "min", "max", "_samples", "_lcg")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 max_samples: int = 4096):
        self.name = name
        self.labels = labels
        self.max_samples = max_samples
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: List[float] = []
        self._lcg = 0x2545F491  # fixed seed: deterministic reservoir

    def _rand(self, n: int) -> int:
        # 32-bit LCG (numerical recipes constants); cheap and reproducible
        self._lcg = (1664525 * self._lcg + 1013904223) & 0xFFFFFFFF
        return self._lcg % n

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._samples) < self.max_samples:
            self._samples.append(v)
        else:
            j = self._rand(self.count)
            if j < self.max_samples:
                self._samples[j] = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        return _percentile(sorted(self._samples), q)

    def summary(self) -> dict:
        s = sorted(self._samples)
        out = {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.min if self.count else float("nan"),
            "max": self.max if self.count else float("nan"),
        }
        for q in _QUANTILES:
            out[f"p{q:g}"] = _percentile(s, q)
        return out

    def to_json(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "summary": self.summary()}

    def _absorb(self, other: "Histogram") -> None:
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        for v in other._samples:
            if len(self._samples) < self.max_samples:
                self._samples.append(v)
            else:
                j = self._rand(len(self._samples) + 1)
                if j < self.max_samples:
                    self._samples[j] = v


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Get-or-create instrument store, thread-safe (the prefetch loader's
    producer thread and the driver thread share one registry)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[tuple, Counter] = {}
        self._gauges: Dict[tuple, Gauge] = {}
        self._histograms: Dict[tuple, Histogram] = {}

    # -- instrument accessors -------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter(name, key[1]))
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge(name, key[1]))
        return g

    def histogram(self, name: str, max_samples: int = 4096,
                  **labels) -> Histogram:
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    key, Histogram(name, key[1], max_samples=max_samples))
        return h

    # -- read side ------------------------------------------------------
    def value(self, name: str, **labels) -> Optional[float]:
        """Current value of a counter or gauge (None if never created) —
        the read path the CI gates use instead of reaching into component
        internals."""
        key = (name, _label_key(labels))
        if key in self._counters:
            return self._counters[key].value
        if key in self._gauges:
            return self._gauges[key].value
        return None

    def counter_total(self, name: str) -> int:
        """Sum of a counter across all label sets (0 if absent)."""
        return sum(c.value for (n, _), c in self._counters.items()
                   if n == name)

    def histogram_summary(self, name: str, **labels) -> Optional[dict]:
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        return h.summary() if h is not None else None

    @property
    def num_instruments(self) -> int:
        return (len(self._counters) + len(self._gauges)
                + len(self._histograms))

    # -- export ---------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "schema_version": SCHEMA_VERSION,
                "counters": [c.to_json() for c in self._counters.values()],
                "gauges": [g.to_json() for g in self._gauges.values()],
                "histograms": [h.to_json()
                               for h in self._histograms.values()],
            }

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)

    # -- scope merging --------------------------------------------------
    def absorb(self, child: "MetricsRegistry") -> None:
        """Fold a child scope's instruments into this registry: counters
        add, gauges take the child's last write, histograms merge."""
        with self._lock:
            for (name, lk), c in child._counters.items():
                mine = self._counters.setdefault((name, lk),
                                                 Counter(name, lk))
                mine.value += c.value
            for (name, lk), g in child._gauges.items():
                mine = self._gauges.setdefault((name, lk), Gauge(name, lk))
                mine.value = g.value
            for (name, lk), h in child._histograms.items():
                mine = self._histograms.setdefault(
                    (name, lk), Histogram(name, lk,
                                          max_samples=h.max_samples))
                mine._absorb(h)


# ---------------------------------------------------------------------------
# snapshot readers (for CI gates over exported/returned snapshots)
# ---------------------------------------------------------------------------
def snapshot_value(snap: dict, name: str, **labels) -> Optional[float]:
    """Value of a counter or gauge in a ``snapshot()`` document (None if
    absent) — how the benchmark gates read serve/train telemetry without
    reaching into component internals."""
    want = dict(_label_key(labels))
    for section in ("counters", "gauges"):
        for it in snap.get(section, ()):
            if it["name"] == name and it["labels"] == want:
                return it["value"]
    return None


def snapshot_counter_total(snap: dict, name: str) -> float:
    """Sum of a counter across all label sets in a snapshot (0 if absent)."""
    return sum(it["value"] for it in snap.get("counters", ())
               if it["name"] == name)


def snapshot_histogram(snap: dict, name: str, **labels) -> Optional[dict]:
    """Summary dict of a histogram in a snapshot (None if absent)."""
    want = dict(_label_key(labels))
    for it in snap.get("histograms", ()):
        if it["name"] == name and it["labels"] == want:
            return it["summary"]
    return None


def snapshot_histograms(snap: dict, name: str) -> List[dict]:
    """Every label set of one histogram name in a snapshot:
    ``[{"labels": {...}, "summary": {...}}, ...]``. The multi-tenant
    reader — per-tenant serving latency lands under the same name with a
    ``model=<tenant>`` label, and dashboards/CI enumerate the tenants
    from the snapshot instead of knowing them up front."""
    return [{"labels": it["labels"], "summary": it["summary"]}
            for it in snap.get("histograms", ()) if it["name"] == name]


# ---------------------------------------------------------------------------
# disabled mode: shared no-op singletons
# ---------------------------------------------------------------------------
class _NullCounter:
    __slots__ = ()
    name = "null"
    labels = ()
    value = 0

    def inc(self, n: int = 1) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    name = "null"
    labels = ()
    value = 0.0

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    name = "null"
    labels = ()
    count = 0
    total = 0.0

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return float("nan")

    def summary(self) -> dict:
        return {"count": 0}


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


class NullRegistry:
    """The disabled-mode registry: every instrument is a shared no-op."""

    num_instruments = 0

    def counter(self, name: str, **labels) -> _NullCounter:
        return _NULL_COUNTER

    def gauge(self, name: str, **labels) -> _NullGauge:
        return _NULL_GAUGE

    def histogram(self, name: str, max_samples: int = 4096,
                  **labels) -> _NullHistogram:
        return _NULL_HISTOGRAM

    def value(self, name: str, **labels) -> None:
        return None

    def counter_total(self, name: str) -> int:
        return 0

    def histogram_summary(self, name: str, **labels) -> None:
        return None

    def snapshot(self) -> dict:
        return {"schema_version": SCHEMA_VERSION, "counters": [],
                "gauges": [], "histograms": []}

    def absorb(self, child) -> None:
        pass


NULL_REGISTRY = NullRegistry()
