"""Data-parallel trainer: sharded sampling + one compiled step per batch.

The loop mirrors ``train.trainer.SampledTrainer`` — an ``EpochSeedStream``
shuffles the train ids, each batch becomes one compiled ``grad_and_update``
— but each step is the multi-shard ``ShardedTrainExecutor`` callable:
per-shard forwards, halo-feature all-gather, backward, gradient all-reduce
and optimizer update all inside the single jitted dispatch.

The loop never synchronizes on step results: metrics stay device arrays
until training finishes (``float()`` on a fresh loss would stall the
pipeline every step), so steady state is host-side batch assembly (cached
for recurring seed sets) plus one async dispatch. The only host decision
per step is the compile-cache bucket pick, exactly like the single-box
trainer. ``log_every`` deliberately opts into a sync every N steps.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.optim import AdamW, TrainState
from repro.sampling import EpochSeedStream


def _quiet(*_a, **_k):
    pass


class DistTrainer:
    """Neighbor-sampled data-parallel SGD over a partitioned graph."""

    def __init__(self, engine, feats, labels, train_ids, val_ids=None, *,
                 opt: Optional[AdamW] = None, log=print):
        engine._require_dist()
        self.engine = engine
        self.opt = opt or AdamW(learning_rate=3e-3, weight_decay=0.01)
        self.labels = np.asarray(labels)
        self.train_ids = np.asarray(train_ids, dtype=np.int32)
        self.val_ids = (np.asarray(val_ids, dtype=np.int32)
                        if val_ids is not None and len(val_ids) else None)
        self.log = log or _quiet
        self.batcher = engine.dist_batcher
        self.step_exec = engine.dist_train_executor(self.opt)
        self.own_feats = engine.shard_features(feats)

    def init_state(self, params) -> TrainState:
        return self.opt.init(params)

    # ------------------------------------------------------------------
    def train(self, state: TrainState, *, epochs: int = 1,
              batch_size: int = 32, stream_seed: Optional[int] = None,
              warmup_epochs: int = 1, log_every: int = 0):
        """Run ``epochs`` of data-parallel sampled SGD; returns
        ``(state, stats)``. Metrics are synced once, after the loop."""
        stream = EpochSeedStream(
            self.train_ids, batch_size,
            seed=self.engine.cfg.seed if stream_seed is None else stream_seed)
        bpe = stream.batches_per_epoch
        total_steps = epochs * bpe
        warmup_steps = min(warmup_epochs * bpe, total_steps)

        ex = self.step_exec
        loss_dev: List[jnp.ndarray] = []
        acc_dev: List[jnp.ndarray] = []
        step_times: List[float] = []
        traces_at_warmup = None
        t0_all = time.perf_counter()
        for step in range(total_steps):
            if traces_at_warmup is None and step >= warmup_steps:
                traces_at_warmup = ex.trace_count
            seeds = stream.batch(step)
            smb = self.batcher.build(seeds, step=step,
                                     epoch=stream.epoch_of(step))
            t0 = time.perf_counter()
            with obs.span("dist_train_step", step=step):
                state, metrics = ex.grad_and_update(
                    state, smb, self.labels, self.own_feats)
            step_times.append(time.perf_counter() - t0)
            loss_dev.append(metrics["loss"])    # device array: no sync
            acc_dev.append(metrics["accuracy"])
            if log_every and (step + 1) % log_every == 0:
                self.log(f"[train_dist] step {step+1:5d} "
                         f"loss {float(metrics['loss']):.4f} "
                         f"acc {float(metrics['accuracy']):.2%}")
        t_total = time.perf_counter() - t0_all
        if traces_at_warmup is None:
            traces_at_warmup = ex.trace_count

        losses = [float(x) for x in loss_dev]   # one sync point, at the end
        accs = [float(x) for x in acc_dev]
        stats = {
            "steps": total_steps,
            "batches_per_epoch": bpe,
            "epochs": epochs,
            "batch_size": stream.batch_size,
            "num_partitions": self.engine.cfg.num_partitions,
            "dp": self.engine.cfg.dp,
            "losses": losses,
            "accuracies": accs,
            "final_loss": losses[-1] if losses else float("nan"),
            "step_ms_p50": float(np.percentile(step_times, 50) * 1e3)
            if step_times else float("nan"),
            "seeds_per_s": stream.batch_size * total_steps
            / max(t_total, 1e-9),
            "executor_traces": ex.trace_count,
            "executor_cache_hits": ex.cache_hits,
            "executor_compiled": ex.num_compiled,
            "retraces_after_warmup": ex.trace_count - traces_at_warmup,
            "warmup_steps": warmup_steps,
            **{f"batcher_{k}": v for k, v in self.batcher.stats().items()},
        }
        return state, stats

    # ------------------------------------------------------------------
    def evaluate(self, params, ids=None, *, batch_size: int = 64,
                 epoch: int = 0) -> Dict[str, float]:
        """Sampled loss/accuracy over ``ids`` through the multi-shard serve
        step (fresh neighborhoods, id order)."""
        ids = np.asarray(self.val_ids if ids is None else ids, np.int32)
        serve = self.engine.dist_serve_executor()
        tot_loss, tot_acc, n = 0.0, 0.0, 0
        for lo in range(0, len(ids), batch_size):
            chunk = ids[lo:lo + batch_size]
            smb = self.batcher.build(chunk, step=lo, epoch=epoch)
            logits = serve.run_minibatch(params, smb, self.own_feats)
            from repro.core.executor import softmax_xent
            loss, acc = softmax_xent(logits, jnp.asarray(self.labels[chunk]))
            tot_loss += float(loss) * len(chunk)
            tot_acc += float(acc) * len(chunk)
            n += len(chunk)
        return {"loss": tot_loss / max(n, 1), "accuracy": tot_acc / max(n, 1)}
