"""Cross-shard batch assembly for data-parallel execution.

``ShardedBatcher`` turns one request batch of seed nodes into a
``ShardedMiniBatch``: every per-hop array is stacked to a leading ``[P]``
shard axis so a single ``shard_map``-ped callable can run all shards'
block forwards at once. Three problems are solved on the host, once per
batch, so the compiled step needs **zero** host round-trips:

1. **Seed routing** — each seed goes to its owner shard, in request order;
   each shard's slice is padded to a common power-of-two ``b_max`` with a
   valid owned node (selection per (dst, etype) bin is independent of the
   rest of the batch, so pad seeds never disturb real selections). A
   ``route`` gather index maps request position -> (shard, slot), which the
   executor uses to restore request order from the gathered outputs.

2. **Common buckets** — shards sample different block sizes, but stacking
   needs identical shapes. Per hop, every shard's block is padded to the
   max bucket over shards (``common_block_targets``). The target
   computation is two-pass because raising the unique-pair bucket spends
   extra pad edges/nodes (see ``bucketing.pad_block_graph``).

3. **Fixed-capacity layouts** — ``codegen.build_kernel_layouts`` composes
   gather rows *before* bucket growth, so its row counts depend on block
   content. ``build_fixed_layouts`` instead grows every tile layout to the
   worst-case capacity implied by the (already common) graph buckets —
   ``sum_r ceil(seg_r/tile)*tile <= roundup(total) + groups*tile`` — and
   only then composes the gather rows, making all layout shapes a pure
   function of the bucket sizes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np

from repro.core import codegen
from repro.core.graph import GraphTensors, HeteroGraph
from repro.kernels import layout as L
from repro.kernels import ops as K
from repro.kernels.layout import pow2ceil
from repro.sampling.bucketing import pad_block_graph, pad_index
from repro.sampling.loader import LRUCache, block_signature
from repro.sampling.sampler import FanoutSpec
from repro.dist.partition import GraphPartition
from repro.dist.sampler import ShardedSampler


def common_block_targets(graphs: Sequence[HeteroGraph]) -> tuple:
    """Smallest common ``(n, e, u)`` power-of-two buckets that every graph
    in ``graphs`` can be padded to *exactly* by ``pad_block_graph``.

    Two-pass: the unique-pair target is fixed first because raising it
    costs each graph ``u_t - u_s`` extra pad edges (one per distinct pad
    pair) and ``ceil((u_t - u_s)/R)`` extra pad source nodes, which feed
    into the edge/node targets."""
    num_r = graphs[0].num_etypes
    u_t = max(pow2ceil(g.num_unique + 1) for g in graphs)
    e_t = max(pow2ceil(g.num_edges + (u_t - g.num_unique)) for g in graphs)
    n_t = max(
        pow2ceil(g.num_nodes + max(1, -(-(u_t - g.num_unique) // num_r)))
        for g in graphs)
    return n_t, e_t, u_t


def build_fixed_layouts(hg: HeteroGraph, tile: int = 128,
                        node_block: int = 128) -> codegen.KernelLayouts:
    """``KernelLayouts`` whose every array shape depends only on the graph's
    bucket sizes ``(num_nodes, num_edges, num_unique)`` plus the static
    type/tile counts — not on how edges distribute over segments/blocks.

    Each tile layout is grown to its worst case *before* the gather rows
    are composed (``codegen.build_kernel_layouts`` composes first, so its
    shapes are content-dependent and would not stack across shards)."""
    if tile & (tile - 1):
        raise ValueError("fixed layouts need a power-of-two tile")

    def up(x: int) -> int:
        return -(-x // tile) * tile

    num_r, num_t = hg.num_etypes, hg.num_ntypes
    edge_ps = L.pad_segments_rows(
        L.pad_segments(hg.etype_ptr, tile), up(hg.num_edges) + num_r * tile)
    unique_ps = L.pad_segments_rows(
        L.pad_segments(hg.unique_etype_ptr, tile),
        up(hg.num_unique) + num_r * tile)
    node_ps = L.pad_segments_rows(
        L.pad_segments(hg.ntype_ptr, tile), up(hg.num_nodes) + num_t * tile)
    nb = -(-hg.num_nodes // node_block)
    bc = L.pad_blocked_csr(
        L.block_csr(hg.dst_ptr, edge_tile=tile, node_block=node_block),
        up(hg.num_edges) + nb * tile)
    return codegen.KernelLayouts(
        edge_seg=K.padded_segments_dev(edge_ps),
        unique_seg=K.padded_segments_dev(unique_ps),
        node_seg=K.padded_segments_dev(node_ps),
        blocked=K.blocked_csr_dev(bc, hg.perm_dst, hg.edge_to_unique),
        edge_src_rows=jnp.asarray(L.compose_gather_rows(edge_ps, hg.src)),
        edge_dst_rows=jnp.asarray(L.compose_gather_rows(edge_ps, hg.dst)),
        unique_src_rows=jnp.asarray(
            L.compose_gather_rows(unique_ps, hg.unique_src)),
        dst_deg=jnp.asarray(np.diff(hg.dst_ptr).astype(np.float32)),
    )


def stack_pytrees(trees):
    """Stack a list of structurally identical pytrees leaf-wise along a new
    leading axis (the shard axis)."""
    return jtu.tree_map(lambda *xs: jnp.stack(xs), *trees)


@dataclasses.dataclass
class ShardedMiniBatch:
    """Device-ready bundle for one request batch across all ``P`` shards.

    Every jnp field has a leading shard axis ``[P, ...]`` (except ``route``,
    which lives in request space); static shapes are common across shards by
    construction, so ``shard_map`` can split the shard axis over devices."""

    step: int
    seeds: np.ndarray               # [B] requested seed nodes (global ids)
    shard_seeds: np.ndarray         # [P, b_max] routed + padded seed slices
    tensors: List[GraphTensors]     # per hop, leaves [P, ...]
    layouts: List[codegen.KernelLayouts]  # per hop, leaves [P, ...]
    dst_locals: List[jnp.ndarray]   # per hop [P, rows]
    seed_perm: jnp.ndarray          # [P, b_max] final-frontier row per slot
    owner_rows: jnp.ndarray         # [P, n_in] owner shard of hop-0 inputs
    local_rows: jnp.ndarray         # [P, n_in] row in the owner's table
    mask: jnp.ndarray               # [P, b_max] 1.0 for real request slots
    route: jnp.ndarray              # [B] request pos -> shard*b_max + slot

    @property
    def num_hops(self) -> int:
        return len(self.tensors)

    @property
    def num_shards(self) -> int:
        return int(self.shard_seeds.shape[0])

    @property
    def b_max(self) -> int:
        return int(self.shard_seeds.shape[1])

    def slice_labels(self, labels: np.ndarray) -> jnp.ndarray:
        """Per-shard label slabs ``[P, b_max]`` (pad slots carry the pad
        seed's label; masked out of every loss term)."""
        return jnp.asarray(
            np.asarray(labels)[self.shard_seeds].astype(np.int32))


def route_seeds(part: GraphPartition, seeds: np.ndarray):
    """Split a request batch by owner shard, preserving request order.

    Returns ``(shard_seeds [P, b_max], mask [P, b_max], route [B])`` where
    ``b_max`` is the power-of-two bucket of the largest per-shard count and
    pad slots hold the shard's first owned node."""
    seeds = np.asarray(seeds, dtype=np.int32)
    if seeds.ndim != 1 or seeds.size == 0:
        raise ValueError("seeds must be a non-empty 1-D int array")
    num_parts = part.num_parts
    owners = part.owner_of(seeds).astype(np.int64)
    counts = np.bincount(owners, minlength=num_parts)
    b_max = pow2ceil(int(counts.max()))
    # rank of each request within its owner, in request order
    order = np.argsort(owners, kind="stable")
    starts = np.zeros(num_parts + 1, dtype=np.int64)
    starts[1:] = np.cumsum(counts)
    slots = np.empty(len(seeds), dtype=np.int64)
    slots[order] = np.arange(len(seeds)) - starts[owners[order]]
    shard_seeds = np.repeat(
        part.bounds[:num_parts].astype(np.int32)[:, None], b_max, axis=1)
    shard_seeds[owners, slots] = seeds
    mask = (np.arange(b_max)[None, :] < counts[:, None]).astype(np.float32)
    route = (owners * b_max + slots).astype(np.int32)
    return shard_seeds, mask, route


class ShardedBatcher:
    """Samples + assembles ``ShardedMiniBatch``es for a partitioned graph.

    Caching: batches are memoized by seed bytes + epoch + *partition
    identity* (two partitionings of the same graph must never share
    entries), layouts by padded-block content signature."""

    def __init__(self, part: GraphPartition, fanouts: Sequence[FanoutSpec],
                 *, seed: int = 0, tile: int = 128, node_block: int = 128,
                 cache_batches: int = 64, cache_layouts: int = 256):
        self.part = part
        self.sampler = ShardedSampler(part, fanouts, seed=seed)
        self.tile = tile
        self.node_block = node_block
        self._fanout_key = tuple(
            tuple(int(x) for x in f) for f in self.sampler.fanouts)
        self._part_key = (part.num_parts, part.bounds.tobytes())
        self._batch_cache = LRUCache(cache_batches, "dist-batches")
        self._layout_cache = LRUCache(cache_layouts, "dist-layouts")
        self.host_builds = 0

    # ------------------------------------------------------------------
    def _layouts_for(self, g: HeteroGraph) -> codegen.KernelLayouts:
        key = ("fixed", block_signature(g, self.tile, self.node_block, True))
        kl = self._layout_cache.get(key)
        if kl is None:
            kl = build_fixed_layouts(g, tile=self.tile,
                                     node_block=self.node_block)
            self._layout_cache.put(key, kl)
        return kl

    def build(self, seeds: np.ndarray, step: int = 0,
              epoch: Optional[int] = None) -> ShardedMiniBatch:
        seeds = np.asarray(seeds, dtype=np.int32)
        key = (seeds.tobytes(), epoch, self._fanout_key, self.tile,
               self.node_block, self._part_key)
        hit = self._batch_cache.get(key)
        if hit is not None:
            return dataclasses.replace(hit, step=step)
        mb = self._build(seeds, step, epoch)
        self._batch_cache.put(key, mb)
        return mb

    def _build(self, seeds: np.ndarray, step: int,
               epoch: Optional[int]) -> ShardedMiniBatch:
        self.host_builds += 1
        num_parts = self.part.num_parts
        shard_seeds, mask, route = route_seeds(self.part, seeds)
        seqs = [self.sampler.sample_for_shard(
                    p, shard_seeds[p], batch_index=step, epoch=epoch)
                for p in range(num_parts)]
        num_hops = len(seqs[0].blocks)

        # pad every shard's hop-h block to the common cross-shard buckets
        padded = []
        for h in range(num_hops):
            n_t, e_t, u_t = common_block_targets(
                [s.blocks[h].graph for s in seqs])
            row = [pad_block_graph(s.blocks[h].graph, n_t, e_t, u_t)
                   for s in seqs]
            assert all(g.num_nodes == n_t for g in row)
            padded.append(row)

        # hop-chaining gathers, padded to the (common) downstream buckets
        n_in = padded[0][0].num_nodes
        input_ids = np.stack([pad_index(s.input_node_ids, n_in)
                              for s in seqs])
        last_rows = max(pow2ceil(s.blocks[-1].dst_local.shape[0])
                        for s in seqs)
        dst_locals = []
        for h in range(num_hops):
            tgt = (padded[h + 1][0].num_nodes if h + 1 < num_hops
                   else last_rows)
            dst_locals.append(jnp.asarray(np.stack(
                [pad_index(s.blocks[h].dst_local, tgt) for s in seqs])))

        return ShardedMiniBatch(
            step=step,
            seeds=seeds,
            shard_seeds=shard_seeds,
            tensors=[stack_pytrees([g.to_tensors() for g in row])
                     for row in padded],
            layouts=[stack_pytrees([self._layouts_for(g) for g in row])
                     for row in padded],
            dst_locals=dst_locals,
            seed_perm=jnp.asarray(np.stack([s.seed_perm for s in seqs])),
            owner_rows=jnp.asarray(self.part.owner_of(input_ids)),
            local_rows=jnp.asarray(self.part.local_row(input_ids)),
            mask=jnp.asarray(mask),
            route=jnp.asarray(route),
        )

    def stats(self) -> dict:
        return {
            "host_builds": self.host_builds,
            "batch_cache": self._batch_cache.stats(),
            "layout_cache": self._layout_cache.stats(),
            **self.sampler.stats(),
        }
