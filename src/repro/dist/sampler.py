"""Per-shard fanout sampling over the partitioned graph.

``ShardedSampler.sample_for_shard(p, seeds, ...)`` produces the exact
``BlockSequence`` the single-box ``FanoutSampler`` would produce for the
same seed slice, evaluated from the partition's per-shard tables:

* candidates of a frontier node are enumerated from its **owner's** CSR
  slice, but as *global* dst-sorted positions (each shard keeps the global
  ``dst_ptr`` values of its owned nodes), so the counter-based keys — and
  therefore the k-smallest-key selection per (dst, etype) bin — are
  bit-identical to the single-box stream;
* hop-0 frontiers are shard-local by construction (seeds are routed to
  their owner). Deeper hops contain halo nodes whose in-edges live on other
  shards; those lookups go through the owner's tables and are counted in
  ``halo_lookups`` — in-process they are array reads, in a multi-host
  deployment they become the sampling-service RPC, with identical results
  either way because the key stream is position-based.

The sampling key stream is shared with the single-box path:
``hop_base_key(seed, batch_index, hop, epoch)`` with the *same* batch index
on every shard, so shard-local selections compose to exactly the union
block's edge multiset.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.graph import HeteroGraph
from repro.dist.partition import GraphPartition
from repro.sampling.sampler import (Block, BlockSequence, FanoutSpec,
                                    candidate_positions, hop_base_key,
                                    normalize_fanout, select_by_keys)


class ShardedSampler:
    """Fanout sampling from per-shard partition tables (one instance serves
    every shard: shard state is an argument, not object identity)."""

    def __init__(self, part: GraphPartition, fanouts: Sequence[FanoutSpec],
                 seed: int = 0):
        if not fanouts:
            raise ValueError("need at least one hop fanout")
        self.part = part
        self.hg = part.hg
        self.fanouts = [normalize_fanout(f, self.hg.num_etypes)
                        for f in fanouts]
        self.seed = seed
        # global dst-sorted edge boundary of each shard's slice: position ->
        # owning shard is a searchsorted over this
        self.edge_bounds = self.hg.dst_ptr[part.bounds].astype(np.int64)
        self.local_lookups = 0
        self.halo_lookups = 0

    @property
    def num_hops(self) -> int:
        return len(self.fanouts)

    # ------------------------------------------------------------------
    def _csr_runs(self, p: int, frontier: np.ndarray):
        """(global start, count) of each frontier node's in-edge run, read
        from the node's owner shard tables."""
        owners = self.part.owner_of(frontier)
        starts = np.zeros(len(frontier), dtype=np.int64)
        counts = np.zeros(len(frontier), dtype=np.int64)
        for t in np.unique(owners):
            sh = self.part.shards[int(t)]
            m = owners == t
            local = frontier[m] - sh.lo
            starts[m] = sh.dst_ptr[local]
            counts[m] = sh.dst_ptr[local + 1] - sh.dst_ptr[local]
            n = int(m.sum())
            if int(t) == p:
                self.local_lookups += n
            else:
                self.halo_lookups += n
        return starts, counts

    def _edge_fields(self, pos: np.ndarray):
        """(src, etype) of global dst-sorted positions, read from the edge
        slice of whichever shard owns each position."""
        owners = (np.searchsorted(self.edge_bounds, pos, side="right") - 1)
        src = np.zeros(len(pos), dtype=np.int32)
        et = np.zeros(len(pos), dtype=np.int32)
        for t in np.unique(owners):
            sh = self.part.shards[int(t)]
            m = owners == t
            rel = pos[m] - sh.edge_base
            src[m] = sh.src_d[rel]
            et[m] = sh.etype_d[rel]
        return src, et

    # ------------------------------------------------------------------
    def sample_for_shard(self, p: int, seeds: np.ndarray,
                         batch_index: int = 0,
                         epoch: Optional[int] = None) -> BlockSequence:
        """Sample shard ``p``'s ``BlockSequence`` for its seed slice.

        Bit-identical to ``FanoutSampler(hg, fanouts, seed).sample(seeds,
        batch_index, epoch)`` — the shared key-stream contract.
        """
        seeds = np.asarray(seeds, dtype=np.int32)
        if seeds.ndim != 1 or seeds.size == 0:
            raise ValueError("seeds must be a non-empty 1-D int array")
        if np.any(self.part.owner_of(seeds) != p):
            raise ValueError(f"shard {p} was routed seeds it does not own")

        frontier = np.unique(seeds)
        seed_perm = np.searchsorted(frontier, seeds).astype(np.int32)
        blocks: List[Block] = []
        for hop, fanout in enumerate(reversed(self.fanouts)):
            base = hop_base_key(self.seed, int(batch_index), hop, epoch)
            starts, counts = self._csr_runs(p, frontier)
            pos, owner = candidate_positions(starts, counts)
            if pos.size:
                _, et_all = self._edge_fields(pos)
                sel, sel_owner = select_by_keys(
                    pos, owner, et_all.astype(np.int64), fanout, base,
                    self.hg.num_etypes)
                src, et = self._edge_fields(sel)
                dst = frontier[sel_owner].astype(np.int32)
            else:
                src = dst = et = np.zeros(0, dtype=np.int32)
            node_ids = np.unique(np.concatenate([frontier, src]))
            bg = HeteroGraph.from_edges(
                np.searchsorted(node_ids, src).astype(np.int32),
                np.searchsorted(node_ids, dst).astype(np.int32),
                et,
                num_nodes=int(node_ids.shape[0]),
                num_etypes=self.hg.num_etypes,
                node_type=self.hg.node_type[node_ids],
                num_ntypes=self.hg.num_ntypes,
            )
            dst_local = np.searchsorted(node_ids, frontier).astype(np.int32)
            blocks.append(Block(graph=bg, node_ids=node_ids.astype(np.int32),
                                dst_local=dst_local))
            frontier = node_ids
        blocks.reverse()
        return BlockSequence(blocks=blocks, seeds=seeds, seed_perm=seed_perm)

    def stats(self) -> dict:
        return {"local_lookups": self.local_lookups,
                "halo_lookups": self.halo_lookups}
