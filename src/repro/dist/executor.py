"""Compiled multi-shard executors: ``shard_map`` over a data-only mesh.

One jitted callable per shape bucket runs *every* shard's block forward
(and, for training, backward + optimizer update) with all cross-shard
communication inside the compiled step:

* **halo features** — each device holds its shards' resident feature slabs
  ``[L, n_own, d]`` (``L = P / dp`` logical shards per device); the step
  opens with one ``all_gather`` over the data axis, giving every device the
  full ``[P, n_own, d]`` table from which each shard gathers its hop-0
  input rows (owned + halo) by host-precomputed ``(owner, row)`` indices.

* **gradient all-reduce** — each device computes its shards' *partial*
  losses ``sum(nll * mask) / B_total`` (linearity: the partials sum to the
  global mean loss exactly) and ``lax.map``s ``jax.grad`` over them,
  producing **stacked** per-shard gradients. Those are ``all_gather``-ed to
  ``[P, ...]`` in shard order and summed over the shard axis. This is the
  determinism-safe spelling of ``psum``: the gathered operands and the
  reduction tree depend only on ``P`` — not on how the shards distribute
  over devices — so dp=1 and dp=4 produce **bit-identical** gradients.

* **request-order outputs** — per-slot nll/logits are gathered to
  ``[P * b_max, ...]`` and un-permuted by the batcher's ``route`` index, so
  the reported loss is ``mean(nll[route])``: the same values, in the same
  order, reduced by the same HLO as the single-box step.

Everything is replicated except the stacked shard-axis arrays, so the
callable needs zero per-step host synchronization; the optimizer state is
donated on accelerator backends exactly like ``BlockTrainExecutor``.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as PS

from repro.compat import shard_map
from repro.core import codegen
from repro.core.executor import _CachedExecutor


def _mesh_key(mesh) -> tuple:
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


def _num_local(mesh, num_shards: int) -> int:
    """Logical shards per device (elastic folding): ``L = P / dp``."""
    dp = int(np.prod(mesh.devices.shape))
    if num_shards % dp:
        raise ValueError(
            f"{num_shards} shards cannot fold onto {dp} devices "
            f"(need num_shards % dp == 0)")
    return num_shards // dp


class _ShardedExecutor(_CachedExecutor):
    """Shared plumbing: plans + data mesh + the per-shard forward."""

    def __init__(self, plans: Sequence, mesh, backend: str = "xla",
                 activation: str = "relu", donate: bool = False,
                 donate_argnums: Sequence[int] = (), decisions=None,
                 tag: str = ""):
        super().__init__(donate, donate_argnums=donate_argnums,
                         decisions=decisions,
                         static_key=(tag, _mesh_key(mesh))
                         + tuple(p.fingerprint() for p in plans))
        self.plans = list(plans)
        self.mesh = mesh
        self.backend = backend
        self.activation = activation

    def _forward_one(self, params, full_feats, shard):
        """One shard's block forward from the gathered feature table."""
        gts, kls, dstl, perm, orow, lrow = shard
        x = full_feats[orow, lrow]
        return codegen.execute_block_sequence(
            self.plans, params, gts, kls, dstl, perm, {"feature": x},
            backend=self.backend, activation=self.activation,
            decisions=self.decisions)


class ShardedServeExecutor(_ShardedExecutor):
    """Compiled multi-shard inference: returns ``[B, C]`` seed logits in
    request order. Feature slabs are persistent (never donated)."""

    def __init__(self, plans: Sequence, mesh, backend: str = "xla",
                 activation: str = "relu", decisions=None):
        super().__init__(plans, mesh, backend, activation,
                         decisions=decisions, tag="serve")

    def _traced(self, params, own_feats, gts, kls, dstl, perm, orow, lrow,
                route):
        self._count_trace()

        def body(params, own_feats, gts, kls, dstl, perm, orow, lrow):
            full_feats = lax.all_gather(own_feats, "data", axis=0,
                                        tiled=True)
            logits_l = lax.map(
                lambda sh: self._forward_one(params, full_feats, sh),
                (gts, kls, dstl, perm, orow, lrow))
            return lax.all_gather(logits_l, "data", axis=0, tiled=True)

        d, r = PS("data"), PS()
        logits = shard_map(
            body, mesh=self.mesh,
            in_specs=(r, d, d, d, d, d, d, d), out_specs=r,
            check_vma=False,
        )(params, own_feats, gts, kls, dstl, perm, orow, lrow)
        num_parts, b_max = logits.shape[0], logits.shape[1]
        return logits.reshape(num_parts * b_max, -1)[route]

    def run_minibatch(self, params, smb, own_feats) -> jnp.ndarray:
        """Logits for ``smb.seeds`` (request order) from the per-owner
        feature slabs ``own_feats [P, n_own, d]``."""
        _num_local(self.mesh, smb.num_shards)
        return self._call(params, own_feats, list(smb.tensors),
                          list(smb.layouts), list(smb.dst_locals),
                          smb.seed_perm, smb.owner_rows, smb.local_rows,
                          smb.route)


class ShardedTrainExecutor(_ShardedExecutor):
    """Compiled multi-shard SGD step: per-shard partial backward, in-step
    gradient all-reduce (gather + ordered shard-axis sum), optimizer
    update, request-order loss/accuracy — one dispatch per step."""

    def __init__(self, plans: Sequence, opt, mesh, backend: str = "xla",
                 activation: str = "relu", donate_state: bool = True,
                 decisions=None):
        super().__init__(plans, mesh, backend, activation,
                         donate=donate_state, donate_argnums=(0,),
                         decisions=decisions, tag="train")
        self.opt = opt

    def _traced(self, state, own_feats, gts, kls, dstl, perm, orow, lrow,
                labels, mask, route, inv_b):
        self._count_trace()

        def body(params, own_feats, gts, kls, dstl, perm, orow, lrow,
                 labels, mask):
            full_feats = lax.all_gather(own_feats, "data", axis=0,
                                        tiled=True)

            def one(sh):
                gts, kls, dstl, perm, orow, lrow, labels, mask = sh

                def loss_fn(p):
                    logits = self._forward_one(
                        p, full_feats, (gts, kls, dstl, perm, orow, lrow))
                    logp = jax.nn.log_softmax(logits)
                    nll = -jnp.take_along_axis(
                        logp, labels[:, None], axis=1)[:, 0]
                    return jnp.sum(nll * mask) * inv_b, (nll, logits)

                (_, (nll, logits)), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params)
                return g, nll, logits

            g_l, nll_l, logits_l = lax.map(
                one, (gts, kls, dstl, perm, orow, lrow, labels, mask))
            # determinism-safe all-reduce: gather per-shard partials in
            # shard order, sum over the shard axis — the operands and the
            # reduction are identical for every device count
            g_all = lax.all_gather(g_l, "data", axis=0, tiled=True)
            grads = jax.tree_util.tree_map(
                lambda a: jnp.sum(a, axis=0), g_all)
            nll = lax.all_gather(nll_l, "data", axis=0, tiled=True)
            logits = lax.all_gather(logits_l, "data", axis=0, tiled=True)
            return grads, nll, logits

        d, r = PS("data"), PS()
        grads, nll, logits = shard_map(
            body, mesh=self.mesh,
            in_specs=(r, d, d, d, d, d, d, d, d, d),
            out_specs=(r, r, r), check_vma=False,
        )(state.params, own_feats, gts, kls, dstl, perm, orow, lrow,
          labels, mask)

        num_parts, b_max = nll.shape
        loss = jnp.mean(nll.reshape(num_parts * b_max)[route])
        logits_req = logits.reshape(num_parts * b_max, -1)[route]
        labels_req = labels.reshape(num_parts * b_max)[route]
        acc = jnp.mean((jnp.argmax(logits_req, axis=-1) == labels_req)
                       .astype(jnp.float32))
        new_state = self.opt.update(grads, state)
        return new_state, {"loss": loss, "accuracy": acc}

    def grad_and_update(self, state, smb, labels, own_feats):
        """One optimizer step over a ``ShardedMiniBatch``.

        ``labels`` is the *global* per-node label array (the batcher routed
        the seeds, so labels are sliced per shard here); ``own_feats`` is
        the persistent ``[P, n_own, d]`` feature slab stack. Returns
        ``(new_state, {"loss", "accuracy"})`` like the single-box step.
        """
        _num_local(self.mesh, smb.num_shards)
        inv_b = jnp.float32(1.0 / len(smb.seeds))
        return self._call(state, own_feats, list(smb.tensors),
                          list(smb.layouts), list(smb.dst_locals),
                          smb.seed_perm, smb.owner_rows, smb.local_rows,
                          smb.slice_labels(labels), smb.mask, smb.route,
                          inv_b)

    def lowered_hlo(self, state, smb, labels, own_feats) -> str:
        """Lowered (StableHLO) text of the whole train step for these
        arguments — lets the ``dist_smoke`` gate assert the halo-feature
        and gradient collectives live *inside* the one jitted module
        rather than as separate dispatches. Traces a throwaway instance of
        the step (bumping ``trace_count``); it never enters the compile
        cache."""
        _num_local(self.mesh, smb.num_shards)
        inv_b = jnp.float32(1.0 / len(smb.seeds))
        return jax.jit(self._traced).lower(
            state, own_feats, list(smb.tensors), list(smb.layouts),
            list(smb.dst_locals), smb.seed_perm, smb.owner_rows,
            smb.local_rows, smb.slice_labels(labels), smb.mask, smb.route,
            inv_b).as_text()
