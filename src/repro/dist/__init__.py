"""Data-parallel execution over a partitioned hetero graph.

The distributed layer slots between the single-box compile-and-serve stack
and the mesh machinery in ``launch/mesh.py``:

* ``partition``  — edge-cut-by-destination partitioner over the canonical
  etype-sorted COO: per-shard CSR slices, halo tables, shard subgraphs.
* ``sampler``    — ``ShardedSampler``: per-shard fanout sampling that draws
  the *same* counter-based key stream as the single-box ``FanoutSampler``
  (selection per (dst, etype) bin is keyed by full-graph dst-sorted edge
  positions, so it is independent of which shard evaluates it).
* ``data``       — ``ShardedBatcher``: routes each seed batch to its owner
  shards, samples per shard, pads every shard's blocks to common cross-shard
  buckets, and stacks the per-hop pytrees into ``[P, ...]`` arrays ready for
  ``shard_map``.
* ``executor``   — ``ShardedServeExecutor`` / ``ShardedTrainExecutor``: one
  jitted, donated-state callable per shape bucket that runs every shard's
  block forward (and backward + AdamW update) under ``shard_map`` over a
  data-only mesh, with the halo-feature all-gather and the gradient
  all-reduce *inside* the compiled step.
"""
from repro.dist.partition import (GraphPartition, partition_graph,
                                  check_partition)
from repro.dist.sampler import ShardedSampler
from repro.dist.data import ShardedBatcher, ShardedMiniBatch
from repro.dist.executor import ShardedServeExecutor, ShardedTrainExecutor
from repro.dist.trainer import DistTrainer

__all__ = [
    "GraphPartition", "partition_graph", "check_partition",
    "ShardedSampler", "ShardedBatcher", "ShardedMiniBatch",
    "ShardedServeExecutor", "ShardedTrainExecutor", "DistTrainer",
]
