"""Edge-cut-by-destination partitioning of a ``HeteroGraph``.

Every node gets exactly one owner shard; every edge lives with its
*destination's* owner. Because the canonical graph keeps a destination-sorted
edge view (``perm_dst``/``dst_ptr``) and ownership is assigned as contiguous
node ranges, each shard's edge set is one contiguous slice of the dst-sorted
order — so a shard can enumerate the in-edges of any node it owns as
*full-graph dst-sorted positions*. Those positions are the counter-based
sampling keys' domain (``sampling.sampler.edge_sample_keys``), which is what
makes sharded sampling draw bit-identical selections to the single-box
sampler: the keys never depend on who evaluates them.

Sources of cut edges (src owned elsewhere) appear in the shard's **halo
table**: the remote node ids plus their owner shard, i.e. exactly the rows
whose features must be fetched from other shards before the shard's blocks
can execute (``dist/executor.py`` implements that fetch as an all-gather of
the per-owner feature tables inside the compiled step).

Ownership is balanced by *edge count* (each shard owns a contiguous node
range covering ~E/P dst-sorted edges), the right balance target for both
sampling and aggregation work.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.graph import HeteroGraph


@dataclasses.dataclass(frozen=True)
class ShardTables:
    """One shard's slice of the partitioned graph (host arrays)."""

    part: int
    lo: int                  # owned node range [lo, hi)
    hi: int
    dst_ptr: np.ndarray      # [hi-lo+1] GLOBAL dst_ptr values at owned nodes
    src_d: np.ndarray        # [E_s] src of the shard's dst-sorted edge slice
    etype_d: np.ndarray      # [E_s] etype of that slice
    halo_nodes: np.ndarray   # [H_s] remote src node ids (sorted, unique)
    halo_owner: np.ndarray   # [H_s] owner shard of each halo node

    @property
    def num_owned(self) -> int:
        return self.hi - self.lo

    @property
    def num_edges(self) -> int:
        return int(self.src_d.shape[0])

    @property
    def edge_base(self) -> int:
        """Global dst-sorted position of this shard's first edge."""
        return int(self.dst_ptr[0])


class GraphPartition:
    """P-way edge-cut partition of one ``HeteroGraph``."""

    def __init__(self, hg: HeteroGraph, bounds: np.ndarray):
        self.hg = hg
        self.num_parts = len(bounds) - 1
        self.bounds = bounds                      # [P+1] node range bounds
        src_d = hg.src[hg.perm_dst]
        etype_d = hg.etype[hg.perm_dst]
        self.shards: List[ShardTables] = []
        for p in range(self.num_parts):
            lo, hi = int(bounds[p]), int(bounds[p + 1])
            e_lo, e_hi = int(hg.dst_ptr[lo]), int(hg.dst_ptr[hi])
            s_src = src_d[e_lo:e_hi]
            owners = self.owner_of(s_src)
            halo = np.unique(s_src[owners != p]).astype(np.int32)
            self.shards.append(ShardTables(
                part=p, lo=lo, hi=hi,
                dst_ptr=hg.dst_ptr[lo:hi + 1].copy(),
                src_d=s_src.copy(), etype_d=etype_d[e_lo:e_hi].copy(),
                halo_nodes=halo, halo_owner=self.owner_of(halo)))

    # ------------------------------------------------------------------
    def owner_of(self, nodes: np.ndarray) -> np.ndarray:
        """Owner shard of each (global) node id."""
        return (np.searchsorted(self.bounds, np.asarray(nodes), side="right")
                - 1).astype(np.int32)

    def owned_count(self, p: int) -> int:
        return int(self.bounds[p + 1] - self.bounds[p])

    @property
    def max_owned(self) -> int:
        return int(np.max(np.diff(self.bounds)))

    def local_row(self, nodes: np.ndarray) -> np.ndarray:
        """Row of each node inside its owner's feature table."""
        nodes = np.asarray(nodes)
        return (nodes - self.bounds[self.owner_of(nodes)]).astype(np.int32)

    # ------------------------------------------------------------------
    def shard_subgraph(self, p: int) -> tuple:
        """Standalone per-shard ``HeteroGraph`` over (owned + halo) nodes.

        Returns ``(graph, node_ids)`` where ``node_ids`` maps local node
        index -> global id (owned range first, halo nodes after). The
        subgraph holds exactly the shard's edges, so calling
        ``.to_device_graph()`` on it gives the shard's device-resident CSC.
        """
        sh = self.shards[p]
        node_ids = np.concatenate([
            np.arange(sh.lo, sh.hi, dtype=np.int32), sh.halo_nodes])
        order = np.argsort(node_ids, kind="stable")
        sorted_ids = node_ids[order]
        dst_g = np.repeat(np.arange(sh.lo, sh.hi, dtype=np.int32),
                          np.diff(sh.dst_ptr))
        g = HeteroGraph.from_edges(
            np.searchsorted(sorted_ids, sh.src_d).astype(np.int32),
            np.searchsorted(sorted_ids, dst_g).astype(np.int32),
            sh.etype_d.astype(np.int32),
            num_nodes=int(sorted_ids.shape[0]),
            num_etypes=self.hg.num_etypes,
            node_type=self.hg.node_type[sorted_ids],
            num_ntypes=self.hg.num_ntypes,
        )
        return g, sorted_ids

    def shard_features(self, feats: np.ndarray) -> np.ndarray:
        """Stack features into the per-owner tables: ``[P, n_own_max, d]``
        (row r of slab p is global node ``bounds[p] + r``; pad rows zero).
        Sharded over the data axis, this is the resident feature layout the
        compiled step all-gathers for halo access."""
        feats = np.asarray(feats)
        n_max = self.max_owned
        out = np.zeros((self.num_parts, n_max) + feats.shape[1:],
                       dtype=feats.dtype)
        for p in range(self.num_parts):
            lo, hi = int(self.bounds[p]), int(self.bounds[p + 1])
            out[p, : hi - lo] = feats[lo:hi]
        return out

    def describe(self) -> str:
        lines = [f"GraphPartition({self.num_parts} shards, "
                 f"{self.hg.num_nodes} nodes, {self.hg.num_edges} edges)"]
        for sh in self.shards:
            lines.append(
                f"  shard {sh.part}: nodes [{sh.lo}, {sh.hi}) "
                f"({sh.num_owned}), {sh.num_edges} edges, "
                f"{len(sh.halo_nodes)} halo nodes")
        return "\n".join(lines)


def partition_graph(hg: HeteroGraph, num_parts: int,
                    bounds: Optional[np.ndarray] = None) -> GraphPartition:
    """Partition ``hg`` into ``num_parts`` shards, balanced by edge count.

    ``bounds`` overrides the automatic split with explicit node-range
    boundaries (``[P+1]``, monotone, ``bounds[0]=0``, ``bounds[-1]=N``).
    """
    if num_parts <= 0:
        raise ValueError("num_parts must be positive")
    if num_parts > hg.num_nodes:
        raise ValueError(
            f"cannot cut {hg.num_nodes} nodes into {num_parts} shards")
    if bounds is None:
        # split node ids where the dst-sorted edge array splits into P
        # equal-ish slices; fall back to node balance for edgeless prefixes
        targets = (np.arange(1, num_parts) * hg.num_edges) // num_parts
        cuts = np.searchsorted(hg.dst_ptr, targets, side="left")
        bounds = np.concatenate([[0], cuts, [hg.num_nodes]]).astype(np.int64)
        # enforce strictly increasing bounds (degenerate distributions can
        # collapse neighboring cuts; every shard must own >= 1 node)
        for p in range(1, num_parts + 1):
            lo = int(bounds[p - 1]) + 1
            hi = hg.num_nodes - (num_parts - p)
            bounds[p] = min(max(int(bounds[p]), lo), hi)
        bounds[num_parts] = hg.num_nodes
    else:
        bounds = np.asarray(bounds, dtype=np.int64)
        if (len(bounds) != num_parts + 1 or bounds[0] != 0
                or bounds[-1] != hg.num_nodes
                or np.any(np.diff(bounds) <= 0)):
            raise ValueError("bounds must be [P+1] strictly increasing "
                             "from 0 to num_nodes")
    return GraphPartition(hg, bounds)


def check_partition(part: GraphPartition) -> dict:
    """Partitioner invariants (raises ``AssertionError`` on violation).

    * every node has exactly one owner; owned ranges tile [0, N);
    * every edge is assigned to exactly one shard (the slices tile the
      dst-sorted edge order) and lives with its destination's owner;
    * halo tables are complete: every remote source of a shard's edges is
      in its halo table, with the correct owner, and no owned node is.
    """
    hg = part.hg
    counts = {"nodes": 0, "edges": 0, "halo": 0}
    assert part.bounds[0] == 0 and part.bounds[-1] == hg.num_nodes
    src_d = hg.src[hg.perm_dst]
    for sh in part.shards:
        counts["nodes"] += sh.num_owned
        counts["edges"] += sh.num_edges
        counts["halo"] += len(sh.halo_nodes)
        # the shard's edge slice is exactly its owned nodes' dst-CSR run
        assert sh.dst_ptr[0] == hg.dst_ptr[sh.lo]
        assert sh.dst_ptr[-1] == hg.dst_ptr[sh.hi]
        np.testing.assert_array_equal(
            sh.src_d, src_d[hg.dst_ptr[sh.lo]:hg.dst_ptr[sh.hi]])
        # halo completeness: remote sources == halo table, owners correct
        owners = part.owner_of(sh.src_d)
        remote = np.unique(sh.src_d[owners != sh.part])
        np.testing.assert_array_equal(sh.halo_nodes, remote)
        np.testing.assert_array_equal(sh.halo_owner,
                                      part.owner_of(sh.halo_nodes))
        assert not np.any((sh.halo_nodes >= sh.lo) & (sh.halo_nodes < sh.hi))
    assert counts["nodes"] == hg.num_nodes
    assert counts["edges"] == hg.num_edges
    return counts
