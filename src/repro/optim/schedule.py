"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                    floor_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(1, warmup_steps)
        frac = jnp.clip((step - warmup_steps) /
                        max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = floor_frac * peak + (1 - floor_frac) * peak * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr
