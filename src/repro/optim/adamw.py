"""AdamW with decoupled weight decay, global-norm clipping, f32 moments.

No optax dependency: the update is a pure pytree transform so that the
ZeRO-1 sharding rules (launch/partitioning.py) apply to the moment tensors
directly and the whole optimizer steps inside one pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    mu: Any
    nu: Any
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> TrainState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return TrainState(
            params=params,
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
            step=jnp.zeros((), jnp.int32),
        )

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.float32(self.learning_rate)

    def update(self, grads, state: TrainState) -> TrainState:
        step = state.step + 1
        if self.clip_norm is not None:
            gsq = jax.tree.reduce(
                lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
                grads, jnp.float32(0.0))
            gnorm = jnp.sqrt(gsq)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(state.params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return TrainState(params=new_p, mu=new_m, nu=new_v, step=step)
