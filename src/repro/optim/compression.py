"""Gradient compression for cross-pod all-reduce: int8 quantization with
error feedback.

At multi-pod scale the "pod" axis rides data-center interconnect (slower
than intra-pod ICI), so the cross-pod gradient reduction is the long pole.
``compressed_psum`` quantizes per-block to int8 before the cross-pod
reduction (4x wire reduction vs f32, 2x vs bf16) inside shard_map;
``ErrorFeedback`` accumulates the quantization residual into the next step
(EF-SGD / 1-bit-Adam style), which restores convergence to near-exact.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, block: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape,
                    dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    block: int = 256) -> jnp.ndarray:
    """psum with int8 wire format (use inside shard_map over the pod axis).

    Quantize -> psum(int32 accumulate) -> dequantize with psum'd scales.
    Using a shared per-block scale (max over members via psum of scales)
    keeps the reduction linear."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    # members agree on a pmax-shared per-block scale (tiny f32 exchange);
    # the int8 sum is then exactly linear — no cross-member scale bias
    scale = jnp.maximum(jax.lax.pmax(local_scale, axis_name), 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    # int8 payload rides the wire; accumulate in int32
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = (acc.astype(jnp.float32) * scale).reshape(-1)
    return out[: x.size].reshape(x.shape).astype(x.dtype)


class ErrorFeedback:
    """e_{t+1} = g_t + e_t - C(g_t + e_t); apply C's output, carry residual."""

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def compress(grads: Any, residual: Any, block: int = 256):
        def one(g, e):
            target = g.astype(jnp.float32) + e
            q, s = quantize_int8(target, block)
            deq = dequantize_int8(q, s, g.shape, jnp.float32)
            return deq.astype(g.dtype), target - deq
        pairs = jax.tree.map(one, grads, residual)
        comp = jax.tree.map(lambda p: p[0], pairs,
                            is_leaf=lambda p: isinstance(p, tuple))
        new_res = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda p: isinstance(p, tuple))
        return comp, new_res
