"""Device introspection for the autotuner and the codegen fusion gate.

The gather-fused kernels keep their whole ungathered source block (plus the
scalar-prefetched gather/slot maps) resident in VMEM, so the budget that
gates fusion must come from the device actually executing the kernel — not
from a constant. There is no public VMEM query in JAX, so the sizes come
from a per-device-kind table (TPU cores carry ~16 MiB of VMEM across
generations; see the Pallas guide's memory hierarchy) with an environment
override for odd parts.

This module deliberately imports nothing from ``repro`` so that
``core/codegen.py`` can use it without an import cycle (the tuner imports
codegen, codegen imports only this leaf).
"""
from __future__ import annotations

import functools
import os

import jax

# Physical VMEM per core by TPU generation. Entries are matched as lowercase
# substrings of ``Device.device_kind``; unknown accelerators fall back to the
# conservative 16 MiB that every shipped TPU core provides.
_VMEM_BYTES_BY_KIND = {
    "v2": 16 * 1024 * 1024,
    "v3": 16 * 1024 * 1024,
    "v4": 16 * 1024 * 1024,
    "v5 lite": 16 * 1024 * 1024,
    "v5e": 16 * 1024 * 1024,
    "v5p": 16 * 1024 * 1024,
    "v6": 32 * 1024 * 1024,
}
_DEFAULT_VMEM_BYTES = 16 * 1024 * 1024

# Fraction of VMEM the fused-gather kernels may claim for their resident
# source block + index maps. The rest stays free for the kernel's own
# input/output blocks, double buffering, and the weight block.
_FUSED_GATHER_VMEM_FRACTION = 0.25

VMEM_ENV = "REPRO_VMEM_BYTES"
BUDGET_ENV = "REPRO_FUSED_GATHER_BUDGET_BYTES"


@functools.lru_cache(maxsize=None)
def device_kind() -> str:
    """Stable, key-safe identifier of the default device, e.g.
    ``cpu`` or ``tpu:TPU v4``. Part of every tuning-cache key so decisions
    measured on one part are never replayed on another."""
    backend = jax.default_backend()
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # no devices initialized yet / headless
        kind = backend
    kind = str(kind).strip().replace("|", "/")
    return backend if kind == backend else f"{backend}:{kind}"


def vmem_bytes() -> int:
    """Physical VMEM of the default device (env-overridable).

    CPU (and interpret-mode testing) has no VMEM; it reports the default
    TPU size so interpret-mode runs exercise the same fusion decisions the
    compiled kernels would take on hardware.
    """
    env = os.environ.get(VMEM_ENV)
    if env:
        return int(env)
    kind = device_kind().lower()
    for sub, size in _VMEM_BYTES_BY_KIND.items():
        if sub in kind:
            return size
    return _DEFAULT_VMEM_BYTES


def fused_gather_budget_bytes() -> int:
    """Bytes the fused-gather kernels may keep resident in VMEM (source
    block + gather/slot index maps), derived from the device's actual VMEM.
    ``REPRO_FUSED_GATHER_BUDGET_BYTES`` overrides the derived value."""
    env = os.environ.get(BUDGET_ENV)
    if env:
        return int(env)
    return int(vmem_bytes() * _FUSED_GATHER_VMEM_FRACTION)
