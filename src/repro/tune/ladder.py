"""Measured validation of serving bucket ladders (ISSUE 10).

The serving coalescer admits request batches into shape-bucket "rungs"
(padded seed counts). Finer-than-pow2 rungs cut pad waste only if the
executor actually runs faster at the finer size — on some backends a
48-seed batch costs the same as 64 (identical downstream pow2 block
buckets), and then the extra rung just buys more compilations. That is a
measured question, so it is answered on the tuner's own harness:
``measure_group`` times every rung's compiled execute interleaved
(round-robin, min-of-iters), and a non-pow2 rung survives only if it
beats the next pow2 rung by ``min_gain``.

The measurements double as the latency calibration the coalescer's
admission control runs on, so validation costs nothing extra.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

from repro.kernels.layout import pow2ceil
from repro.tune.tuner import measure_group


@dataclasses.dataclass
class LadderReport:
    """Outcome of ``validate_ladder``: the surviving rungs, per-rung
    measured milliseconds, and which non-pow2 rungs were dropped."""

    rungs: List[int]
    measured_ms: Dict[int, float]
    dropped: List[int]

    def describe(self) -> str:
        rows = [f"  rung {r:>4}: {self.measured_ms[r]:8.3f} ms"
                + ("  [dropped]" if r in self.dropped else "")
                for r in sorted(self.measured_ms)]
        return "ladder validation:\n" + "\n".join(rows)


def validate_ladder(
    rungs: Sequence[int],
    prepare: Callable[[int], Tuple[Callable, tuple]],
    *,
    warmup: int = 1,
    iters: int = 3,
    min_gain: float = 0.03,
) -> LadderReport:
    """Measure every rung and drop non-pow2 rungs that don't pay.

    ``prepare(rung) -> (fn, args)`` must return a ready-to-run execute of
    one batch at that rung size (the serving runtime passes its compiled
    block forward over a representative sampled batch). All rungs are
    timed with one interleaved ``measure_group`` call so machine drift
    lands on every rung alike. A non-pow2 rung is kept only when its
    measured time undercuts the next pow2 rung by at least ``min_gain``
    (fractional); pow2 rungs are always kept — they are the shape set the
    executor compiles for anyway.
    """
    rungs = sorted(set(int(r) for r in rungs))
    if not rungs:
        raise ValueError("empty ladder")
    calls = [prepare(r) for r in rungs]
    times = measure_group(calls, warmup=warmup, iters=iters)
    measured = {r: t * 1e3 for r, t in zip(rungs, times)}

    kept, dropped = [], []
    for r in rungs:
        if r & (r - 1) == 0:        # pow2: always kept
            kept.append(r)
            continue
        cover = pow2ceil(r)
        cover_ms = measured.get(cover)
        if cover_ms is None or measured[r] <= cover_ms * (1.0 - min_gain):
            kept.append(r)
        else:
            dropped.append(r)
    return LadderReport(rungs=kept, measured_ms=measured, dropped=dropped)
