"""The TuningDecisions table codegen dispatches on.

Three decision families, mirroring the three layers where the compiler
makes a choice:

* ``ops``      — per lowered op instance (keyed by ``tune/space.py`` keys):
                 backend, tile shape, gather fusion. Consulted at trace time
                 by ``codegen._exec_gemm``/``_exec_traversal``.
* ``materialization`` — per edge variable of a lowered program: COMPACT vs
                 VANILLA. Consulted at *lowering* time (it changes the
                 plan's gather schemes), keyed per (program, graph).
* ``layout``   — per graph: the kernel-layout tile / node-block shape.

The table is a plain Python object closed over by the compiled executors;
``fingerprint()`` joins the executors' compile-cache signature so a changed
table never reuses a stale executable.
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, Optional

from repro.tune.space import variant_from_json


class TuningDecisions:
    def __init__(self,
                 ops: Optional[Dict[str, object]] = None,
                 materialization: Optional[Dict[str, Dict[str, str]]] = None,
                 layout: Optional[Dict[str, Dict[str, int]]] = None):
        self.ops = dict(ops or {})
        self.materialization = dict(materialization or {})
        self.layout = dict(layout or {})
        self._fingerprint: Optional[str] = None

    # -- op decisions ---------------------------------------------------
    def lookup(self, key: str):
        """Variant for one lowered op instance, or None (use defaults)."""
        return self.ops.get(key)

    def set_op(self, key: str, variant) -> None:
        self.ops[key] = variant
        self._fingerprint = None

    # -- materialization / layout ---------------------------------------
    def set_materialization(self, key: str, per_var: Dict[str, str]) -> None:
        self.materialization[key] = dict(per_var)
        self._fingerprint = None

    def compact_vars(self, key: str) -> Optional[frozenset]:
        """The COMPACT-var set recorded for one (program, graph), or None
        when that program was never tuned (lowering keeps its default)."""
        per_var = self.materialization.get(key)
        if per_var is None:
            return None
        return frozenset(v for v, m in per_var.items() if m == "compact")

    def set_layout(self, key: str, tile: int, node_block: int) -> None:
        self.layout[key] = {"tile": int(tile), "node_block": int(node_block)}
        self._fingerprint = None

    def layout_for(self, key: str) -> Optional[Dict[str, int]]:
        return self.layout.get(key)

    # -- identity --------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "ops": {k: v.to_json() for k, v in sorted(self.ops.items())},
            "materialization": self.materialization,
            "layout": self.layout,
        }

    @classmethod
    def from_json(cls, d: dict) -> "TuningDecisions":
        return cls(
            ops={k: variant_from_json(v) for k, v in d.get("ops", {}).items()},
            materialization=d.get("materialization", {}),
            layout=d.get("layout", {}),
        )

    def fingerprint(self) -> str:
        """Stable digest of the whole table — part of the executors'
        compile-cache key, so tuned plans cache correctly and re-tuning
        invalidates previously compiled entries."""
        if self._fingerprint is None:
            blob = json.dumps(self.to_json(), sort_keys=True)
            self._fingerprint = hashlib.sha1(blob.encode()).hexdigest()[:16]
        return self._fingerprint

    def __len__(self) -> int:
        return len(self.ops) + len(self.materialization) + len(self.layout)

    def __repr__(self) -> str:
        return (f"TuningDecisions(ops={len(self.ops)}, "
                f"materialization={len(self.materialization)}, "
                f"layout={len(self.layout)}, fp={self.fingerprint()})")
