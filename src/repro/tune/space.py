"""The per-operator variant space and the keys decisions are stored under.

A *variant* is one point in the operator-specific optimization space the
paper's code generator chooses from (§3.4): execution backend, GEMM tile
shape, and whether the access-scheme gather runs inside the kernel. A *key*
identifies one lowered op instance up to everything that determines which
variant wins: the spec's identity fields, the layout signature (tile sizes,
group counts, power-of-two row buckets — sampled blocks are shape-bucketed,
so buckets make block-scale decisions reusable across batches), the dtype,
and the device kind. Keys are plain strings so the persistent cache stores
them verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.kernels.layout import pow2ceil
from repro.tune import device as D

# sentinel backend meaning "inherit the plan-wide backend"
DEFAULT = "default"


@dataclasses.dataclass(frozen=True)
class GemmVariant:
    """One point in a GEMM-template instance's variant space.

    ``None`` knobs keep the lowering default (layout tile rows, 128-column
    tiles, the VMEM-budget fusion heuristic)."""

    backend: str = DEFAULT
    tile_rows: Optional[int] = None
    tile_n: Optional[int] = None
    fuse_gather: Optional[bool] = None

    def to_json(self) -> dict:
        return {"kind": "gemm", "backend": self.backend,
                "tile_rows": self.tile_rows, "tile_n": self.tile_n,
                "fuse_gather": self.fuse_gather}


@dataclasses.dataclass(frozen=True)
class TravVariant:
    """One point in a fused traversal instance's variant space."""

    backend: str = DEFAULT
    fuse_gather: Optional[bool] = None

    def to_json(self) -> dict:
        return {"kind": "trav", "backend": self.backend,
                "fuse_gather": self.fuse_gather}


GEMM_DEFAULT = GemmVariant()
TRAV_DEFAULT = TravVariant()


def variant_from_json(d: dict):
    if d["kind"] == "gemm":
        return GemmVariant(backend=d.get("backend", DEFAULT),
                           tile_rows=d.get("tile_rows"),
                           tile_n=d.get("tile_n"),
                           fuse_gather=d.get("fuse_gather"))
    if d["kind"] == "trav":
        return TravVariant(backend=d.get("backend", DEFAULT),
                           fuse_gather=d.get("fuse_gather"))
    raise ValueError(f"unknown variant kind {d!r}")


# ---------------------------------------------------------------------------
# op-instance keys
# ---------------------------------------------------------------------------
def gemm_key(op, lay, x_rows: int, k: int, n: int, has_scale: bool,
             dtype) -> str:
    """Key of one lowered GemmSpec instance: spec identity x layout
    signature x dtype x device kind."""
    return "|".join([
        "gemm", op.gather.value, op.type_index.value, op.seg_ptr,
        f"k{k}", f"n{n}", f"s{int(has_scale)}",
        f"t{lay.tile}", f"g{lay.num_groups}",
        f"rp{pow2ceil(int(lay.row_map.shape[0]))}",
        f"x{pow2ceil(int(x_rows))}",
        str(dtype), D.device_kind(),
    ])


def trav_key(agg_kind: str, d: int, compact_msg: bool, bc, dtype) -> str:
    """Key of one fused traversal-aggregation instance (softmax+agg or
    weighted agg) over a blocked-CSR layout."""
    return "|".join([
        "trav", agg_kind, f"d{d}", f"c{int(compact_msg)}",
        f"et{bc.edge_tile}", f"nb{bc.node_block}",
        f"ep{pow2ceil(int(bc.edge_map.shape[0]))}",
        str(dtype), D.device_kind(),
    ])


# ---------------------------------------------------------------------------
# key parsing + candidate enumeration
# ---------------------------------------------------------------------------
_MIN_TILE_ROWS = 8  # f32 sublane minimum — smaller row tiles can't be laid out

_FUSABLE = ("edge_src", "edge_dst", "unique_src")


def parse_key(key: str) -> dict:
    """Decode a decision key back into the fields that shape its variant
    space. The tuner *records* the exact keys codegen queries (so key
    construction has a single source of truth) and enumerates from them."""
    parts = key.split("|")

    def num(part: str, prefix: str) -> int:
        assert part.startswith(prefix), (key, part, prefix)
        return int(part[len(prefix):])

    if parts[0] == "gemm":
        gather, tindex, seg = parts[1:4]
        return {
            "kind": "gemm", "gather": gather, "tindex": tindex, "seg": seg,
            "k": num(parts[4], "k"), "n": num(parts[5], "n"),
            "has_scale": bool(num(parts[6], "s")),
            "lay_tile": num(parts[7], "t"), "groups": num(parts[8], "g"),
            "padded_rows": num(parts[9], "rp"), "x_rows": num(parts[10], "x"),
            "dtype": parts[11], "device": parts[12],
            "fusable": gather in _FUSABLE and tindex != "none",
        }
    if parts[0] == "trav":
        return {
            "kind": "trav", "agg": parts[1], "d": num(parts[2], "d"),
            "compact_msg": bool(num(parts[3], "c")),
            "edge_tile": num(parts[4], "et"),
            "node_block": num(parts[5], "nb"),
            "padded_edges": num(parts[6], "ep"), "dtype": parts[7],
            "device": parts[8],
        }
    raise ValueError(f"unparseable decision key {key!r}")


def _fit_tile_n(n: int, tile_n: int) -> int:
    """Mirror of ``kernels.ops._fit_tile_n``: the column tile a request
    actually resolves to (used to drop behaviorally identical candidates)."""
    tn = min(tile_n, n)
    return n if n % tn else tn


def _col_tile_candidates(n: int) -> List[Optional[int]]:
    """Column-tile candidates with distinct *effective* tiles: for n <= 128
    every request clips to the same tile, so only the default survives."""
    cands: List[Optional[int]] = [None]          # the 128 default
    alt = min(256, max(_MIN_TILE_ROWS, n))
    if _fit_tile_n(n, alt) != _fit_tile_n(n, 128):
        cands.append(alt)
    return cands


def _row_tile_candidates(lay_tile: int) -> List[Optional[int]]:
    """Sub-tiles of the layout tile: each kernel row tile must stay within
    one type segment, which any divisor of the layout tile guarantees."""
    cands: List[Optional[int]] = [None]  # the layout tile itself
    t = lay_tile // 2
    while t >= _MIN_TILE_ROWS:
        cands.append(t)
        t //= 2
    return cands[:3]


def _alt_backends(plan_backend: str) -> List[str]:
    """Backends worth proposing besides the plan-wide one. On CPU, 'pallas'
    does not exist and 'pallas_interpret' is a pure correctness mode, so
    the only real alternative is falling back to 'xla' from a Pallas plan."""
    backends = [DEFAULT]
    if D.device_kind().startswith("tpu") and plan_backend != "pallas":
        backends.append("pallas")
    if plan_backend != "xla":
        backends.append("xla")
    return backends


def candidates_for_key(key: str, plan_backend: str) -> List:
    """Enumerate the (unpruned) variant space of one recorded op instance.
    The default variant is always first."""
    info = parse_key(key)
    out: List = []
    if info["kind"] == "trav":
        for b in _alt_backends(plan_backend):
            eff = plan_backend if b == DEFAULT else b
            out.append(TravVariant(backend=b))
            if eff != "xla":
                # the materialized-gather kernel (fusion off) is a variant
                out.append(TravVariant(backend=b, fuse_gather=False))
        return _dedup(out)
    for b in _alt_backends(plan_backend):
        eff = plan_backend if b == DEFAULT else b
        if eff == "xla":
            # the XLA formulation batches the einsum by row tile; column
            # tiling and gather fusion are kernel-only knobs
            for tr in _row_tile_candidates(info["lay_tile"]):
                out.append(GemmVariant(backend=b, tile_rows=tr))
            continue
        for tr in _row_tile_candidates(info["lay_tile"]):
            for tn in _col_tile_candidates(info["n"]):
                fuses = [None, False] if info["fusable"] else [None]
                for fg in fuses:
                    out.append(GemmVariant(backend=b, tile_rows=tr,
                                           tile_n=tn, fuse_gather=fg))
    return _dedup(out)


def _dedup(variants: Sequence) -> List:
    seen, out = set(), []
    for v in variants:
        if v not in seen:
            seen.add(v)
            out.append(v)
    return out
