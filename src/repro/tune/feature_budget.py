"""Measured per-ntype split of the device feature-cache budget.

``CachedFeatureStore`` partitions its slot slab per ntype. The default
split (proportional to ntype populations) is wrong whenever traffic is
skewed — a small ntype can dominate the sampled input rows (hetero graphs
routinely have hub types), and population-proportional slots then thrash.

``measured_split`` makes the split a *measured* decision, the same
philosophy as the operator autotuner: probe a few seed batches from the
actual stream through the host fanout sampler (pure host work, no device
involvement, no sampler state perturbed — selection keys are pure
functions of (seed, batch_index)), count each ntype's share of the
blocks' input rows, and split the budget proportional to observed traffic
via ``feats.split_budget`` (which caps at table sizes and redistributes
the remainder).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.graph import HeteroGraph
from repro.feats.store import split_budget


def measured_split(graph: HeteroGraph, sampler, seed_source, budget: int,
                   probe_batches: int = 4,
                   start_step: int = 0) -> Tuple[np.ndarray, dict]:
    """Probe ``probe_batches`` seed batches and split ``budget`` cache
    rows across ntypes by observed input-row traffic.

    ``sampler`` is a host ``FanoutSampler``; ``seed_source`` is anything
    with ``batch(step)`` (or a ``step -> ids`` callable). Returns
    ``(per_ntype_slots [T], report)`` where the report carries the raw
    row counts so drivers can log the decision.
    """
    seeds_for = (seed_source.batch if hasattr(seed_source, "batch")
                 else seed_source)
    ptr = graph.ntype_ptr.astype(np.int64)
    counts = np.zeros(graph.num_ntypes, dtype=np.int64)
    for k in range(max(1, probe_batches)):
        seeds = np.asarray(seeds_for(start_step + k))
        seq = sampler.sample(seeds, batch_index=start_step + k)
        ids = np.asarray(seq.input_node_ids, dtype=np.int64)
        t = np.searchsorted(ptr, ids, side="right") - 1
        counts += np.bincount(t, minlength=graph.num_ntypes)
    weights: Optional[np.ndarray] = counts if counts.sum() else None
    slots = split_budget(graph, budget, weights=weights)
    report = {
        "probe_batches": int(max(1, probe_batches)),
        "row_counts": counts.tolist(),
        "populations": np.diff(graph.ntype_ptr).tolist(),
        "slots": slots.tolist(),
        "budget": int(budget),
    }
    return slots, report
