"""Cheap cost-model prior used to prune variants before measurement.

The model only needs to *rank* candidates well enough that the top-K always
contains the winner; on-device timing makes the final call. It scores bytes
moved through the memory hierarchy plus a per-grid-step overhead term —
the two effects the tuning knobs actually trade against each other:

* gather fusion removes the materialized ``[rows, k]`` HBM copy but pins the
  whole source block (+ index maps) in VMEM — infeasible past the budget;
* smaller row tiles pay more grid-step overhead (but can win on skewed
  type segments where big tiles are mostly padding);
* the interpret backend exists for correctness only and is effectively
  infinitely expensive.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.tune import device as D
from repro.tune import space as S

_GRID_STEP_COST_BYTES = 2048   # fixed overhead per grid step, in byte units
_INFEASIBLE = 1e9

_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}


def _eff_backend(variant, plan_backend: str) -> str:
    return plan_backend if variant.backend == S.DEFAULT else variant.backend


def score(key: str, variant, plan_backend: str) -> float:
    """Predicted relative cost of running the keyed op with ``variant``."""
    info = S.parse_key(key)
    eff = _eff_backend(variant, plan_backend)
    if eff == "pallas_interpret" and plan_backend != "pallas_interpret":
        return _INFEASIBLE
    itemsize = _ITEMSIZE.get(info["dtype"], 4)
    budget = D.fused_gather_budget_bytes()

    if info["kind"] == "trav":
        ep, d = info["padded_edges"], info["d"]
        io = ep * d * itemsize                       # message traffic
        if eff != "xla":
            msg_rows = (info["padded_edges"] if not info["compact_msg"]
                        else max(1, info["padded_edges"] // 2))
            resident = msg_rows * d * itemsize + ep * 4
            fuse = variant.fuse_gather
            if fuse is None:
                fuse = resident <= budget
            if fuse:
                if resident > budget:
                    return _INFEASIBLE
                io = msg_rows * d * itemsize
            else:
                io += ep * d * itemsize              # dst-sorted copy
        return io

    k, n = info["k"], info["n"]
    rp, x_rows = info["padded_rows"], info["x_rows"]
    tr = variant.tile_rows or info["lay_tile"]
    tn = min(variant.tile_n or 128, n)
    io = rp * (k + n) * itemsize                     # X in + Y out
    if eff != "xla" and info["fusable"]:
        resident = x_rows * k * itemsize + rp * 4    # source + gather map
        fuse = variant.fuse_gather
        if fuse is None:
            fuse = resident <= budget
        if fuse:
            if resident > budget:
                return _INFEASIBLE
            io = x_rows * k * itemsize + rp * n * itemsize
        else:
            io += rp * k * itemsize                  # materialized copy
    grid_steps = max(1, rp // max(1, tr)) * max(1, n // max(1, tn))
    return io + grid_steps * _GRID_STEP_COST_BYTES


def prune(key: str, candidates: Sequence, plan_backend: str,
          k: int) -> List:
    """Keep the default variant (always, first) plus the cheapest
    alternatives in ascending predicted cost, dropping infeasible ones."""
    default = candidates[0]
    scored = sorted(
        ((score(key, c, plan_backend), i) for i, c in enumerate(candidates)
         if c != default),
        key=lambda t: t[0],
    )
    keep = [candidates[i] for s, i in scored if s < _INFEASIBLE]
    return [default] + keep[: max(0, k - 1)]
