"""Measurement-driven per-operator autotuning (ISSUE 4 tentpole).

Hector's compiler decouples model semantics from per-operator optimization;
this package supplies the *mechanism* that picks each lowered operator's
variant — backend, tile shape, in-kernel gather fusion, per-edge-var
materialization, and the kernel-layout tile — by cost-model pruning plus
on-device timing, with a persistent cache so tuned decisions replay across
processes with zero measurements.

``codegen`` imports the leaf modules here (``device``, ``space``,
``decisions``), so this ``__init__`` must stay import-light: the ``Tuner``
(which itself imports codegen) loads lazily.
"""
from repro.tune.cache import TuneCache, default_cache_path  # noqa: F401
from repro.tune.decisions import TuningDecisions            # noqa: F401
from repro.tune.device import (device_kind,                 # noqa: F401
                               fused_gather_budget_bytes, vmem_bytes)
from repro.tune.space import (GemmVariant, TravVariant,     # noqa: F401
                              gemm_key, trav_key)

__all__ = [
    "TuneCache", "default_cache_path", "TuningDecisions", "device_kind",
    "fused_gather_budget_bytes", "vmem_bytes", "GemmVariant", "TravVariant",
    "gemm_key", "trav_key", "Tuner", "TuneReport", "measured_split",
    "measure_group", "validate_ladder", "LadderReport",
]


def __getattr__(name):
    # lazy: tuner -> codegen -> tune.device would otherwise be a cycle
    if name in ("Tuner", "TuneReport"):
        from repro.tune import tuner as _tuner
        return getattr(_tuner, name)
    if name == "measured_split":
        # lazy: pulls in repro.feats (jax) — keep this __init__ import-light
        from repro.tune.feature_budget import measured_split
        return measured_split
    if name == "measure_group":
        from repro.tune.tuner import measure_group
        return measure_group
    if name in ("validate_ladder", "LadderReport"):
        # lazy: ladder -> tuner -> codegen
        from repro.tune import ladder as _ladder
        return getattr(_ladder, name)
    raise AttributeError(name)
