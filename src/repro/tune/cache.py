"""Persistent on-disk tuning cache.

A single JSON file maps decision keys (see ``tune/space.py``) to recorded
decisions, so a process that has tuned once never measures again: the next
run — or the next *machine* sharing the cache file — replays the table.
Location: ``$REPRO_TUNE_CACHE`` if set, else ``~/.cache/repro-tune.json``.
Writes are atomic (tmp file + rename) and the schema is versioned; a cache
written by an incompatible version is ignored rather than misread. The
payload also carries a fingerprint of the kernel/codegen sources the
decisions were measured against: variants tuned on old kernel code would
otherwise replay forever (warm caches never re-measure by design), so a
code change invalidates the whole cache and the next ``full`` run re-tunes.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import tempfile
from typing import Dict, Optional

CACHE_ENV = "REPRO_TUNE_CACHE"
SCHEMA_VERSION = 1


def default_cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro-tune.json")


@functools.lru_cache(maxsize=1)
def code_fingerprint() -> str:
    """Digest of the sources whose changes invalidate measured decisions:
    the kernels the variants select among and the codegen that dispatches
    on them. Imported lazily — codegen itself imports ``tune.device``."""
    from repro.core import codegen
    from repro.kernels import ops, segment_mm, traversal

    h = hashlib.sha1()
    for mod in (segment_mm, traversal, ops, codegen):
        try:
            with open(mod.__file__, "rb") as f:
                h.update(f.read())
        except OSError:
            h.update(mod.__name__.encode())
    return h.hexdigest()[:12]


class TuneCache:
    """Dict-like persistent store: key string -> JSON-able decision value."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._entries: Dict[str, object] = {}
        self._dirty = False
        self._load()

    def _load(self) -> None:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return
        if (isinstance(raw, dict) and raw.get("version") == SCHEMA_VERSION
                and raw.get("code") == code_fingerprint()):
            entries = raw.get("entries")
            if isinstance(entries, dict):
                self._entries = entries

    # ------------------------------------------------------------------
    def get(self, key: str):
        return self._entries.get(key)

    def put(self, key: str, value) -> None:
        if self._entries.get(key) != value:
            self._entries[key] = value
            self._dirty = True

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------------
    def save(self) -> None:
        """Atomically persist if anything changed since load/last save."""
        if not self._dirty:
            return
        payload = {"version": SCHEMA_VERSION, "code": code_fingerprint(),
                   "entries": self._entries}
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".repro-tune-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._dirty = False
