"""The measurement-driven autotuner (ISSUE 4 tentpole).

Per lowered op instance the tuner searches a small variant space — backend,
``tile_rows``/``tile_n``, in-kernel gather fusion, per-edge-var COMPACT vs
VANILLA materialization, and the kernel-layout tile — pruning with the
``tune/cost.py`` prior and deciding by on-device timing of the whole lowered
plan (coordinate descent: one op's variant changes at a time, so fusion
interactions are measured, not modeled). Decisions land in a
``TuningDecisions`` table and in the persistent ``TuneCache``; a warm cache
replays every decision with **zero** measurements.

Keys are never constructed here: a shape-only ``jax.eval_shape`` pass runs
the generated code with a recording decision table, capturing the exact key
strings ``codegen`` will query at trace time. That makes key construction
single-sourced — a tuned decision can't miss its op because of key drift.

Modes:
  * ``off``    — the tuner is never built; hardcoded defaults everywhere.
  * ``cached`` — replay persisted decisions; never measure. Ops without a
                 cache entry keep the default heuristics.
  * ``full``   — replay persisted decisions; measure (and persist) the rest.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core import codegen
from repro.core.ir import passes
from repro.tune import cost
from repro.tune import device as D
from repro.tune import space as S
from repro.tune.cache import TuneCache
from repro.tune.decisions import TuningDecisions

MODES = ("off", "cached", "full")

# layout-tile candidates measured per graph (deduped against the caller's)
_LAYOUT_CANDIDATES = ((128, 128), (32, 32))


def measure(fn, *args, warmup: int = 1, iters: int = 3,
            reduce: str = "median") -> float:
    """On-device wall-clock of one compiled candidate: compile + ``warmup``
    untimed calls, then ``reduce`` ("median" or "min") over ``iters``
    synced calls. The shared timing harness of the tuner and the obs
    per-op profiler. The tuner compares with the noise-tolerant median;
    the profiler differences prefix times, where scheduler noise
    accumulates through clamping — it wants the minimum, the best
    estimate of the true kernel cost."""
    for _ in range(1 + warmup):        # compile + warmup
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts) if reduce == "min" else np.median(ts))


def measure_group(calls, warmup: int = 1, iters: int = 3) -> List[float]:
    """Interleaved ``measure`` over a group of candidates whose timings
    will be *compared or differenced*: ``calls`` is a list of
    ``(fn, args_tuple)``. Every candidate is compiled + warmed first, then
    the timed iterations round-robin across the whole group, so slow
    clock drift (frequency scaling, co-tenant load) lands on every
    candidate alike instead of biasing whichever ran last. Returns the
    per-candidate minimum — the best estimate of true cost under
    one-sided scheduler noise. The obs profiler differences consecutive
    prefix times, where cross-candidate consistency matters more than any
    single absolute number."""
    for fn, args in calls:
        for _ in range(1 + warmup):
            jax.block_until_ready(fn(*args))
    ts: List[List[float]] = [[] for _ in calls]
    for _ in range(iters):
        for rec, (fn, args) in zip(ts, calls):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            rec.append(time.perf_counter() - t0)
    return [float(np.min(t)) for t in ts]


class _KeyRecorder:
    """Decision-table stand-in that records every key codegen queries."""

    def __init__(self):
        self.keys: List[str] = []

    def lookup(self, key: str):
        if key not in self.keys:
            self.keys.append(key)
        return None


@dataclasses.dataclass
class TuneReport:
    """What a tuned stack needs at build time."""

    decisions: TuningDecisions
    compact_vars: Optional[List[Optional[frozenset]]]  # per layer, None=default
    tile: int
    node_block: int
    graph_key: str


def graph_key(graph) -> str:
    """Graph identity for layout/materialization decisions."""
    return (f"g{graph.num_nodes}n{graph.num_edges}e{graph.num_etypes}"
            f"t{graph.num_ntypes}r{graph.entity_compaction_ratio:.3f}")


class Tuner:
    def __init__(self, mode: str = "cached", cache_path: Optional[str] = None,
                 warmup: int = 1, iters: int = 3, max_candidates: int = 4,
                 log=None):
        if mode not in MODES:
            raise ValueError(f"tune mode {mode!r}; pick one of {MODES}")
        self.mode = mode
        self.cache = TuneCache(cache_path)
        self.decisions = TuningDecisions()
        self.warmup = warmup
        self.iters = iters
        self.max_candidates = max_candidates
        self.log = log or (lambda *a, **k: None)
        self.stats: Dict[str, int] = {
            "measurements": 0, "cache_hits": 0, "tuned_ops": 0,
        }

    def _bump(self, key: str, n: int = 1) -> None:
        """Increment a tuner stat, mirrored into the obs metrics registry
        (``tune_measurements`` / ``tune_cache_hits`` / ``tune_tuned_ops``)
        so drivers and CI gates read one surface."""
        self.stats[key] += n
        obs.metrics().counter(f"tune_{key}").inc(n)

    # ------------------------------------------------------------------
    # measurement
    # ------------------------------------------------------------------
    def _time(self, fn, *args) -> float:
        """Median on-device wall-clock of one compiled candidate."""
        self._bump("measurements")
        return measure(fn, *args, warmup=self.warmup, iters=self.iters)

    def _plan_time(self, plan, params, gt, kl, feats, backend,
                   decisions) -> float:
        fn = jax.jit(lambda p, g, k, f: codegen.execute_plan(
            plan, p, g, f, k, backend, decisions))
        return self._time(fn, params, gt, kl, feats)

    # ------------------------------------------------------------------
    # the per-key decision loop (shared by plan- and block-scale tuning)
    # ------------------------------------------------------------------
    def _trial(self, key: str, variant) -> TuningDecisions:
        t = TuningDecisions(self.decisions.ops, self.decisions.materialization,
                            self.decisions.layout)
        t.set_op(key, variant)
        return t

    def _tune_keys(self, keys: Sequence[str], backend: str, measure) -> None:
        """Decide every recorded key: cache replay first, measurement (in
        ``full`` mode) for the rest. ``measure(decisions) -> seconds``."""
        for key in keys:
            if self.decisions.lookup(key) is not None:
                continue                         # decided earlier this run
            cached = self.cache.get(key)
            if cached is not None:
                self._bump("cache_hits")
                self.decisions.set_op(key, S.variant_from_json(cached))
                continue
            if self.mode != "full":
                continue                         # cached mode: keep defaults
            cands = cost.prune(key, S.candidates_for_key(key, backend),
                               backend, self.max_candidates)
            best, best_t = cands[0], float("inf")
            if len(cands) > 1:
                for c in cands:
                    t = measure(self._trial(key, c))
                    self.log(f"[tune]   {key.split('|')[0]} {c} "
                             f"{t * 1e6:.0f}us")
                    if t < best_t:
                        best, best_t = c, t
            self.decisions.set_op(key, best)
            self.cache.put(key, best.to_json())
            self._bump("tuned_ops")

    # ------------------------------------------------------------------
    # full-graph stack tuning (layout tile -> materialization -> op variants)
    # ------------------------------------------------------------------
    def tune_stack(self, programs: Sequence, graph, *, backend: str = "xla",
                   tile: int = 128, node_block: int = 128,
                   feat_dims: Optional[Sequence[int]] = None,
                   reorder: bool = True, compact: bool = True,
                   seed: int = 0, tune_layout: bool = True,
                   tune_ops: bool = True) -> TuneReport:
        """Tune a multi-layer stack over one graph. ``feat_dims`` is each
        layer's input feature dimension (defaults to probing layer 0's
        weights is not possible generically, so callers pass it).

        ``tune_layout``/``tune_ops`` gate the full-graph-only decision
        families: a caller that will only ever run the sampled block path
        (serving) keeps just the materialization decisions — which shape
        the lowered plans shared by both paths — and skips the full-graph
        layout/op measurements its traffic would never query."""
        if feat_dims is None:
            raise ValueError("tune_stack needs feat_dims (input dim per "
                             "layer)")
        if len(feat_dims) != len(programs):
            raise ValueError("one feat dim per layer program")
        gkey = graph_key(graph)
        gt = graph.to_tensors()
        rng = np.random.default_rng(seed)

        def feats_for(dim: int):
            return {"feature": jnp.asarray(
                rng.normal(size=(graph.num_nodes, dim)), jnp.float32)}

        # -- layout tile (per graph; all layers share the kernel layouts)
        if tune_layout:
            tile, node_block = self._tune_layout(
                programs[0], graph, gt, gkey, backend, tile, node_block,
                feats_for(feat_dims[0]), reorder, compact, seed)
        kl = codegen.build_kernel_layouts(graph, tile=tile,
                                          node_block=node_block)

        # -- per layer: materialization, then per-op variants
        compact_sets: List[Optional[frozenset]] = []
        for li, prog in enumerate(programs):
            feats = feats_for(feat_dims[li])
            cset = self._tune_materialization(
                prog, li, gt, kl, gkey, backend, feat_dims[li], feats,
                reorder, compact, seed)
            compact_sets.append(cset)
            if not tune_ops:
                continue
            plan = passes.lower_program(prog, reorder=reorder,
                                        compact=compact, compact_vars=cset)
            params = codegen.init_params(plan, gt, jax.random.key(seed))
            rec = _KeyRecorder()
            jax.eval_shape(lambda p, g, k, f, pl=plan: codegen.execute_plan(
                pl, p, g, f, k, backend, rec), params, gt, kl, feats)

            def measure(trial, pl=plan, pa=params, fe=feats):
                return self._plan_time(pl, pa, gt, kl, fe, backend, trial)

            self._tune_keys(rec.keys, backend, measure)
        self.cache.save()
        self.log(f"[tune] stack tuned: {self.stats['tuned_ops']} measured "
                 f"ops, {self.stats['cache_hits']} cache replays, "
                 f"{self.stats['measurements']} measurements")
        return TuneReport(decisions=self.decisions,
                          compact_vars=compact_sets, tile=tile,
                          node_block=node_block, graph_key=gkey)

    # ------------------------------------------------------------------
    def _tune_layout(self, prog, graph, gt, gkey, backend, tile, node_block,
                     feats, reorder, compact, seed):
        key = f"lay|{gkey}|{backend}|{D.device_kind()}"
        cached = self.cache.get(key)
        if cached is not None:
            self._bump("cache_hits")
            self.decisions.set_layout(key, cached["tile"],
                                      cached["node_block"])
            return cached["tile"], cached["node_block"]
        if self.mode != "full":
            return tile, node_block
        plan = passes.lower_program(prog, reorder=reorder, compact=compact)
        params = codegen.init_params(plan, gt, jax.random.key(seed))
        cands = [(tile, node_block)]
        cands += [c for c in _LAYOUT_CANDIDATES if c not in cands]
        best, best_t = cands[0], float("inf")
        for t, nb in cands:
            kl = codegen.build_kernel_layouts(graph, tile=t, node_block=nb)
            dt = self._plan_time(plan, params, gt, kl, feats, backend, None)
            self.log(f"[tune]   layout tile={t} node_block={nb} "
                     f"{dt * 1e6:.0f}us")
            if dt < best_t:
                best, best_t = (t, nb), dt
        self.decisions.set_layout(key, *best)
        self.cache.put(key, {"tile": best[0], "node_block": best[1]})
        return best

    # ------------------------------------------------------------------
    def _tune_materialization(self, prog, layer_idx, gt, kl, gkey, backend,
                              feat_dim, feats, reorder, compact, seed):
        """Per-edge-var COMPACT vs VANILLA, gated by the block's
        entity-compaction ratio and decided by measurement (greedy one-var
        flips off the static default)."""
        cands = passes.compactable_edge_vars(prog, reorder=reorder)
        if not cands:
            return None
        key = (f"mat|{prog.name}|d{feat_dim}|{gkey}|{backend}|"
               f"{D.device_kind()}")
        cached = self.cache.get(key)
        if cached is not None and set(cached) == set(cands):
            self._bump("cache_hits")
            self.decisions.set_materialization(key, cached)
            return frozenset(v for v, m in cached.items() if m == "compact")
        if self.mode != "full":
            return None                          # keep the static policy
        ratio = gt.num_unique / max(1, gt.num_edges)
        # compaction dedups (src, etype) work; with no dedup available
        # (ratio ~1) the indirection can only cost — skip the measurements
        if ratio >= 0.999:
            current = {v: "vanilla" for v in cands}
            self.decisions.set_materialization(key, current)
            self.cache.put(key, current)
            return frozenset()
        current = {v: ("compact" if compact else "vanilla") for v in cands}
        base_t = self._mat_time(prog, current, gt, kl, feats, backend,
                                reorder, compact, seed)
        for v in cands:
            flipped = dict(current)
            flipped[v] = "vanilla" if current[v] == "compact" else "compact"
            t = self._mat_time(prog, flipped, gt, kl, feats, backend,
                               reorder, compact, seed)
            self.log(f"[tune]   mat {v}={flipped[v]} {t * 1e6:.0f}us "
                     f"(base {base_t * 1e6:.0f}us)")
            if t < base_t:
                current, base_t = flipped, t
        self.decisions.set_materialization(key, current)
        self.cache.put(key, current)
        self._bump("tuned_ops")
        return frozenset(v for v, m in current.items() if m == "compact")

    def _mat_time(self, prog, per_var, gt, kl, feats, backend, reorder,
                  compact, seed) -> float:
        cset = frozenset(v for v, m in per_var.items() if m == "compact")
        plan = passes.lower_program(prog, reorder=reorder, compact=compact,
                                    compact_vars=cset)
        params = codegen.init_params(plan, gt, jax.random.key(seed))
        return self._plan_time(plan, params, gt, kl, feats, backend, None)

    # ------------------------------------------------------------------
    # block-scale tuning (sampled serving / training mini-batches)
    # ------------------------------------------------------------------
    def tune_block_sequence(self, plans: Sequence, params, mb, global_feats,
                            *, backend: str = "xla",
                            activation: str = "relu") -> TuningDecisions:
        """Tune the op variants of a sampled block sequence on a
        representative ``MiniBatch`` (bucketed shapes make the decisions
        reusable across steady-state traffic). Adds to ``self.decisions``
        and persists; returns the table."""
        feats = {"feature": global_feats[mb.input_ids]}
        gts, kls = list(mb.tensors), list(mb.layouts)
        dst_locals, seed_perm = list(mb.dst_locals), mb.seed_perm
        plans = list(plans)
        params = list(params)

        rec = _KeyRecorder()
        jax.eval_shape(
            lambda p, g, k, d, s, f: codegen.execute_block_sequence(
                plans, p, g, k, d, s, f, backend, activation, rec),
            params, gts, kls, dst_locals, seed_perm, feats)

        def measure(trial):
            fn = jax.jit(
                lambda p, g, k, d, s, f: codegen.execute_block_sequence(
                    plans, p, g, k, d, s, f, backend, activation, trial))
            return self._time(fn, params, gts, kls, dst_locals, seed_perm,
                              feats)

        self._tune_keys(rec.keys, backend, measure)
        self.cache.save()
        return self.decisions
