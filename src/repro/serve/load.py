"""Open-loop load generation for the serving runtime.

An *open-loop* generator decides request arrival times independently of
how fast the server completes them — the production traffic model, and the
one under which tail latency means anything (a closed loop silently
self-throttles when the server slows down, hiding queueing collapse).

``OpenLoopLoad`` is a pure function of its seed: it materializes a list of
``Request`` objects with

* **arrival offsets** drawn from a seeded arrival process — ``poisson``
  (exponential inter-arrival gaps at ``rate_rps``), ``burst`` (groups of
  ``burst_size`` back-to-back requests, bursts Poisson-spaced at the same
  average rate), or ``uniform`` (fixed gaps);
* **seed-node ids** drawn through the existing ``sampling.SeedStream`` —
  so the Zipf-skew machinery (``zipf_alpha``) and the id-space permutation
  that serving benchmarks already rely on apply unchanged to request
  traffic;
* **request sizes** (seeds per request) drawn from ``size_choices``; and
* **per-request deadlines** (``slo_ms`` — a scalar or per-request choices)
  that the coalescer's admission control honors.

Replaying the same ``OpenLoopLoad`` therefore submits bit-identical
request content on every run; only wall-clock service times differ.
``replay()`` walks the schedule in real time (sleeping out the gaps) and
pushes each request into a runtime's ``submit`` — KeyboardInterrupt-safe,
so Ctrl-C mid-replay stops submission and lets the caller drain.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from repro.sampling import SeedStream

# terminal request states (set by the runtime, reported by stats())
OK = "ok"                          # completed within its deadline
LATE = "late"                      # completed, but past its deadline
REJECTED_DEADLINE = "rejected_deadline"  # admission: could not make SLO
REJECTED_OVERLOAD = "rejected_overload"  # admission queue full
REJECTED_SHUTDOWN = "rejected_shutdown"  # queued at close(), not served
TERMINAL_STATUSES = (OK, LATE, REJECTED_DEADLINE, REJECTED_OVERLOAD,
                     REJECTED_SHUTDOWN)


@dataclasses.dataclass
class Request:
    """One inference request: classify ``seeds`` within ``slo_ms`` of
    arrival. ``arrival_s`` is the scheduled offset from stream start;
    ``t_arrive`` is stamped (monotonic clock) when the runtime admits the
    request into its queue, and every deadline computation runs off it."""

    rid: int
    seeds: np.ndarray
    arrival_s: float
    slo_ms: float
    model: Optional[str] = None     # tenant route (None: single-model)
    t_arrive: Optional[float] = None

    @property
    def num_seeds(self) -> int:
        return int(self.seeds.shape[0])

    def deadline(self) -> float:
        """Absolute monotonic-clock deadline (requires ``t_arrive``)."""
        return self.t_arrive + self.slo_ms * 1e-3


@dataclasses.dataclass
class Response:
    """Terminal record for one request."""

    rid: int
    status: str
    logits: Optional[np.ndarray]    # [num_seeds, classes] or None
    latency_ms: float               # arrival -> completion (0 for rejects)
    queue_ms: float                 # arrival -> batch admission
    rung: Optional[int] = None      # shape bucket the request was served in
    model: Optional[str] = None

    @property
    def completed(self) -> bool:
        return self.status in (OK, LATE)


class OpenLoopLoad:
    """Seeded open-loop request schedule over a ``SeedStream``.

    ``requests()`` returns the full schedule (a pure function of the
    constructor arguments); ``replay(submit)`` walks it in real time.

    ``rate_rps`` is the *average* arrival rate for every process kind.
    ``size_choices`` gives the per-request seed counts (drawn uniformly,
    per-request rng); ``slo_ms`` is one budget for all requests or a
    sequence of choices drawn the same way. ``models`` routes requests
    round-robin across tenant names (multi-model tenancy traffic).
    """

    def __init__(self, num_nodes: int, *, rate_rps: float = 100.0,
                 num_requests: int = 64, process: str = "poisson",
                 burst_size: int = 4,
                 size_choices: Sequence[int] = (1, 2, 4, 8),
                 slo_ms: Union[float, Sequence[float]] = 50.0,
                 zipf_alpha: Optional[float] = None,
                 models: Optional[Sequence[str]] = None, seed: int = 0):
        if rate_rps <= 0:
            raise ValueError("rate_rps must be positive")
        if process not in ("poisson", "burst", "uniform"):
            raise ValueError(f"process={process!r}; "
                             f"pick poisson/burst/uniform")
        if burst_size < 1:
            raise ValueError("burst_size must be >= 1")
        self.num_nodes = int(num_nodes)
        self.rate_rps = float(rate_rps)
        self.num_requests = int(num_requests)
        self.process = process
        self.burst_size = int(burst_size)
        self.size_choices = tuple(int(s) for s in size_choices)
        if any(s < 1 for s in self.size_choices):
            raise ValueError("request sizes must be >= 1")
        self.slo_choices = (tuple(float(s) for s in slo_ms)
                            if isinstance(slo_ms, (tuple, list, np.ndarray))
                            else (float(slo_ms),))
        self.models = tuple(models) if models else None
        self.seed = int(seed)
        # seed ids ride the existing stream machinery (Zipf skew included);
        # batch_size = max request size, each request takes a prefix
        self._stream = SeedStream(self.num_nodes,
                                  batch_size=max(self.size_choices),
                                  seed=self.seed, zipf_alpha=zipf_alpha)

    # ------------------------------------------------------------------
    def _arrivals(self) -> np.ndarray:
        rng = np.random.default_rng((self.seed, 0xA881))
        n = self.num_requests
        if self.process == "uniform":
            return np.arange(n, dtype=np.float64) / self.rate_rps
        if self.process == "poisson":
            return np.cumsum(rng.exponential(1.0 / self.rate_rps, size=n))
        # burst: groups arrive back-to-back; burst starts are Poisson at
        # rate rate_rps / burst_size so the average rate is preserved
        starts = np.cumsum(rng.exponential(
            self.burst_size / self.rate_rps,
            size=-(-n // self.burst_size)))
        return np.repeat(starts, self.burst_size)[:n]

    def requests(self) -> List[Request]:
        """The full schedule, deterministic in the constructor args."""
        arrivals = self._arrivals()
        out: List[Request] = []
        for rid in range(self.num_requests):
            rng = np.random.default_rng((self.seed, 0x5120, rid))
            size = int(self.size_choices[rng.integers(
                len(self.size_choices))])
            slo = float(self.slo_choices[rng.integers(
                len(self.slo_choices))])
            seeds = self._stream.batch(rid)[:size]
            model = (self.models[rid % len(self.models)]
                     if self.models else None)
            out.append(Request(rid=rid, seeds=seeds,
                               arrival_s=float(arrivals[rid]),
                               slo_ms=slo, model=model))
        return out

    # ------------------------------------------------------------------
    def replay(self, submit: Callable[[Request], object],
               requests: Optional[List[Request]] = None,
               speedup: float = 1.0) -> int:
        """Submit the schedule in real time (open loop: never waits on
        completions). ``speedup`` > 1 compresses the schedule. Returns the
        number of requests submitted; stops early (without raising) on
        KeyboardInterrupt so the caller can drain what is in flight."""
        if requests is None:
            requests = self.requests()
        t0 = time.monotonic()
        submitted = 0
        try:
            for req in requests:
                delay = t0 + req.arrival_s / speedup - time.monotonic()
                if delay > 0:
                    time.sleep(delay)
                submit(req)
                submitted += 1
        except KeyboardInterrupt:
            pass
        return submitted
