"""Deadline-aware batch coalescing over a measured shape-bucket ladder.

Serving traffic arrives as small requests (1-16 seeds); the compiled
executors amortize best over larger batches. The coalescer holds queued
requests just long enough to merge them into the **largest ladder rung
whose measured execute latency still meets the tightest deadline in the
batch** — the classic latency/throughput trade, but made explicit against
per-rung measurements instead of a fixed timeout.

Three pieces:

* ``ladder(...)`` builds the rung set. ``pow2`` is the shape-bucket set
  serving already compiles for; ``fine`` interleaves ``3 * 2^k`` rungs
  (1, 2, 3, 4, 6, 8, 12, ...) halving the worst-case pad waste. Whether a
  finer rung *pays for itself* is a measured question — padding a
  37-request batch to 48 instead of 64 only helps if the 48-rung actually
  executes faster — so ``repro.tune.ladder.validate_ladder`` times every
  rung with the tuner's interleaved ``measure_group`` harness and drops
  non-pow2 rungs that don't beat the next pow2 rung.
* ``LatencyModel``: per-rung execute-latency estimates — seeded by the
  calibration measurements, tracked online as a peak-decaying EWMA so the
  admission decision follows the machine it is running on.
* ``Coalescer.plan(...)``: one admission decision over the pending queue.
  Expired requests (deadline unmeetable even at the smallest rung) are
  rejected immediately — never silently served late — and the decision to
  *wait* for more arrivals is taken only while the tightest in-queue
  deadline retains slack beyond the coalesce window.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.layout import pow2ceil
from repro.serve.load import Request


def ladder(max_batch: int, kind: str = "fine") -> List[int]:
    """Rung sizes (ascending). ``pow2``: 1, 2, 4, ..., max_batch.
    ``fine`` adds the 3*2^k midpoints: 1, 2, 3, 4, 6, 8, 12, 16, ...
    ``max_batch`` is rounded up to a power of two (the top rung)."""
    if max_batch < 1:
        raise ValueError("max_batch must be >= 1")
    if kind not in ("pow2", "fine"):
        raise ValueError(f"ladder kind={kind!r}; pick pow2/fine")
    top = pow2ceil(max_batch)
    rungs = {1 << k for k in range(top.bit_length())}
    if kind == "fine":
        rungs.update(3 << k for k in range(top.bit_length())
                     if 3 << k <= top)
    return sorted(rungs)


class LatencyModel:
    """Per-rung execute-latency estimates (milliseconds).

    ``calibrate(rung, ms)`` installs a measured baseline (the ladder
    validation / warmup pass); ``observe(rung, ms)`` folds in live
    samples with an EWMA whose estimate decays *down* slowly but jumps
    *up* immediately (admission errs toward rejecting what it cannot
    serve, not toward promising latencies it once saw on a cold cache).
    ``estimate`` for an unmeasured rung falls back to the nearest
    measured rung above it (conservative), then below."""

    def __init__(self, alpha: float = 0.25, headroom: float = 1.1):
        if not 0 < alpha <= 1:
            raise ValueError("alpha in (0, 1] required")
        self.alpha = alpha
        self.headroom = headroom
        self._ewma: Dict[int, float] = {}
        self.samples = 0

    def calibrate(self, rung: int, ms: float) -> None:
        self._ewma[int(rung)] = float(ms)

    def observe(self, rung: int, ms: float) -> None:
        rung = int(rung)
        self.samples += 1
        prev = self._ewma.get(rung)
        if prev is None or ms > prev:
            self._ewma[rung] = float(ms)     # jump up immediately
        else:
            self._ewma[rung] = prev + self.alpha * (ms - prev)

    def known(self) -> Dict[int, float]:
        return dict(self._ewma)

    def estimate(self, rung: int) -> Optional[float]:
        """Headroom-padded latency estimate for ``rung`` (None if nothing
        is measured yet — admission then treats every rung as feasible,
        the only option before calibration)."""
        if not self._ewma:
            return None
        rung = int(rung)
        v = self._ewma.get(rung)
        if v is None:
            above = [r for r in self._ewma if r >= rung]
            v = (self._ewma[min(above)] if above
                 else self._ewma[max(self._ewma)])
        return v * self.headroom


@dataclasses.dataclass
class PlannedBatch:
    """One admitted batch: the member requests, the rung it executes at,
    and the padded seed vector (member seeds concatenated in arrival
    order, padded with repeats of the first seed — inert rows that are
    never sliced back out)."""

    step: int
    rung: int
    requests: List[Request]
    seeds: np.ndarray
    t_admit: float

    @property
    def slices(self) -> List[Tuple[int, int]]:
        """Per-request [lo, hi) row ranges into the executed batch."""
        out, lo = [], 0
        for r in self.requests:
            out.append((lo, lo + r.num_seeds))
            lo += r.num_seeds
        return out


@dataclasses.dataclass
class PlanDecision:
    """Outcome of one ``Coalescer.plan`` call."""

    batch: Optional[PlannedBatch]      # admit this now (None: nothing yet)
    rejects: List[Request]             # deadline-unmeetable, reject NOW
    wait_s: float                      # if no batch: how long to hold


class Coalescer:
    """Admission control: pending requests -> (batch | wait | rejects).

    ``max_wait_ms`` bounds how long the oldest pending request may be
    held for coalescing; the deadline-aware part is that waiting is also
    cut short whenever the tightest pending deadline's slack (beyond the
    estimated execute latency) runs out.
    """

    def __init__(self, rungs: Sequence[int], latency: LatencyModel,
                 *, max_wait_ms: float = 5.0, slack_margin_ms: float = 0.5):
        self.rungs = sorted(int(r) for r in rungs)
        if not self.rungs or self.rungs[0] < 1:
            raise ValueError("need a non-empty ladder of positive rungs")
        self.latency = latency
        self.max_wait_ms = float(max_wait_ms)
        self.slack_margin_ms = float(slack_margin_ms)
        self._step = 0

    @property
    def max_rung(self) -> int:
        return self.rungs[-1]

    def covering_rung(self, num_seeds: int) -> int:
        """Smallest rung holding ``num_seeds`` (the executed bucket)."""
        for r in self.rungs:
            if r >= num_seeds:
                return r
        raise ValueError(f"{num_seeds} seeds exceed the top rung "
                         f"{self.max_rung}")

    # ------------------------------------------------------------------
    def plan(self, pending: List[Request], now: float,
             drain: bool = False) -> PlanDecision:
        """One admission decision. ``pending`` is mutated: admitted and
        rejected requests are removed (arrival order is preserved for the
        remainder). ``drain`` disables waiting — shutdown admits whatever
        is feasible immediately.

        Every request in ``pending`` must be stamped (``t_arrive``)."""
        rejects: List[Request] = []
        min_est = self.latency.estimate(self.rungs[0])
        # 1) reject what can no longer make its deadline even alone at the
        #    smallest rung — an expired request must never ride along and
        #    be silently served late
        keep = []
        for r in pending:
            slack_ms = (r.deadline() - now) * 1e3
            if slack_ms <= 0 or (min_est is not None
                                 and slack_ms < min_est):
                rejects.append(r)
            else:
                keep.append(r)
        pending[:] = keep
        if not pending:
            return PlanDecision(None, rejects, self.max_wait_ms * 1e-3)

        # 2) the largest rung whose estimated latency fits the tightest
        #    in-queue deadline
        tightest_ms = min((r.deadline() - now) * 1e3 for r in pending)
        budget_ms = tightest_ms - self.slack_margin_ms
        feasible = [r for r in self.rungs
                    if (est := self.latency.estimate(r)) is None
                    or est <= budget_ms]
        r_max = max(feasible) if feasible else self.rungs[0]

        # 3) fill it in arrival order
        batch: List[Request] = []
        used = 0
        for r in pending:
            if used + r.num_seeds > r_max:
                break
            batch.append(r)
            used += r.num_seeds

        total = sum(r.num_seeds for r in pending)
        oldest = pending[0]
        waited_ms = (now - oldest.t_arrive) * 1e3
        if (not drain and used < r_max and total < r_max
                and waited_ms < self.max_wait_ms
                and budget_ms - self.max_wait_ms > (
                    self.latency.estimate(r_max) or 0.0)):
            # the largest feasible rung is not full, more arrivals may
            # still make it, and the tightest deadline can afford the wait
            wait_s = min(self.max_wait_ms - waited_ms,
                         self.max_wait_ms) * 1e-3
            return PlanDecision(None, rejects, max(wait_s, 1e-4))

        if not batch:
            # head request alone exceeds every feasible rung (a huge
            # request under a tight deadline): serve it at its covering
            # rung rather than starving it — completion marks it late if
            # the estimate was right
            batch = [pending[0]]
            used = pending[0].num_seeds
        del pending[:len(batch)]
        rung = self.covering_rung(used)
        seeds = np.concatenate([r.seeds for r in batch])
        if seeds.shape[0] < rung:   # pad rows are never sliced back out
            seeds = np.concatenate([
                seeds, np.full(rung - seeds.shape[0], seeds[0],
                               dtype=seeds.dtype)])
        pb = PlannedBatch(step=self._step, rung=rung, requests=batch,
                          seeds=seeds.astype(np.int32), t_admit=now)
        self._step += 1
        return PlanDecision(pb, rejects, 0.0)
