"""``repro.serve`` — the online serving runtime (ISSUE 10 tentpole).

Hector's serving story so far was a *closed-loop* driver: one batch in
flight, the next request waits for the previous answer. This package puts
a production-shaped runtime in front of the compiled executors:

* ``load``: open-loop request generation — seeded Poisson/burst arrival
  processes over the existing ``SeedStream`` traffic models, per-request
  deadlines. Open-loop (arrivals independent of completions) is the model
  under which tail latency means anything.
* ``coalesce``: deadline-aware batch coalescing — queued requests merge
  into the largest shape-bucket rung whose *measured* execute latency
  still meets the tightest in-batch SLO; expired requests are rejected,
  never silently served late. The finer-than-pow2 rung ladder is
  validated on the tuner's measurement harness
  (``repro.tune.ladder.validate_ladder``).
* ``runtime``: the async pipeline — sampling → feature gather → compiled
  execute overlapped across in-flight batches via the prefetch loader,
  bounded queues end to end, graceful drain on shutdown.
* ``tenancy``: multi-model serving — several ``hector.compile()``
  artifacts in one process sharing one tuning cache and one obs scope,
  isolated by per-plan compile-cache keys.
"""
from repro.serve.coalesce import (Coalescer, LatencyModel,  # noqa: F401
                                  PlannedBatch, PlanDecision, ladder)
from repro.serve.load import (LATE, OK, OpenLoopLoad,       # noqa: F401
                              REJECTED_DEADLINE, REJECTED_OVERLOAD,
                              REJECTED_SHUTDOWN, Request, Response,
                              TERMINAL_STATUSES)
from repro.serve.runtime import ServingRuntime              # noqa: F401
from repro.serve.tenancy import MultiTenantRuntime          # noqa: F401

__all__ = [
    "OpenLoopLoad", "Request", "Response", "OK", "LATE",
    "REJECTED_DEADLINE", "REJECTED_OVERLOAD", "REJECTED_SHUTDOWN",
    "TERMINAL_STATUSES", "ladder", "LatencyModel", "Coalescer",
    "PlannedBatch", "PlanDecision", "ServingRuntime", "MultiTenantRuntime",
]
