"""The online serving runtime: async request pipeline over the compiled
executors.

One ``ServingRuntime`` turns a compiled engine (``hector.compile()`` /
``RGNNEngine``) into a deadline-aware online server::

    submit(Request) -> [admission queue] -> coalescer thread
        -> PlannedBatch -> [plan queue] -> MiniBatchLoader producer
            (sample + layout + feature gather, prefetch-overlapped)
        -> device-ready MiniBatch -> execute loop (compiled block forward)
        -> per-request Response (OK / LATE / REJECTED_*)

The three stages run concurrently for *different* batches: while batch k
executes, the loader producer is already sampling and feature-gathering
batch k+1 (the same overlap the offline loader gives training), and the
coalescer is accumulating batch k+2 from fresh arrivals. Queues are
bounded everywhere, so a slow stage exerts backpressure instead of
growing memory without bound.

Admission is the ``coalesce.Coalescer``: requests merge into the largest
ladder rung whose measured latency still meets the tightest in-batch
deadline, expired requests are *rejected* (never silently served late),
and ``calibrate()`` pre-measures every rung — validating finer-than-pow2
rungs with the tuner's ``measure_group`` harness — so the compiled-shape
set is warm before the first real request and the steady state retraces
zero times.

Shutdown (``close()`` — also what a SIGINT handler should call) is a
graceful drain: no new requests are accepted, queued requests are either
admitted (deadline-feasible) or rejected with ``REJECTED_SHUTDOWN``,
in-flight batches complete, and every worker thread is joined — no
orphaned threads survive ``close()``.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.sampling.bucketing import ShapeFloors
from repro.sampling.loader import build_minibatch
from repro.serve.coalesce import Coalescer, LatencyModel, PlannedBatch, ladder
from repro.serve.load import (LATE, OK, REJECTED_DEADLINE, REJECTED_OVERLOAD,
                              REJECTED_SHUTDOWN, Request, Response)

# calibration batches sample with step indices far outside real traffic so
# they never collide with the request stream's (seed, batch_index) keying
_CAL_STEP_BASE = 1 << 30
_PROBE_BASE = 1 << 20    # floor-probe builds use their own index range


class _Handle:
    """Per-request completion handle: ``wait()`` blocks for the terminal
    ``Response`` (set exactly once by the runtime)."""

    __slots__ = ("_event", "response")

    def __init__(self):
        self._event = threading.Event()
        self.response: Optional[Response] = None

    def _complete(self, resp: Response) -> None:
        self.response = resp
        self._event.set()

    def wait(self, timeout: Optional[float] = None) -> Optional[Response]:
        self._event.wait(timeout)
        return self.response

    @property
    def done(self) -> bool:
        return self._event.is_set()


class ServingRuntime:
    """Deadline-aware online server over one compiled engine.

    ``engine`` is a ``CompiledRGNN`` or ``RGNNEngine``; ``store`` an
    optional ``repro.feats`` store (feature rows then ride the loader's
    prefetch overlap exactly as in offline serving). ``rungs`` is the
    coalescer's shape-bucket ladder (default: the fine ladder up to
    ``max_batch``); run ``calibrate()`` before ``start()`` to measure it.

    Metrics land in the ambient ``obs`` scope labeled by tenant
    (``model=<name>``): ``serve_request_ms`` / ``serve_queue_ms`` /
    ``serve_execute_ms`` histograms, ``serve_requests`` (by status) and
    ``serve_deadline_miss`` counters, ``serve_queue_depth`` gauge +
    histogram, and per-rung ``serve_batches`` counters. Spans:
    ``coalesce`` per admitted batch, ``execute_async`` per executed
    batch (both on their worker threads' tracks).
    """

    def __init__(self, engine, params, store=None, *,
                 name: Optional[str] = None,
                 rungs: Optional[Sequence[int]] = None,
                 max_batch: int = 32,
                 max_wait_ms: float = 5.0,
                 queue_limit: int = 256,
                 depth: int = 2,
                 cache_blocks: int = 0,
                 cache_layouts: int = 64,
                 latency_headroom: float = 1.25,
                 now_fn: Callable[[], float] = time.monotonic):
        self.engine = engine
        self.params = params
        if store is None:
            raise ValueError(
                "ServingRuntime needs features: pass store= (a repro.feats "
                "store, rides the loader's prefetch overlap) or a raw "
                "global feature pytree")
        self.store = store
        self.name = name or engine.cfg.model_name
        self.latency = LatencyModel(headroom=latency_headroom)
        self.coalescer = Coalescer(
            rungs if rungs is not None else ladder(max_batch, "fine"),
            self.latency, max_wait_ms=max_wait_ms)
        self.queue_limit = int(queue_limit)
        self._now = now_fn

        self._lock = threading.Condition()
        self._pending: List[Request] = []
        self._handles = {}                    # rid -> _Handle
        self._inflight = 0                    # submitted, not yet terminal
        self._plan_q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._by_step = {}                    # loader step -> PlannedBatch
        self.responses: List[Response] = []   # completion order

        self._closing = False
        self._closed = threading.Event()
        self._started = False
        self._stopping = False                # unblocks the seed callable
        self._close_lock = threading.Lock()

        # grow-only per-rung bucket floors: one ladder rung converges to
        # one compiled shape set (host sampler path; the device sampler
        # brings its own bucket hysteresis)
        self.shape_floors = (ShapeFloors()
                             if getattr(engine, "device_sampler", None)
                             is None else None)
        # only a real store can ride the loader's producer-side gather; a
        # raw feature pytree goes straight to the executor instead
        loader_store = store if hasattr(store, "gather") else None
        self._loader = engine.make_loader(
            self._planned_seeds, num_batches=None, depth=depth,
            cache_blocks=cache_blocks, cache_layouts=cache_layouts,
            feature_store=loader_store, shape_floors=self.shape_floors)
        self._coalesce_thread = threading.Thread(
            target=self._coalesce_loop, daemon=True,
            name=f"serve-coalesce-{self.name}")
        self._exec_thread = threading.Thread(
            target=self._exec_loop, daemon=True,
            name=f"serve-exec-{self.name}")

        # warmup bookkeeping for the zero-retrace steady-state contract
        self._warm_traces: Optional[int] = None
        self._hubs: Optional[np.ndarray] = None
        self._exec_failure: Optional[BaseException] = None
        # local aggregates (exact even when obs is disabled)
        self._lat_ms: List[float] = []
        self._queue_ms: List[float] = []
        self._exec_ms: List[float] = []
        self._depth_seen: List[int] = []
        self._rung_counts = {}
        self._batches = 0
        self._padded_seeds = 0
        self._real_seeds = 0
        self.ladder_report = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "ServingRuntime":
        if self._started:
            return self
        self._started = True
        self._coalesce_thread.start()
        self._exec_thread.start()
        return self

    def __enter__(self) -> "ServingRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def mark_warm(self) -> None:
        """Snapshot executor trace counts: compiles after this point count
        as steady-state retraces in ``stats()``."""
        self._warm_traces = self.engine.block_executor.trace_count

    # ------------------------------------------------------------------
    # calibration: measure the ladder, warm the compiled-shape set
    # ------------------------------------------------------------------
    def _hub_seeds(self) -> np.ndarray:
        """Node ids ranked by capped sampled-neighborhood size — the seeds
        that produce the *largest* block shapes. Fanout sampling takes up
        to ``fanout`` in-neighbors per (node, etype), so a node's worst-case
        frontier contribution is its per-etype in-degree capped at the
        fanout, summed; probing floors with the top-ranked nodes pins the
        heavy tail (hub seeds) that random probes miss."""
        if self._hubs is None:
            g = self.engine.graph
            fan = max((int(x) for f in self.engine.cfg.fanouts
                       for x in np.atleast_1d(f)), default=3)
            if fan < 0:     # full-neighborhood sampling: no cap
                fan = np.iinfo(np.int64).max
            key = (np.asarray(g.dst, np.int64) * g.num_etypes
                   + np.asarray(g.etype, np.int64))
            uniq, cnt = np.unique(key, return_counts=True)
            score = np.zeros(g.num_nodes, np.int64)
            np.add.at(score, uniq // g.num_etypes, np.minimum(cnt, fan))
            self._hubs = np.argsort(-score).astype(np.int32)
        return self._hubs

    def _calibration_mb(self, rung: int, index: int, hubs: bool = False):
        """A representative device-ready batch of ``rung`` seeds (built
        through the same sampler/layout config the loader will use, so the
        compiled shapes it warms are the ones traffic hits). ``hubs``
        draws the highest-degree seeds instead of random ones — the
        adversarial shape probe."""
        cfg = self.engine.cfg
        if hubs:
            # consecutive top-of-ranking windows: probe 0 takes the worst
            # hubs, later probes the next tiers (index is only used mod a
            # small window count — keep the slices at the top)
            ranked = self._hub_seeds()
            lo = min((index % 16) * rung, max(0, ranked.size - rung))
            seeds = ranked[lo:lo + rung]
            if seeds.size < rung:
                seeds = np.concatenate(
                    [seeds, ranked[:rung - seeds.size]])
        else:
            seeds = np.random.default_rng(
                (cfg.seed, 0xCA11B, rung, index)).integers(
                0, self.engine.graph.num_nodes, rung).astype(np.int32)
        step = _CAL_STEP_BASE + index
        dev = getattr(self.engine, "device_sampler", None)
        if dev is not None:
            return dev.sample_minibatch(seeds, batch_index=step, step=step)
        seq = self.engine.sampler.sample(seeds, batch_index=step)
        return build_minibatch(seq, step=step, tile=cfg.tile,
                               node_block=cfg.node_block, bucket=cfg.bucket,
                               shape_floors=self.shape_floors)

    def calibrate(self, *, batches_per_rung: int = 2, validate: bool = True,
                  min_gain: float = 0.03, iters: int = 3,
                  probe_batches: int = 16, floor_margin: int = 1,
                  warm_rounds: int = 6, log=None) -> None:
        """Measure every ladder rung with the tuner's interleaved
        ``measure_group`` harness (``tune.ladder.validate_ladder``); seed
        the coalescer's latency model with the measurements; optionally
        drop non-pow2 rungs that don't beat their covering pow2 rung
        (``validate=True``); and mark the executor warm — calibration
        compiles every surviving rung's shape set up front.

        Shape stability comes first: ``probe_batches`` sampled batches per
        rung grow the loader's ``ShapeFloors`` (host-only builds, nothing
        executes), then the floors get ``floor_margin`` buckets of
        headroom — only after the shape set is pinned does anything
        compile, so traffic retraces only if a batch overflows double the
        largest probed bucket.

        Must run before ``start()`` (it executes on the caller's thread
        against the same compiled executor the serving loop uses)."""
        if self._started:
            raise RuntimeError("calibrate() before start()")
        from repro.tune.ladder import validate_ladder

        if self.shape_floors is not None:
            for i in range(probe_batches):
                for rung in self.coalescer.rungs:
                    # random probes cover typical traffic; hub probes pin
                    # the heavy tail (a hub seed inflates the sampled
                    # frontier several-fold past anything random probing
                    # sees)
                    # i // 2 keeps the hub window index starting at 0, so
                    # the very top of the hub ranking is always probed
                    self._calibration_mb(rung, _PROBE_BASE + i // 2,
                                         hubs=i % 2 == 1)
            self.shape_floors.bump(floor_margin)
            self.shape_floors.growths = 0   # probing is not traffic

        def prepare(rung: int):
            mbs = [self._calibration_mb(rung, i)
                   for i in range(batches_per_rung)]
            it = {"i": 0}

            def fn():
                mb = mbs[it["i"] % len(mbs)]
                it["i"] += 1
                # feats=None: gather through the store per call, so donated
                # feature buffers are never re-consumed across timed iters
                return self.engine.forward_minibatch(
                    self.params, dataclasses.replace(mb, feats=None),
                    self.store)
            return (fn, ())

        report = validate_ladder(self.coalescer.rungs, prepare,
                                 iters=iters, min_gain=min_gain)
        self.ladder_report = report
        for rung, ms in report.measured_ms.items():
            self.latency.calibrate(rung, ms)
        if validate:
            self.coalescer.rungs = report.rungs
        if log is not None:
            log(f"[serve-runtime:{self.name}] " + report.describe()
                + (f"\n  -> ladder {self.coalescer.rungs}"))

        # shape-set warmup: different sampled batches at one rung can land
        # on different pow2 block buckets, and a retrace mid-traffic is a
        # multi-hundred-ms latency spike — keep executing fresh batches per
        # surviving rung until the executor stops tracing new shapes (the
        # bucket set saturates after a handful of batches)
        ex = self.engine.block_executor
        for rnd in range(max(0, warm_rounds)):
            before = ex.trace_count
            for i, rung in enumerate(self.coalescer.rungs):
                mb = self._calibration_mb(
                    rung, batches_per_rung + rnd * len(self.coalescer.rungs)
                    + i)
                out = self.engine.forward_minibatch(
                    self.params, dataclasses.replace(mb, feats=None),
                    self.store)
                out.block_until_ready()
            if ex.trace_count == before:
                break
        if log is not None and ex.trace_count is not None:
            log(f"[serve-runtime:{self.name}] warm: "
                f"{ex.trace_count} compiled shape sets")
        self.mark_warm()

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> _Handle:
        """Admit ``req`` into the queue (stamping its arrival time).
        Returns a completion handle; rejections resolve it immediately."""
        handle = _Handle()
        req.t_arrive = self._now()
        if req.num_seeds > self.coalescer.max_rung:
            raise ValueError(
                f"request {req.rid}: {req.num_seeds} seeds exceed the top "
                f"ladder rung {self.coalescer.max_rung}")
        if not self._started:
            self.start()
        with self._lock:
            if self._closing:
                self._finish(req, handle, REJECTED_SHUTDOWN)
                return handle
            if len(self._pending) >= self.queue_limit:
                self._finish(req, handle, REJECTED_OVERLOAD)
                return handle
            self._handles[req.rid] = handle
            self._pending.append(req)
            self._inflight += 1
            self._observe_depth(len(self._pending))
            self._lock.notify_all()
        return handle

    def _observe_depth(self, depth: int) -> None:
        self._depth_seen.append(depth)
        m = obs.metrics()
        m.gauge("serve_queue_depth", model=self.name).set(depth)
        m.histogram("serve_queue_depth_hist", model=self.name).observe(depth)

    def _finish(self, req: Request, handle: Optional[_Handle],
                status: str, logits: Optional[np.ndarray] = None,
                rung: Optional[int] = None,
                t_admit: Optional[float] = None) -> Response:
        """Resolve one request to its terminal status (any thread)."""
        now = self._now()
        lat_ms = (now - req.t_arrive) * 1e3 if status in (OK, LATE) else 0.0
        q_ms = ((t_admit - req.t_arrive) * 1e3
                if t_admit is not None else 0.0)
        resp = Response(rid=req.rid, status=status, logits=logits,
                        latency_ms=lat_ms, queue_ms=q_ms, rung=rung,
                        model=self.name)
        m = obs.metrics()
        m.counter("serve_requests", model=self.name, status=status).inc()
        if status in (LATE, REJECTED_DEADLINE):
            m.counter("serve_deadline_miss", model=self.name).inc()
        if status in (OK, LATE):
            m.histogram("serve_request_ms", model=self.name).observe(lat_ms)
            m.histogram("serve_queue_ms", model=self.name).observe(q_ms)
            self._lat_ms.append(lat_ms)
            self._queue_ms.append(q_ms)
        with self._lock:
            self.responses.append(resp)
            h = self._handles.pop(req.rid, None)
            if h is not None:       # was registered (i.e. counted in-flight)
                self._inflight -= 1
            self._lock.notify_all()
        (h or handle)._complete(resp)
        return resp

    # ------------------------------------------------------------------
    # coalescer thread
    # ------------------------------------------------------------------
    def _coalesce_loop(self) -> None:
        while True:
            with self._lock:
                while not self._pending and not self._closing:
                    self._lock.wait(timeout=0.2)
                if self._closing and not self._pending:
                    break
                with obs.span("coalesce", pending=len(self._pending)):
                    decision = self.coalescer.plan(
                        self._pending, self._now(), drain=self._closing)
                if decision.batch is not None:
                    self._observe_depth(len(self._pending))
            for req in decision.rejects:
                self._finish(req, None, REJECTED_DEADLINE)
            if decision.batch is not None:
                self._enqueue_plan(decision.batch)
            elif decision.wait_s > 0 and not self._closing:
                time.sleep(min(decision.wait_s, 0.05))
        while True:              # end-of-stream for the loader producer
            try:
                self._plan_q.put(None, timeout=0.5)
                break
            except queue.Full:
                if not self._exec_thread.is_alive():
                    break        # close() force-stops the loader instead

    def _enqueue_plan(self, pb: PlannedBatch) -> None:
        m = obs.metrics()
        m.counter("serve_batches", model=self.name, rung=pb.rung).inc()
        real = sum(r.num_seeds for r in pb.requests)
        m.histogram("serve_batch_fill", model=self.name).observe(
            real / pb.rung)
        while True:
            try:
                self._plan_q.put(pb, timeout=0.5)
                return
            except queue.Full:
                if not self._exec_thread.is_alive():
                    # executor died: fail the batch's requests instead of
                    # spinning forever against a queue nobody drains
                    for req in pb.requests:
                        self._finish(req, None, REJECTED_SHUTDOWN)
                    return

    # ------------------------------------------------------------------
    # loader seed source (runs on the loader's producer thread)
    # ------------------------------------------------------------------
    def _planned_seeds(self, step: int):
        while True:
            try:
                pb = self._plan_q.get(timeout=0.2)
                break
            except queue.Empty:
                if self._stopping:
                    return None
        if pb is None:
            return None              # drain: loader ends its stream
        self._by_step[step] = pb
        return pb.seeds

    # ------------------------------------------------------------------
    # execute loop
    # ------------------------------------------------------------------
    def _exec_loop(self) -> None:
        try:
            for mb in self._loader:
                pb = self._by_step.pop(mb.step)
                t0 = self._now()
                with obs.span("execute_async", step=mb.step, rung=pb.rung):
                    logits = self.engine.forward_minibatch(
                        self.params, mb, self.store)
                    logits.block_until_ready()
                t1 = self._now()
                exec_ms = (t1 - t0) * 1e3
                # the promise admission makes is admit -> completion: feed
                # that (not just device time) back into the latency model
                self.latency.observe(pb.rung, (t1 - pb.t_admit) * 1e3)
                self._exec_ms.append(exec_ms)
                obs.metrics().histogram(
                    "serve_execute_ms", model=self.name).observe(exec_ms)
                self._batches += 1
                self._rung_counts[pb.rung] = \
                    self._rung_counts.get(pb.rung, 0) + 1
                real = sum(r.num_seeds for r in pb.requests)
                self._real_seeds += real
                self._padded_seeds += pb.rung
                rows = np.asarray(logits)
                for req, (lo, hi) in zip(pb.requests, pb.slices):
                    status = OK if t1 <= req.deadline() else LATE
                    self._finish(req, None, status, logits=rows[lo:hi],
                                 rung=pb.rung, t_admit=pb.t_admit)
        except BaseException as e:  # noqa: BLE001 - recorded, re-raised in close
            self._exec_failure = e
        finally:
            # resolve anything still mapped to a batch (loader died before
            # executing it)
            for pb in list(self._by_step.values()):
                for req in pb.requests:
                    if req.rid in self._handles:
                        self._finish(req, None, REJECTED_SHUTDOWN)
            self._by_step.clear()

    # ------------------------------------------------------------------
    # shutdown
    # ------------------------------------------------------------------
    def close(self, timeout: float = 30.0) -> None:
        """Graceful drain: stop accepting, serve or reject everything
        queued, join every worker thread. Idempotent; also the SIGINT
        path (``with runtime:`` calls it on any exception, Ctrl-C
        included)."""
        with self._close_lock:
            if self._closed.is_set():
                return
            with self._lock:
                self._closing = True
                self._lock.notify_all()
            if self._started:
                self._coalesce_thread.join(timeout=timeout)
                self._exec_thread.join(timeout=timeout)
            else:
                # never started: nothing consumes the plan queue; reject
                # whatever was queued so handles always resolve
                with self._lock:
                    pending, self._pending = self._pending, []
                for req in pending:
                    self._finish(req, None, REJECTED_SHUTDOWN)
            self._stopping = True
            self._loader.close()
            self._closed.set()
        if self._exec_failure is not None:
            raise self._exec_failure

    def drain(self, timeout: Optional[float] = 30.0) -> None:
        """Block until every submitted request reached a terminal state
        (without closing — the runtime keeps serving afterwards)."""
        deadline = None if timeout is None else self._now() + timeout
        with self._lock:
            while self._inflight > 0:
                rem = None if deadline is None else deadline - self._now()
                if rem is not None and rem <= 0:
                    raise TimeoutError(
                        f"{self._inflight} requests still in flight")
                if not self._exec_thread.is_alive() and self._started \
                        and self._exec_failure is not None:
                    raise self._exec_failure
                self._lock.wait(timeout=0.1 if rem is None
                                else min(rem, 0.1))

    @property
    def closed(self) -> bool:
        return self._closed.is_set()

    def worker_threads(self) -> List[threading.Thread]:
        """Every thread this runtime (incl. its loader) may own — the
        no-orphans-after-close contract is asserted over these."""
        ts = [self._coalesce_thread, self._exec_thread]
        lt = getattr(self._loader, "_thread", None)
        if lt is not None:
            ts.append(lt)
        return ts

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate serving report (exact local aggregates; the registry
        carries the same numbers labeled ``model=<name>`` when obs is
        on)."""
        by_status = {}
        for r in self.responses:
            by_status[r.status] = by_status.get(r.status, 0) + 1
        graded = sum(n for s, n in by_status.items()
                     if s != REJECTED_SHUTDOWN)
        lat = np.asarray(self._lat_ms) if self._lat_ms else np.zeros(1)
        ex = self.engine.block_executor
        out = {
            "model": self.name,
            "requests": len(self.responses),
            "by_status": by_status,
            "slo_attainment": (by_status.get(OK, 0) / graded
                               if graded else 1.0),
            "deadline_misses": (by_status.get(LATE, 0)
                                + by_status.get(REJECTED_DEADLINE, 0)),
            "latency_ms_p50": float(np.percentile(lat, 50)),
            "latency_ms_p99": float(np.percentile(lat, 99)),
            "latency_ms_mean": float(lat.mean()),
            "queue_ms_mean": (float(np.mean(self._queue_ms))
                              if self._queue_ms else 0.0),
            "execute_ms_mean": (float(np.mean(self._exec_ms))
                                if self._exec_ms else 0.0),
            "queue_depth_max": max(self._depth_seen, default=0),
            "batches": self._batches,
            "rung_counts": dict(sorted(self._rung_counts.items())),
            "batch_fill": (self._real_seeds / self._padded_seeds
                           if self._padded_seeds else 0.0),
            "ladder": list(self.coalescer.rungs),
            "ladder_ms": (dict(self.ladder_report.measured_ms)
                          if self.ladder_report is not None else {}),
            "executor_traces": ex.trace_count,
            "retraces_after_warmup": (
                ex.trace_count - self._warm_traces
                if self._warm_traces is not None else None),
            "shape_floor_growths": (self.shape_floors.growths
                                    if self.shape_floors is not None
                                    else None),
        }
        if obs.metrics_enabled():
            hs = obs.metrics().histogram_summary("serve_request_ms",
                                                 model=self.name)
            if hs and hs["count"]:
                out["latency_ms_p50"] = hs["p50"]
                out["latency_ms_p99"] = hs["p99"]
        return out
