"""Multi-model tenancy: several compiled models serving from one process.

Each tenant is one ``hector.compile()`` artifact wrapped in its own
``ServingRuntime`` (own admission queue, ladder, latency model, worker
threads); the process-level resources are shared:

* **one tuning cache** — tenants built with the same ``tune_cache`` path
  replay each other's measured per-operator decisions (the cache key
  includes the model/plan identity, so entries never collide);
* **one obs scope** — every tenant reports into the ambient registry,
  isolated by its ``model=<name>`` label, and spans land on each tenant's
  own worker-thread tracks;
* **one compiled-executor regime** — executors key compiled programs by
  plan identity + shapes, so interleaved traffic across tenants never
  cross-invalidates: model A's shape warmup survives model B's, and the
  steady state stays at zero retraces for *all* tenants.

``MultiTenantRuntime`` itself is thin routing: ``submit`` dispatches on
``Request.model`` (or the sole tenant), lifecycle calls fan out.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.serve.load import Request
from repro.serve.runtime import ServingRuntime


class MultiTenantRuntime:
    """Route requests to named ``ServingRuntime`` tenants.

    Build with ``add_tenant`` (which constructs the per-tenant runtime) or
    ``add`` (which registers one you built yourself); then ``calibrate()``
    every tenant's ladder before ``start()``. Context-manager use closes
    all tenants — every tenant's worker threads are joined.
    """

    def __init__(self):
        self._tenants: Dict[str, ServingRuntime] = {}
        self._started = False

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    def add(self, runtime: ServingRuntime) -> ServingRuntime:
        if runtime.name in self._tenants:
            raise ValueError(f"duplicate tenant {runtime.name!r}")
        self._tenants[runtime.name] = runtime
        return runtime

    def add_tenant(self, name: str, engine, params, store=None,
                   **runtime_kw) -> ServingRuntime:
        return self.add(ServingRuntime(engine, params, store,
                                       name=name, **runtime_kw))

    @property
    def tenants(self) -> Dict[str, ServingRuntime]:
        return dict(self._tenants)

    def __getitem__(self, name: str) -> ServingRuntime:
        return self._tenants[name]

    def __len__(self) -> int:
        return len(self._tenants)

    # ------------------------------------------------------------------
    # lifecycle (fans out)
    # ------------------------------------------------------------------
    def calibrate(self, **kw) -> None:
        for rt in self._tenants.values():
            rt.calibrate(**kw)

    def start(self) -> "MultiTenantRuntime":
        if not self._tenants:
            raise RuntimeError("no tenants registered")
        for rt in self._tenants.values():
            rt.start()
        self._started = True
        return self

    def __enter__(self) -> "MultiTenantRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def drain(self, timeout: Optional[float] = 30.0) -> None:
        for rt in self._tenants.values():
            rt.drain(timeout=timeout)

    def close(self, timeout: float = 30.0) -> None:
        first_failure = None
        for rt in self._tenants.values():
            try:
                rt.close(timeout=timeout)
            except BaseException as e:  # close every tenant regardless
                if first_failure is None:
                    first_failure = e
        if first_failure is not None:
            raise first_failure

    def worker_threads(self) -> List:
        return [t for rt in self._tenants.values()
                for t in rt.worker_threads()]

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Dispatch on ``req.model``; a single-tenant deployment may leave
        it unset."""
        if req.model is None:
            if len(self._tenants) != 1:
                raise ValueError(
                    f"request {req.rid} names no model and "
                    f"{len(self._tenants)} tenants are registered")
            rt = next(iter(self._tenants.values()))
        else:
            rt = self._tenants.get(req.model)
            if rt is None:
                raise KeyError(
                    f"request {req.rid}: unknown model {req.model!r} "
                    f"(tenants: {sorted(self._tenants)})")
        return rt.submit(req)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Per-tenant reports plus the cross-tenant isolation aggregate
        (``retraces_after_warmup`` summed over tenants — the zero-cross-
        model-retrace contract is one number)."""
        per = {name: rt.stats() for name, rt in self._tenants.items()}
        retr = [s["retraces_after_warmup"] for s in per.values()
                if s["retraces_after_warmup"] is not None]
        return {
            "tenants": per,
            "requests": sum(s["requests"] for s in per.values()),
            "retraces_after_warmup": sum(retr) if retr else None,
        }
