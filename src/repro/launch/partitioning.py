"""Sharding rules: params (TP + size-gated FSDP), optimizer state (ZeRO-1),
activations (logical names), batches and KV caches — per architecture and
per shape cell.

Strategy (DESIGN.md §5):
  * TP over "model" (16): attention heads / FFN hidden / vocab / SSM inner
    channels / MoE experts (EP when E % tp == 0, expert-internal TP
    otherwise). Archs whose head counts don't divide TP fall back per-tensor
    (e.g. Gemma H=8 -> shard head_dim; KV heads < tp -> replicate KV, the
    standard Megatron GQA duplication).
  * FSDP over "data" for any parameter above a size threshold (grok-1's
    expert stacks don't fit at TP-only sharding); ZeRO-1 = same rule with a
    ~1 MiB threshold applied to the f32 Adam moments.
  * Batch over ("pod","data") when divisible; the 500k-decode cell (B=1)
    shards the KV cache over sequence instead (context parallelism).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.lm.config import LMConfig, ShapeCell


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


@dataclasses.dataclass
class Partitioner:
    mesh: Mesh
    cfg: LMConfig
    mode: str = "train"                  # train | prefill | decode
    fsdp_threshold: int = 64 * 2**20     # bytes; params above this get FSDP
    zero_threshold: int = 1 * 2**20      # bytes; moments above this: ZeRO-1
    seq_shard_activations: bool = False  # sequence parallelism (perf v-E)
    # perf iteration flags (EXPERIMENTS.md §Perf). Defaults = tuned config;
    # pass False to reproduce the recorded baseline.
    attn_head_sharding_only: bool = True   # v-A: replicate attn when H % tp
    seq_shard_kv_decode: bool = False      # v-C: S-sharded cache + partial softmax
    moe_ep: bool = False                   # v-B: shard_map EP all-to-all MoE
    bf16_reduce: bool = False              # v-D: bf16 partial-sum collectives

    # ------------------------------------------------------------ axes
    @property
    def tp_axis(self) -> str:
        return "model"

    @property
    def fsdp_axis(self) -> str:
        return "data"

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp(self) -> int:
        return _prod(self.mesh.shape[a] for a in self.dp_axes)

    @property
    def data_size(self) -> int:
        return self.mesh.shape[self.fsdp_axis]

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------------ params
    def _base_param_spec(self, path: str, shape: Tuple[int, ...]) -> list:
        """TP assignment on the *unstacked* shape; returns a mutable list."""
        tp, ax = self.tp, self.tp_axis
        spec: list = [None] * len(shape)
        leaf = path.split("/")[-1]

        def try_axis(*cands):
            for c in cands:
                if shape[c] % tp == 0:
                    spec[c] = ax
                    return True
            return False

        if leaf in ("embed", "lm_head"):
            # vocab TP (padded to a multiple of 128)
            vocab_dim = 0 if leaf == "embed" else 1
            try_axis(vocab_dim)
        elif leaf == "frontend_proj":
            try_axis(1)
        elif leaf == "wq":
            if self.attn_head_sharding_only and self.mode != "decode":
                # v-A: H % tp != 0 -> REPLICATE attention, TP only the MLP.
                # hd-sharding wq against replicated KV was measured to emit a
                # [B,H,S,S] partial-score all-reduce per layer (27.7 TB/dev
                # on qwen3-14b prefill_32k — EXPERIMENTS §Perf).
                try_axis(1)
            else:
                try_axis(1, 2)                 # heads, else head_dim
        elif leaf in ("wk", "wv"):
            if self.mode == "decode" and not self.seq_shard_kv_decode:
                # decode: KV-cache memory dominates; shard KV heads, else
                # head_dim (partial-score all-reduce — a tracked §Perf item)
                try_axis(1, 2)
            else:
                # train/prefill (and v-C decode): KV heads if divisible,
                # else REPLICATE (Megatron GQA duplication)
                try_axis(1)
        elif leaf == "wo":
            if self.attn_head_sharding_only and self.mode != "decode":
                try_axis(0)
            else:
                try_axis(0, 1)
        elif leaf in ("w_gate", "w_up"):
            if len(shape) == 3:                # MoE [E, D, F]: EP else TP
                try_axis(0, 2)
            else:
                try_axis(1)
        elif leaf == "w_down":
            if len(shape) == 3:                # MoE [E, F, D]
                try_axis(0, 1)
            else:
                try_axis(0)
        elif leaf in ("wi_z", "wi_x", "wi_bc", "wi_dt"):
            try_axis(1)
        elif leaf in ("conv_w_x", "conv_w_bc"):
            try_axis(1)
        elif leaf in ("conv_b_x", "conv_b_bc", "gate_norm"):
            try_axis(0)
        elif leaf == "router":
            pass                                # replicate
        # norms / A_log / dt_bias / D_skip / scalars: replicate
        if leaf == "wo" and len(shape) == 2:    # mamba out proj [din, D]
            spec[:] = [None] * len(shape)
            try_axis(0)
        return spec

    def _apply_fsdp(self, spec: list, shape: Tuple[int, ...], nbytes: int,
                    threshold: int) -> list:
        if nbytes < threshold:
            return spec
        ds = self.data_size
        # largest unsharded dim divisible by the data axis
        cands = sorted(
            (i for i in range(len(shape))
             if spec[i] is None and shape[i] % ds == 0),
            key=lambda i: -shape[i])
        if cands:
            spec[cands[0]] = self.fsdp_axis
        return spec

    def param_spec(self, path: str, leaf) -> P:
        shape = tuple(leaf.shape)
        stacked = path.startswith("stages/") or "/stages/" in path
        inner = shape[1:] if stacked else shape
        spec = self._base_param_spec(path, inner)
        nbytes = _prod(shape) * jnp.dtype(leaf.dtype).itemsize
        spec = self._apply_fsdp(spec, inner, nbytes, self.fsdp_threshold)
        if stacked:
            spec = [None] + spec
        return P(*spec)

    def opt_spec(self, path: str, leaf) -> P:
        """ZeRO-1: moments follow params but with an aggressive FSDP gate."""
        shape = tuple(leaf.shape)
        stacked = path.startswith("stages/") or "/stages/" in path
        inner = shape[1:] if stacked else shape
        spec = self._base_param_spec(path, inner)
        nbytes = _prod(shape) * 4
        spec = self._apply_fsdp(spec, inner, nbytes, self.zero_threshold)
        if stacked:
            spec = [None] + spec
        return P(*spec)

    def _tree_specs(self, tree, fn) -> Any:
        def path_str(kp):
            parts = []
            for k in kp:
                if hasattr(k, "key"):
                    parts.append(str(k.key))
                elif hasattr(k, "idx"):
                    parts.append(str(k.idx))
                elif hasattr(k, "name"):
                    parts.append(str(k.name))
                else:
                    parts.append(str(k))
            return "/".join(parts)
        return jax.tree_util.tree_map_with_path(
            lambda kp, leaf: self.named(fn(path_str(kp), leaf)), tree)

    def param_shardings(self, params_tree) -> Any:
        return self._tree_specs(params_tree, self.param_spec)

    def state_shardings(self, state_tree) -> Any:
        """TrainState: params use param rules; mu/nu use ZeRO rules."""
        def fn(path, leaf):
            if path.startswith("mu/") or path.startswith("nu/"):
                return self.opt_spec(path.split("/", 1)[1], leaf)
            if path.startswith("params/"):
                return self.param_spec(path.split("/", 1)[1], leaf)
            return P()
        return self._tree_specs(state_tree, fn)

    # ------------------------------------------------------------ data
    def batch_dims(self, b: int) -> Optional[Tuple[str, ...]]:
        """Mesh axes to shard the batch dim over (None = replicate)."""
        if b % self.dp == 0:
            return self.dp_axes
        if b % self.data_size == 0:
            return (self.fsdp_axis,)
        return None

    def batch_spec(self, shape: Tuple[int, ...]) -> P:
        ba = self.batch_dims(shape[0])
        spec = [ba] + [None] * (len(shape) - 1)
        if ba is None and len(shape) >= 2 and shape[1] % self.data_size == 0:
            spec[1] = self.fsdp_axis       # sequence sharding fallback
        return P(*spec)

    def cache_spec(self, path: str, leaf) -> P:
        """KV / SSM cache sharding. Shapes carry a leading stage-repeat dim."""
        shape = tuple(leaf.shape)
        tp, ds = self.tp, self.data_size
        leaf_name = path.split("/")[-1]
        spec: list = [None] * len(shape)
        if leaf_name in ("k", "v"):
            _, b, s, kv, hd = shape
            ba = self.batch_dims(b)
            if ba is not None:
                spec[1] = ba
            elif s % ds == 0 and not self.seq_shard_kv_decode:
                spec[2] = self.fsdp_axis   # context parallelism (B too small)
            if self.seq_shard_kv_decode and self.mode == "decode" \
                    and s % tp == 0:
                # v-C: sequence-sharded cache; attention combines partial
                # softmax stats across the model axis (tiny psum). Decode
                # only — on prefill bundles this sharding was measured to
                # force large K/V-write reshards (§Perf).
                spec[2] = self.tp_axis
            elif kv % tp == 0:
                spec[3] = self.tp_axis
            elif hd % tp == 0:
                spec[4] = self.tp_axis
        elif leaf_name == "conv":
            _, b, k, c = shape
            ba = self.batch_dims(b)
            if ba is not None:
                spec[1] = ba
            if c % tp == 0:
                spec[3] = self.tp_axis
        elif leaf_name == "state":
            _, b, h, p_, n = shape
            ba = self.batch_dims(b)
            if ba is not None:
                spec[1] = ba
            if h % tp == 0:
                spec[2] = self.tp_axis
        return P(*spec)

    def cache_shardings(self, cache_tree) -> Any:
        return self._tree_specs(cache_tree, self.cache_spec)

    # ------------------------------------------------------------ logical
    def logical_resolver(self) -> "LogicalResolver":
        """Resolver installed via nn.common.sharding_context. It is callable
        (sharding constraints by logical name) and carries the mesh/axis
        metadata the shard_map code paths (EP-MoE, v-C decode) need."""
        return LogicalResolver(self)

    def _resolve_fn(self):
        mesh, tp, ax = self.mesh, self.tp, self.tp_axis
        ds, fa = self.data_size, self.fsdp_axis

        def resolve(name: str, x: jnp.ndarray) -> jnp.ndarray:
            shape = x.shape
            spec: list = [None] * len(shape)
            if name == "activation":            # [B, S, D]
                ba = self.batch_dims(shape[0])
                if ba is not None:
                    spec[0] = ba
                elif shape[1] % ds == 0:
                    spec[1] = fa
                if self.seq_shard_activations and spec[1] is None \
                        and shape[1] % tp == 0 and shape[1] > 1:
                    spec[1] = ax
            elif name == "kv":                  # [B, S, KV, hd]
                ba = self.batch_dims(shape[0])
                if ba is not None:
                    spec[0] = ba
                elif shape[1] % ds == 0:
                    spec[1] = fa
                if shape[2] % tp == 0:
                    spec[2] = ax
                elif shape[3] % tp == 0:
                    spec[3] = ax
            elif name == "ffn_hidden":          # [B, S, F]
                ba = self.batch_dims(shape[0])
                if ba is not None:
                    spec[0] = ba
                if shape[-1] % tp == 0:
                    spec[-1] = ax
            elif name == "attn_out_heads":      # [B, Q, H, hd]
                ba = self.batch_dims(shape[0])
                if ba is not None:
                    spec[0] = ba
                if shape[2] % tp == 0:
                    spec[2] = ax
                elif shape[3] % tp == 0:
                    spec[3] = ax
            elif name == "ssm_heads":           # [B, L, nh, hd]
                ba = self.batch_dims(shape[0])
                if ba is not None:
                    spec[0] = ba
                if shape[2] % tp == 0:
                    spec[2] = ax
            elif name == "moe_dispatch":        # [E, C, D]
                if shape[0] % tp == 0:
                    spec[0] = ax
                if shape[1] % self.dp == 0:
                    spec[1] = self.dp_axes
            elif name == "moe_hidden":          # [E, C, F]
                if shape[0] % tp == 0:
                    spec[0] = ax
                elif shape[2] % tp == 0:
                    spec[2] = ax
                if shape[1] % self.dp == 0:
                    spec[1] = self.dp_axes
            else:
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))

        return resolve


class LogicalResolver:
    """Callable sharding resolver + mesh metadata for shard_map paths."""

    def __init__(self, part: Partitioner):
        self._fn = part._resolve_fn()
        self.mesh = part.mesh
        self.tp_axis = part.tp_axis
        self.tp = part.tp
        self.dp_axes = part.dp_axes
        self.dp = part.dp
        self.batch_dims = part.batch_dims
        self.seq_shard_kv_decode = part.seq_shard_kv_decode
        self.moe_ep = part.moe_ep
        self.bf16_reduce = part.bf16_reduce

    def __call__(self, name, x):
        return self._fn(name, x)
