"""Neighbor-sampled RGNN training driver (RGCN / RGAT / HGT).

The training counterpart of ``serve_rgnn``: seed batches stream through the
epoch-aware shuffled ``EpochSeedStream`` (without replacement) into the
prefetching loader, and every mini-batch runs ONE compiled step — block
forward, per-seed cross-entropy, backward through the gather-fused
``custom_vjp`` kernels, AdamW update — via ``BlockTrainExecutor`` behind
the signature compile cache (zero retraces after the warmup epoch).
Periodic full-graph + sampled evaluation, async checkpointing with
mid-epoch resume, and an optional full-graph parity run (``--parity``)
mirroring the paper's sampled-vs-dense training comparison.

    PYTHONPATH=src python -m repro.launch.train_rgnn --reduced
    PYTHONPATH=src python -m repro.launch.train_rgnn --model hgt \
        --fanout 5,10 --batch-size 64 --epochs 5
    PYTHONPATH=src python -m repro.launch.train_rgnn --reduced --parity
"""
from __future__ import annotations

import argparse
import contextlib

import numpy as np
import jax
import jax.numpy as jnp

import hector
from repro import obs
from repro.core.graph import (CPU_REDUCED_SCALES, synthetic_heterograph,
                              table3_graph)
from repro.optim import AdamW, cosine_schedule
from repro.sampling import EpochSeedStream, SeedStream
from repro.train import (EngineConfig, MODEL_PROGRAMS, SampledTrainer,
                         parse_fanout)

# synthetic default workload (the example trainer's graph); --reduced scale
SYNTHETIC = dict(num_nodes=2000, num_edges=16000, num_ntypes=4,
                 num_etypes=16, target_compaction=0.5)
SYNTHETIC_REDUCED_SCALE = 0.2


def build_task(dataset: str, scale: float, cfg: EngineConfig, seed: int,
               val_frac: float = 0.2):
    """Graph + engine + a *learnable* node-classification task: labels come
    from a frozen randomly-initialized teacher forward of the same
    architecture, so both trainers can actually fit the data (random labels
    would only measure memorization)."""
    if dataset == "synthetic":
        graph = synthetic_heterograph(
            num_nodes=max(64, int(SYNTHETIC["num_nodes"] * scale)),
            num_edges=max(256, int(SYNTHETIC["num_edges"] * scale)),
            num_ntypes=SYNTHETIC["num_ntypes"],
            num_etypes=SYNTHETIC["num_etypes"], seed=seed,
            target_compaction=SYNTHETIC["target_compaction"])
    else:
        graph = table3_graph(dataset, scale=scale, seed=seed)
    rng = np.random.default_rng(seed)
    # host-side table: the chosen feature store decides what (if anything)
    # becomes device-resident
    feats = rng.normal(size=(graph.num_nodes, cfg.dim)).astype(np.float32)
    # the unified front door (frontend/compile.py) builds program -> plans
    # -> compiled stack -> sampler (+ tuner) from the prebuilt config
    engine = hector.compile(None, graph, config=cfg)
    teacher = engine.init(jax.random.key(seed + 1))
    labels = np.asarray(jnp.argmax(
        engine.forward_full(teacher, jnp.asarray(feats)), -1))
    perm = rng.permutation(graph.num_nodes)
    n_val = int(graph.num_nodes * val_frac)
    val_ids = np.sort(perm[:n_val]).astype(np.int32)
    train_ids = np.sort(perm[n_val:]).astype(np.int32)
    return engine, feats, labels, train_ids, val_ids


def train(
    model: str = "rgat",
    dataset: str = "synthetic",
    scale: float = 1.0,
    layers: int = 2,
    dim: int = 64,
    hidden: int = 64,
    classes: int = 8,
    fanouts=None,
    batch_size: int = 64,
    epochs: int = 3,
    lr: float = 1e-2,
    weight_decay: float = 0.0,
    warmup_steps: int = 5,
    backend: str = "xla",
    tile: int = 32,
    node_block: int = 32,
    bucket: bool = True,
    seed: int = 0,
    sampler: str = "host",
    dp: int = 1,
    partitions=None,
    feature_store: str = "device",
    feature_budget=None,
    skew=None,
    val_frac: float = 0.2,
    ckpt_dir=None,
    ckpt_every: int = 0,
    resume: bool = False,
    eval_every_epochs: int = 0,
    parity: bool = False,
    parity_tol: float = 0.05,
    tune: str = "off",
    tune_cache=None,
    obs_mode: str = "on",
    trace_out=None,
    metrics_out=None,
    profile: bool = False,
    log=print,
):
    """Run the sampled training loop; returns a stats dict (used by tests
    and the ``train_sampled`` benchmark).

    Observability mirrors ``serve_rgnn``: with ``obs_mode="on"`` the run is
    wrapped in an ``obs.scope`` (per-step latency histograms, cache/trace
    counters, ``stats["metrics"]`` snapshot, optional ``metrics_out``
    export); ``trace_out`` additionally enables phase tracing
    (``sample``/``layout``/``train_step`` spans) and writes a Chrome-trace
    JSON. ``profile=True`` attributes one fused compiled SGD step into
    forward / backward / optimizer via ``obs.profile.profile_train_step``
    (host spans cannot split a single jitted callable).
    """
    with contextlib.ExitStack() as stack:
        sc = None
        if obs_mode == "off":
            stack.enter_context(obs.disabled())
        else:
            sc = stack.enter_context(obs.scope(
                metrics=True, tracing=trace_out is not None))
        return _train_scoped(
            sc, model, dataset, scale, layers, dim, hidden, classes,
            fanouts, batch_size, epochs, lr, weight_decay, warmup_steps,
            backend, tile, node_block, bucket, seed, sampler, dp,
            partitions, feature_store, feature_budget, skew, val_frac,
            ckpt_dir, ckpt_every, resume,
            eval_every_epochs, parity, parity_tol, tune, tune_cache,
            trace_out, metrics_out, profile, log)


def _train_scoped(
    sc, model, dataset, scale, layers, dim, hidden, classes, fanouts,
    batch_size, epochs, lr, weight_decay, warmup_steps, backend, tile,
    node_block, bucket, seed, sampler, dp, partitions, feature_store,
    feature_budget, skew, val_frac, ckpt_dir,
    ckpt_every, resume, eval_every_epochs, parity, parity_tol, tune,
    tune_cache, trace_out, metrics_out, profile, log,
):
    cfg = EngineConfig(model=model, layers=layers, dim=dim, hidden=hidden,
                       classes=classes, fanouts=fanouts, backend=backend,
                       tile=tile, node_block=node_block, bucket=bucket,
                       seed=seed, sampler=sampler, dp=dp,
                       partitions=partitions, feature_store=feature_store,
                       feature_budget=feature_budget, tune=tune,
                       tune_cache=tune_cache)
    engine, feats, labels, train_ids, val_ids = build_task(
        dataset, scale, cfg, seed, val_frac)
    log(f"[train_rgnn] {model} on {dataset} (scale {scale}): "
        f"{engine.graph.num_nodes} nodes, {engine.graph.num_edges} edges, "
        f"{engine.graph.num_etypes} etypes; fanouts={cfg.fanouts}, "
        f"sampler={sampler}, feature_store={feature_store}"
        + (f" skew={skew}" if skew else "")
        + f", {len(train_ids)} train / {len(val_ids)} val nodes")

    # size the LR schedule off the same stream the trainer will iterate
    # (trainer.train rebuilds it from (ids, batch_size, skew), all passed
    # verbatim below; the stream seed never affects sizing)
    if skew is not None:
        bpe = max(1, len(train_ids) // batch_size)
    else:
        bpe = EpochSeedStream(train_ids, batch_size).batches_per_epoch
    total_steps = epochs * bpe
    opt = AdamW(learning_rate=cosine_schedule(lr, warmup_steps, total_steps),
                weight_decay=weight_decay)

    # the feature store; for the cached tier the per-ntype slot split is a
    # measured decision probed on the same traffic the trainer will iterate
    probe = (SeedStream(ids=train_ids, batch_size=batch_size, seed=seed,
                        zipf_alpha=skew) if skew is not None
             else EpochSeedStream(train_ids, batch_size, seed=seed))
    store = engine.make_feature_store(feats, seed_source=probe)
    if feature_store == "cached":
        log(f"[train_rgnn] feature cache: {store.capacity} device rows "
            f"({store.device_bytes() / 1e6:.2f} MB vs full table "
            f"{store.table_bytes / 1e6:.2f} MB), per-ntype slots "
            f"{store.slot_ptr.tolist()}")

    if cfg.distributed:
        return _train_dist(engine, store, labels, train_ids, val_ids, opt,
                           epochs, batch_size, bpe, seed, parity, profile,
                           ckpt_dir, resume, sc, metrics_out, log)

    trainer = SampledTrainer(engine, store, labels, train_ids, val_ids,
                             opt=opt, ckpt_dir=ckpt_dir, log=log)
    state = trainer.init_state(engine.init(jax.random.key(seed)))

    if tune != "off":
        # block-scale tuning on one representative training batch (bucketed
        # shapes make the decisions valid for the whole epoch stream)
        warm_seeds = np.sort(np.random.default_rng(seed + 1).choice(
            train_ids, size=min(batch_size, len(train_ids)),
            replace=False)).astype(np.int32)
        tl = engine.make_loader(lambda step: warm_seeds, num_batches=1,
                                depth=1)
        try:
            engine.tune_minibatch(state.params, next(tl), jnp.asarray(feats))
        finally:
            tl.close()
        ts = engine.tuner_stats
        log(f"[train_rgnn] tune={tune}: {ts.get('measurements', 0)} "
            f"measurements, {ts.get('cache_hits', 0)} cache replays "
            f"(tile {engine.tile}, node_block {engine.node_block})")

    start_step = 0
    if resume:
        state, start_step = trainer.resume(state)
        if start_step:
            log(f"[train_rgnn] resumed from step {start_step} "
                f"(epoch {start_step // bpe}, batch {start_step % bpe})")

    state, stats = trainer.train(
        state, epochs=epochs, batch_size=batch_size, start_step=start_step,
        ckpt_every=ckpt_every, eval_every_epochs=eval_every_epochs,
        log_every=max(1, bpe // 2), skew=skew)

    for k, v in engine.tuner_stats.items():
        stats[f"tune_{k}"] = v
    dev_sampler = getattr(engine, "device_sampler", None)
    if dev_sampler is not None:
        for k, v in dev_sampler.stats().items():
            stats[f"sampler_{k}"] = v
        log(f"[train_rgnn] device sampler: "
            f"{dev_sampler.trace_count} traces / "
            f"{dev_sampler.cache_hits} program-cache hits over "
            f"{dev_sampler.batches_sampled} batches")
    final_train = trainer.full.evaluate(state.params)
    final_val = (trainer.full.evaluate(state.params, val_ids)
                 if len(val_ids) else None)
    stats["full_train_loss"] = final_train["loss"]
    stats["full_train_acc"] = final_train["accuracy"]
    if final_val is not None:
        stats["full_val_loss"] = final_val["loss"]
        stats["full_val_acc"] = final_val["accuracy"]
    log(f"[train_rgnn] sampled training done: {stats['steps']} steps, "
        f"step p50 {stats['step_ms_p50']:.1f} ms, "
        f"{stats['seeds_per_s']:.1f} seeds/s, "
        f"{stats['retraces_after_warmup']} retraces after warmup "
        f"({stats['executor_compiled']} compiled buckets)")
    log(f"[train_rgnn] full-graph eval: train loss {final_train['loss']:.4f} "
        f"acc {final_train['accuracy']:.2%}"
        + (f" | val loss {final_val['loss']:.4f} "
           f"acc {final_val['accuracy']:.2%}" if final_val else ""))

    if parity:
        # dense baseline: same init, same optimizer-step budget; parity is
        # judged on *held-out* loss (mini-batch SGD trades per-step training
        # loss for more updates, so train-loss comparison at equal step
        # count is dominated by that trade — generalization is the
        # apples-to-apples metric). With no val split, falls back to train.
        fg = trainer.full   # identical config: reuse its compiled step
        fstate = fg.init_state(engine.init_params(jax.random.key(seed)))
        fstate, _ = fg.train(fstate, steps=total_steps,
                             log_every=max(1, total_steps // 4))
        if len(val_ids):
            split, sampled_loss = "val", final_val["loss"]
            fg_loss = fg.evaluate(fstate.params, val_ids)["loss"]
        else:
            split, sampled_loss = "train", final_train["loss"]
            fg_loss = fg.evaluate(fstate.params)["loss"]
        gap = (sampled_loss - fg_loss) / max(fg_loss, 1e-6)
        stats["parity_full_graph_loss"] = fg_loss
        stats["parity_gap"] = gap
        ok = gap <= parity_tol
        log(f"[train_rgnn] parity ({split} loss): sampled "
            f"{sampled_loss:.4f} vs full-graph {fg_loss:.4f} "
            f"(gap {gap:+.1%}, tol {parity_tol:.0%}) -> "
            f"{'OK' if ok else 'FAIL'}")
        if not ok:
            raise SystemExit(
                f"sampled {split} loss {sampled_loss:.4f} not within "
                f"{parity_tol:.0%} of full-graph {fg_loss:.4f}")

    if profile:
        # forward/backward/optimizer attribution of ONE fused compiled
        # step, on a representative (bucketed) batch off the epoch stream
        from repro.obs import profile as prof_mod
        warm_seeds = np.sort(np.random.default_rng(seed + 2).choice(
            train_ids, size=min(batch_size, len(train_ids)),
            replace=False)).astype(np.int32)
        pl = engine.make_loader(lambda step: warm_seeds, num_batches=1,
                                depth=1)
        try:
            mb = next(pl)
        finally:
            pl.close()
        ph = prof_mod.profile_train_step(
            engine.plans, trainer.opt, state, mb,
            mb.seq.slice_labels(labels),
            {"feature": jnp.asarray(feats)[mb.input_ids]},
            backend=engine.cfg.backend, activation=engine.cfg.activation,
            decisions=engine.decisions, warmup=1, iters=5)
        log(f"[train_rgnn] step attribution: "
            f"forward {ph['forward']*1e3:.2f} ms, "
            f"backward {ph['backward']*1e3:.2f} ms, "
            f"optimizer {ph['optimizer']*1e3:.2f} ms "
            f"(fused step {ph['total']*1e3:.2f} ms)")
        stats["profile"] = {k: v * 1e3 for k, v in ph.items()}

    if sc is not None:
        if sc.tracer is not None:
            log("[train_rgnn] phase table:\n" + sc.tracer.phase_table())
            if trace_out:
                sc.tracer.write(trace_out)
                log(f"[train_rgnn] chrome trace -> {trace_out}")
        stats["metrics"] = sc.registry.snapshot()
        if metrics_out:
            sc.registry.export(metrics_out)
            log(f"[train_rgnn] metrics snapshot -> {metrics_out}")
    return stats


def _train_dist(engine, feats, labels, train_ids, val_ids, opt, epochs,
                batch_size, bpe, seed, parity, profile, ckpt_dir, resume,
                sc, metrics_out, log):
    """Data-parallel training loop (``--dp`` / ``--partitions``): sharded
    sampling + one compiled shard_map step per batch, no per-step host
    sync; final evaluation runs the usual full-graph compiled step."""
    if parity or profile or ckpt_dir or resume:
        raise ValueError("--parity/--profile/--ckpt-dir/--resume are not "
                         "supported together with --dp/--partitions")
    from repro.dist import DistTrainer
    from repro.train import FullGraphTrainer
    cfg = engine.cfg
    log(f"[train_rgnn] distributed: {cfg.num_partitions} shards over "
        f"{cfg.dp} devices\n" + engine.partition.describe())
    trainer = DistTrainer(engine, feats, labels, train_ids, val_ids,
                          opt=opt, log=log)
    state = trainer.init_state(engine.init(jax.random.key(seed)))
    state, stats = trainer.train(state, epochs=epochs,
                                 batch_size=batch_size,
                                 log_every=max(1, bpe // 2))

    full = FullGraphTrainer(engine, feats, labels, train_ids, opt=opt,
                            log=log)
    final_train = full.evaluate(state.params)
    final_val = (full.evaluate(state.params, val_ids)
                 if len(val_ids) else None)
    stats["full_train_loss"] = final_train["loss"]
    stats["full_train_acc"] = final_train["accuracy"]
    if final_val is not None:
        stats["full_val_loss"] = final_val["loss"]
        stats["full_val_acc"] = final_val["accuracy"]
    log(f"[train_rgnn] dist training done: {stats['steps']} steps on "
        f"{cfg.num_partitions} shards / {cfg.dp} devices, "
        f"step p50 {stats['step_ms_p50']:.1f} ms, "
        f"{stats['seeds_per_s']:.1f} seeds/s, "
        f"{stats['retraces_after_warmup']} retraces after warmup "
        f"({stats['executor_compiled']} compiled buckets)")
    log(f"[train_rgnn] full-graph eval: train loss {final_train['loss']:.4f} "
        f"acc {final_train['accuracy']:.2%}"
        + (f" | val loss {final_val['loss']:.4f} "
           f"acc {final_val['accuracy']:.2%}" if final_val else ""))
    from repro.feats import is_feature_store
    if is_feature_store(feats):
        for k, v in feats.stats().items():
            stats[f"feature_{k}"] = v
    if sc is not None:
        stats["metrics"] = sc.registry.snapshot()
        if metrics_out:
            sc.registry.export(metrics_out)
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="rgat", choices=sorted(MODEL_PROGRAMS))
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic"] + sorted(CPU_REDUCED_SCALES))
    ap.add_argument("--reduced", action="store_true",
                    help="scale the dataset for CPU tractability")
    ap.add_argument("--scale", type=float, default=None,
                    help="explicit dataset scale factor (overrides --reduced)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--fanout", default="5",
                    help="per-hop fanout, e.g. '5' or '5,10'; -1 = full")
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--node-block", type=int, default=32)
    ap.add_argument("--no-bucket", action="store_true")
    ap.add_argument("--sampler", default="host", choices=["host", "device"],
                    help="'host': NumPy fanout sampling + host layout "
                         "build; 'device': jit-compiled sampling + layout "
                         "over a device-resident CSC")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel device count: shard the graph and "
                         "run each SGD step across all shards under one "
                         "compiled shard_map step (all-reduce inside)")
    ap.add_argument("--partitions", type=int, default=None,
                    help="graph shard count (default: one per --dp device; "
                         "a multiple of --dp folds extra shards onto "
                         "devices with bit-identical results)")
    ap.add_argument("--feature-store", default="device",
                    choices=["device", "host", "cached"],
                    help="where the node-feature table lives: 'device' = "
                         "full table device-resident (baseline); 'host' = "
                         "host tables, per-batch input rows gathered inside "
                         "the prefetch overlap; 'cached' = host tables "
                         "fronted by a fixed-budget device hot-row cache")
    ap.add_argument("--feature-budget", type=int, default=None,
                    help="device hot-row count for --feature-store cached "
                         "(default: num_nodes // 4), split per ntype by "
                         "measured traffic")
    ap.add_argument("--skew", type=float, default=None, metavar="ALPHA",
                    help="Zipf-skew the seed stream (rank probability "
                         "(r+1)^-ALPHA, with replacement) — the power-law "
                         "traffic model for feature-cache studies")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--val-frac", type=float, default=0.2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N steps (0 disables)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir")
    ap.add_argument("--eval-every-epochs", type=int, default=1)
    ap.add_argument("--parity", action="store_true",
                    help="also run the full-graph trainer with the same "
                         "step budget and assert the sampled loss is within "
                         "--parity-tol of it")
    ap.add_argument("--parity-tol", type=float, default=0.05)
    ap.add_argument("--tune", default="off",
                    choices=["off", "cached", "full"],
                    help="autotune operator variants: 'cached' replays the "
                         "persistent cache with zero measurements, 'full' "
                         "measures missing entries on-device")
    ap.add_argument("--tune-cache", default=None,
                    help="persistent tuning-cache path (default "
                         "$REPRO_TUNE_CACHE or ~/.cache/repro-tune.json)")
    ap.add_argument("--obs", default="on", choices=["on", "off"],
                    help="observability: 'on' runs inside an obs scope "
                         "(metrics registry + stats['metrics']); 'off' is "
                         "the zero-instrumentation baseline")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable phase tracing and write a Chrome-trace "
                         "JSON (load in chrome://tracing or Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot JSON here")
    ap.add_argument("--profile", action="store_true",
                    help="attribute one fused compiled SGD step into "
                         "forward / backward / optimizer phases")
    args = ap.parse_args(argv)

    if args.scale is not None:
        scale = args.scale
    elif args.reduced:
        scale = (SYNTHETIC_REDUCED_SCALE if args.dataset == "synthetic"
                 else CPU_REDUCED_SCALES[args.dataset])
    else:
        scale = 1.0
    return train(
        model=args.model, dataset=args.dataset, scale=scale,
        layers=args.layers, dim=args.dim, hidden=args.hidden,
        classes=args.classes,
        fanouts=parse_fanout(args.fanout, args.layers),
        batch_size=args.batch_size, epochs=args.epochs, lr=args.lr,
        weight_decay=args.weight_decay, backend=args.backend,
        tile=args.tile, node_block=args.node_block,
        bucket=not args.no_bucket, seed=args.seed, sampler=args.sampler,
        dp=args.dp, partitions=args.partitions,
        feature_store=args.feature_store,
        feature_budget=args.feature_budget, skew=args.skew,
        val_frac=args.val_frac,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, eval_every_epochs=args.eval_every_epochs,
        parity=args.parity, parity_tol=args.parity_tol,
        tune=args.tune, tune_cache=args.tune_cache,
        obs_mode=args.obs, trace_out=args.trace_out,
        metrics_out=args.metrics_out, profile=args.profile,
    )


if __name__ == "__main__":
    main()
