"""Roofline analysis from compiled dry-run artifacts (deliverable g).

XLA's ``cost_analysis`` visits a ``while`` body ONCE (verified empirically:
scan flops are independent of trip count), so naive totals undercount
scanned-layer models by ~L×. This module therefore parses the
post-optimization HLO text itself:

  * builds the computation table (op name -> result shape/bytes),
  * finds ``while`` ops, extracts trip counts from their condition
    computations (max integer constant in the compare),
  * propagates loop multipliers down the call graph (nested scans multiply),
  * tallies per-device dot FLOPs (2 x prod(result) x contraction),
    HBM traffic proxy (operand reads + result writes of top-level ops), and
    collective wire bytes with per-primitive factors.

Terms (prompt formulas, TPU v5e):
  compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective = collective_bytes / (chips x 50e9 B/s per ICI link)
HLO quantities here are per-device (post-SPMD module), so the per-chip
division is already done; multiply back by ``chips`` where totals are shown.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass
class HW:
    name: str
    peak_flops: float       # per chip
    hbm_bw: float           # B/s per chip
    ici_bw: float           # B/s per link
    hbm_bytes: float        # capacity per chip


HW_V5E = HW(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
            hbm_bytes=16 * 2**30)


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_KIND_RE = re.compile(r"\s([a-z][\w\-]*)\(")


def _parse_op_line(line: str):
    """Parse '%name = TYPE kind(...)' where TYPE may be a tuple with spaces."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):                     # tuple type: scan to match
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, tail = rest[: i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp:]
    km = _KIND_RE.search(" " + tail)
    if not km:
        return None
    return name, type_str, km.group(1), line


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    line: str


@dataclasses.dataclass
class HloModule:
    computations: Dict[str, List[Op]]
    entry: str
    op_types: Dict[str, str]            # op name -> type str


def parse_hlo(text: str) -> HloModule:
    computations: Dict[str, List[Op]] = {}
    op_types: Dict[str, str] = {}
    entry = None
    current = None
    for line in text.splitlines():
        # computation headers start at column 0 and end with '{'
        if (not line.startswith(" ") and line.rstrip().endswith("{")
                and not line.startswith("HloModule")):
            m = _COMP_RE.match(line)
            if m:
                current = m.group(1)
                computations[current] = []
                if line.lstrip().startswith("ENTRY"):
                    entry = current
                continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, type_str, kind, _ = parsed
            op = Op(name=name, type_str=type_str, kind=kind, line=line)
            computations[current].append(op)
            op_types[name] = type_str
    if entry is None and computations:
        entry = next(iter(computations))
    return HloModule(computations=computations, entry=entry, op_types=op_types)


def _trip_count(mod: HloModule, cond_name: str) -> int:
    """Max integer constant in the loop condition (counted-loop heuristic);
    follows fusion calls inside the condition (XLA often fuses the compare)."""
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        comp = stack.pop()
        if comp in seen:
            continue
        seen.add(comp)
        for op in mod.computations.get(comp, []):
            if op.kind == "constant":
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    best = max(best, int(m.group(1)))
            mm = re.search(r"calls=%?([\w.\-]+)", op.line)
            if mm and mm.group(1) in mod.computations:
                stack.append(mm.group(1))
    return best


_CALL_ATTRS = re.compile(
    r"(?:body|to_apply|branch_computations|called_computations|calls)="
    r"\{?%?([\w.\-]+)(?:,\s*%?([\w.\-]+))*\}?")


def _multipliers(mod: HloModule) -> Dict[str, int]:
    """Execution multiplier per computation (product of enclosing trips)."""
    mult: Dict[str, int] = {mod.entry: 1}
    # BFS from entry following while/call/conditional edges
    frontier = [mod.entry]
    visited = set()
    while frontier:
        comp = frontier.pop()
        if comp in visited:
            continue
        visited.add(comp)
        base = mult.get(comp, 1)
        for op in mod.computations.get(comp, []):
            if op.kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                # XLA records counted-loop trip counts in backend_config
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
                if mb:
                    if mt:
                        trips = int(mt.group(1))
                    else:
                        trips = _trip_count(mod, mc.group(1)) if mc else 1
                    body = mb.group(1)
                    mult[body] = max(mult.get(body, 0), base * trips)
                    frontier.append(body)
            elif op.kind in ("call", "fusion", "custom-call", "conditional",
                             "map", "reduce", "sort", "scatter",
                             "select-and-scatter", "reduce-window"):
                for mm in re.finditer(
                        r"(?:to_apply|calls|branch_computations)="
                        r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?", op.line):
                    for name in re.split(r",\s*%?", mm.group(1)):
                        name = name.lstrip("%")
                        if name in mod.computations:
                            mult[name] = max(mult.get(name, 0), base)
                            frontier.append(name)
    return mult


# ---------------------------------------------------------------------------
# tallies
# ---------------------------------------------------------------------------
def _dot_flops(mod: HloModule, op: Op) -> float:
    out_dims = _shape_dims(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contraction = 1
    lhs_dims = None
    # newer XLA prints operand types inline: dot(f32[64,128]{1,0} %lhs, ...)
    minline = re.search(r"dot\(([a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?)\s+%", op.line)
    if minline:
        lhs_dims = _shape_dims(minline.group(1))
    else:  # older format: dot(%lhs, %rhs)
        mlhs = re.search(r"dot\(%?([\w.\-]+)", op.line)
        if mlhs and mlhs.group(1) in mod.op_types:
            lhs_dims = _shape_dims(mod.op_types[mlhs.group(1)])
    if lhs_dims is not None and mcd:
        for idx in mcd.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contraction *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contraction


def _conv_flops(mod: HloModule, op: Op) -> float:
    # rough: 2 * out_elems * (kernel elems per output)
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    mrhs = re.findall(r"%([\w.\-]+)", op.line)
    if len(mrhs) >= 2 and mrhs[1] in mod.op_types:
        k = 1
        for d in _shape_dims(mod.op_types[mrhs[1]]):
            k *= d
        out_dims = _shape_dims(op.type_str)
        if out_dims:
            k = k // max(1, out_dims[-1])
        return 2.0 * out_elems * max(1, k)
    return 2.0 * out_elems


def _collective_wire_bytes(op: Op) -> float:
    """Per-device wire bytes for a collective (standard ring formulas with
    group size folded into the (n-1)/n ~= 1 approximation)."""
    b = _shape_bytes(op.type_str)
    if op.kind.startswith("all-reduce"):
        return 2.0 * b                   # reduce-scatter + all-gather phases
    if op.kind.startswith("all-gather"):
        return 1.0 * b                   # receives the gathered result
    if op.kind.startswith("reduce-scatter"):
        # result is the scattered shard; wire ~ full input = shard * n
        m = re.search(r"replica_groups=\{?\{([0-9,]+)\}", op.line)
        n = len(m.group(1).split(",")) if m else 8
        return float(b) * n
    if op.kind.startswith("all-to-all"):
        return 1.0 * b
    if op.kind.startswith("collective-permute"):
        return 1.0 * b
    return float(b)


_MEM_SKIP = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "after-all", "partition-id",
             "replica-id")


def _fused_comps(mod: HloModule) -> set:
    """Computations called via fusion/wrapped ops (their internals are not
    separate HBM materializations — the fusion call site accounts for IO)."""
    fused = set()
    for ops in mod.computations.values():
        for op in ops:
            if op.kind in ("fusion", "reduce", "sort", "scatter", "map",
                           "reduce-window", "select-and-scatter"):
                for mm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                      op.line):
                    fused.add(mm.group(1))
    return fused


def analyze_hlo(text: str) -> Dict[str, float]:
    """Per-device totals with loop multipliers applied.

    FLOPs: every dot/conv anywhere (×loop multiplier). Memory proxy: for
    top-level ops only (entry + loop bodies; fused internals excluded),
    result write bytes + operand read bytes — an upper-ish estimate of HBM
    traffic assuming each listed op materializes (TPU fuses more than the
    CPU HLO shows, so relative deltas matter more than absolutes)."""
    mod = parse_hlo(text)
    mult = _multipliers(mod)
    fused = _fused_comps(mod)
    flops = 0.0
    coll_bytes = 0.0
    mem_bytes = 0.0
    coll_by_kind: Dict[str, float] = {}
    for comp, ops in mod.computations.items():
        m = mult.get(comp, 1)
        in_fused = comp in fused
        for op in ops:
            if op.kind == "dot":
                flops += m * _dot_flops(mod, op)
            elif op.kind == "convolution":
                flops += m * _conv_flops(mod, op)
            if op.kind.startswith(_COLLECTIVES) and not op.kind.endswith("-done"):
                wb = m * _collective_wire_bytes(op)
                coll_bytes += wb
                key = op.kind.split("-start")[0]
                coll_by_kind[key] = coll_by_kind.get(key, 0.0) + wb
            if in_fused or op.kind in _MEM_SKIP:
                continue
            ob = _shape_bytes(op.type_str)
            reads = 0
            for ref in re.finditer(r"%([\w.\-]+)", op.line.split("metadata=")[0]):
                t = mod.op_types.get(ref.group(1))
                if t is not None and ref.group(1) != op.name:
                    reads += _shape_bytes(t)
            mem_bytes += m * (ob + reads)
    out = {
        "flops": flops,
        "collective_bytes": coll_bytes,
        "mem_bytes_proxy": mem_bytes,
    }
    for k, v in coll_by_kind.items():
        out[f"coll_{k}"] = v
    return out


# ---------------------------------------------------------------------------
# public API used by dryrun.py
# ---------------------------------------------------------------------------
def summarize_cost(cost) -> Dict[str, float]:
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out = {}
    for k, v in (cost or {}).items():
        if isinstance(v, (int, float)):
            out[str(k)] = float(v)
    return out


def collective_bytes_from_hlo(text: str) -> Dict[str, float]:
    return analyze_hlo(text)


def roofline_terms(cost: Dict[str, float], hlo: Dict[str, float],
                   chips: int, hw: HW) -> Dict[str, float]:
    """Three roofline terms in seconds (per-step), from per-device tallies."""
    flops = hlo.get("flops", 0.0)
    mem = hlo.get("mem_bytes_proxy", 0.0)
    coll = hlo.get("collective_bytes", 0.0)
    t_compute = flops / hw.peak_flops
    t_memory = mem / hw.hbm_bw
    t_collective = coll / hw.ici_bw
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "device_flops": flops,
        "device_mem_bytes": mem,
        "device_collective_bytes": coll,
        "total_flops": flops * chips,
    }


def model_flops(cfg, cell) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N_active·B decode."""
    n_active = cfg.active_param_count()
    if cell.mode == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.mode == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    return 2.0 * n_active * cell.global_batch          # one token per request


def analytic_memory_bytes(cfg, cell, chips: int) -> float:
    """Model-based per-device HBM traffic per step — the calibrated
    counterpart of the HLO proxy (which over-counts CPU-HLO copies/converts
    that TPU fusion would eliminate).

    train:   params read twice (fwd + remat-bwd) + grad write + Adam moment
             read/write (f32 m,v) + activation checkpoint IO
    prefill: params read once + activation IO + cache write
    decode:  active params read once + full KV/state cache read + write
    """
    n = cfg.param_count()
    n_act = cfg.active_param_count()
    d, l = cfg.d_model, cfg.num_layers
    b, s = cell.global_batch, cell.seq_len
    tokens = b * s
    if cell.mode == "train":
        weight_io = n * (2 * 2 + 2 + 4 * 4)       # bf16 r(fwd)+r(bwd)+w(grad), f32 m/v r+w
        act_io = tokens * d * 2 * 2 * (l + 4)     # one checkpoint r+w per layer
        return (weight_io + act_io) / chips
    if cell.mode == "prefill":
        weight_io = n_act * 2
        act_io = tokens * d * 2 * 8 * l           # ~8 materialized tensors/layer
        cache_w = _cache_bytes(cfg, cell)
        return (weight_io * max(1, tokens // 8192) + act_io + cache_w) / chips
    # decode: cache read dominates
    weight_io = n_act * 2
    cache_rw = _cache_bytes(cfg, cell) * 1.0
    return (weight_io + cache_rw) / chips


def _cache_bytes(cfg, cell) -> float:
    b, s = cell.global_batch, cell.seq_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    total = 0.0
    for st in cfg.stages:
        for spec in st.pattern:
            if spec.kind == "self_attn":
                total += st.repeats * 2 * b * s * kv * hd * 2
            elif spec.kind == "mamba":
                total += st.repeats * b * (
                    cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
                    + (cfg.ssm_conv - 1)
                    * (cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state) * 2)
    return total
