"""Step builders: train_step / serve_prefill / serve_step per (arch x cell),
with full sharding trees. ``input_specs`` returns ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, no device allocation) — the dry-run lowers
against these.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.lm.config import LMConfig, ShapeCell, SHAPES
from repro.lm.model import TransformerLM
from repro.launch.partitioning import Partitioner
from repro.nn.common import sharding_context
from repro.optim import AdamW, TrainState, cosine_schedule

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: LMConfig, cell: ShapeCell) -> Dict[str, SDS]:
    """Abstract data inputs for this (arch, cell): tokens/targets or the
    decode token + index; multimodal archs add stubbed frontend embeddings."""
    b, s = cell.global_batch, cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, SDS] = {}
    if cell.mode == "train":
        specs["tokens"] = SDS((b, s), jnp.int32)
        specs["targets"] = SDS((b, s), jnp.int32)
    elif cell.mode == "prefill":
        specs["tokens"] = SDS((b, s), jnp.int32)
    else:  # decode: one new token against a seq_len cache
        specs["token"] = SDS((b, 1), jnp.int32)
        specs["index"] = SDS((), jnp.int32)
    if cfg.encoder_layers:
        specs["frontend"] = SDS((b, cfg.encoder_seq, cfg.d_model), dt)
    elif cfg.frontend_tokens:
        specs["frontend"] = SDS((b, cfg.frontend_tokens, cfg.frontend_dim), dt)
    return specs


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower/compile/run one (arch x cell) step."""

    name: str
    fn: Any                      # jitted function
    abstract_args: Tuple         # ShapeDtypeStructs matching fn's signature
    partitioner: Partitioner
    model: TransformerLM
    mode: str

    def lower(self):
        return self.fn.lower(*self.abstract_args)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def build_step(
    cfg: LMConfig,
    cell: ShapeCell,
    mesh,
    *,
    remat: bool = True,
    donate: bool = True,
    part_kwargs: Optional[dict] = None,
) -> StepBundle:
    model = TransformerLM(cfg, remat=remat)
    part = Partitioner(mesh, cfg, mode=cell.mode, **(part_kwargs or {}))
    resolver = part.logical_resolver()
    data = input_specs(cfg, cell)
    b, s = cell.global_batch, cell.seq_len

    a_params = jax.eval_shape(model.init, jax.random.key(0))
    params_sh = part.param_shardings(a_params)

    def data_sharding(tree):
        return jax.tree.map(
            lambda x: NamedSharding(mesh, part.batch_spec(x.shape)), tree)

    if cell.mode == "train":
        opt = AdamW(learning_rate=cosine_schedule(3e-4, 200, 20_000))
        a_state = jax.eval_shape(opt.init, a_params)
        state_sh = part.state_shardings(a_state)
        batch = {k: v for k, v in data.items()}
        batch_sh = data_sharding(batch)

        def train_step(state: TrainState, batch):
            with sharding_context(resolver):
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(state.params, batch)
                new_state = opt.update(grads, state)
            out = {"loss": loss, **metrics}
            return new_state, out

        fn = jax.jit(
            train_step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, _replicated(mesh)),
            donate_argnums=(0,) if donate else (),
        )
        return StepBundle(f"{cfg.name}:{cell.name}:train", fn,
                          (a_state, batch), part, model, "train")

    if cell.mode == "prefill":
        tokens = data["tokens"]
        frontend = data.get("frontend")

        def serve_prefill(params, tokens, frontend=None):
            with sharding_context(resolver):
                return model.prefill(params, tokens, frontend=frontend,
                                     cache_len=s)

        a_cache = jax.eval_shape(lambda: model.init_cache(b, s))
        cache_sh = part.cache_shardings(a_cache)
        in_sh = [params_sh, data_sharding(tokens)]
        args = [a_params, tokens]
        if frontend is not None:
            in_sh.append(data_sharding(frontend))
            args.append(frontend)
        fn = jax.jit(
            serve_prefill,
            in_shardings=tuple(in_sh),
            out_shardings=(_replicated(mesh), cache_sh),
        )
        return StepBundle(f"{cfg.name}:{cell.name}:prefill", fn,
                          tuple(args), part, model, "prefill")

    # decode
    token = data["token"]
    index = data["index"]
    frontend = data.get("frontend")
    a_cache = jax.eval_shape(lambda: model.init_cache(b, s))
    cache_sh = part.cache_shardings(a_cache)

    def serve_step(params, token, index, caches, frontend=None):
        with sharding_context(resolver):
            return model.decode_step(params, token, index, caches,
                                     frontend=frontend)

    in_sh = [params_sh, data_sharding(token), _replicated(mesh), cache_sh]
    args = [a_params, token, index, a_cache]
    if frontend is not None:
        in_sh.append(data_sharding(frontend))
        args.append(frontend)
    fn = jax.jit(
        serve_step,
        in_shardings=tuple(in_sh),
        out_shardings=(_replicated(mesh), cache_sh),
        donate_argnums=(3,) if donate else (),
    )
    return StepBundle(f"{cfg.name}:{cell.name}:decode", fn,
                      tuple(args), part, model, "decode")
