"""End-to-end training driver: data pipeline + sharded step + async
checkpointing + heartbeat/straggler monitoring + elastic restart.

CPU-scale run (reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 20 --batch 8 --seq 64

Fault-tolerance drill (same command + --simulate-failure 7): a "host"
stops heartbeating at step 7; the controller drains, replans the mesh from
survivors, restores the last checkpoint under the new mesh and resumes —
the data stream is a pure function of the step, so no batch is skipped.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.lm.config import ShapeCell
from repro.lm.model import TransformerLM
from repro.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLMStream, PrefetchIterator
from repro.launch.mesh import make_mesh, plan_elastic_mesh
from repro.launch.steps import build_step
from repro.runtime.fault import (
    ElasticController, HeartbeatMonitor, StragglerPolicy,
)


def build_mesh_for_devices(model_parallel: int | None = None):
    n = jax.device_count()
    mp = model_parallel or (16 if n % 16 == 0 and n >= 16 else 1)
    plan = plan_elastic_mesh(n, model_parallel=mp)
    return make_mesh(plan.shape, plan.axes), plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = C.get_reduced(args.arch) if args.reduced else C.get_config(args.arch)
    cell = ShapeCell("custom", args.seq, args.batch, "train")
    mesh, plan = build_mesh_for_devices()
    print(f"[train] {cfg.name}: mesh={plan.shape} devices={plan.used_devices}")

    bundle = build_step(cfg, cell, mesh, remat=False, donate=True)
    model = bundle.model

    # real state init (the dry run only eval_shapes this)
    from repro.optim import AdamW, cosine_schedule
    opt = AdamW(learning_rate=cosine_schedule(3e-4, 10, max(args.steps, 20)))
    params = model.init(jax.random.key(0))
    state = opt.init(params)
    state_sh = bundle.partitioner.state_shardings(jax.eval_shape(lambda: state))
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, state_sh)

    ckpt = Checkpointer(args.ckpt_dir)
    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore(state, shardings=state_sh)
        start_step = ckpt.latest_step()
        print(f"[train] resumed from step {start_step}")

    stream = SyntheticLMStream(cfg, cell, seed=0)
    it = PrefetchIterator(stream, start_step=start_step)
    hosts = [f"host{i}" for i in range(max(1, jax.process_count()))]
    monitor = HeartbeatMonitor(hosts, timeout=1e9)  # injected clock in tests
    policy = StragglerPolicy()
    controller = ElasticController(monitor, devices_per_host=jax.device_count())

    losses = []
    step = start_step
    while step < args.steps:
        got_step, batch = next(it)
        assert got_step == step, (got_step, step)
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = bundle.fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        for h in hosts:
            monitor.heartbeat(h, step=step, step_time=dt)
        actions = policy.decide(monitor)
        if actions:
            print(f"[train] straggler actions: {actions}")

        if args.simulate_failure == step:
            print(f"[train] !! simulating host failure at step {step}")
            monitor.hosts["host0"].last_heartbeat = -1e12
            monitor.timeout = 1.0
            ev = controller.check(step)
            assert ev is not None
            # drain -> replan -> restore -> resume
            ckpt.wait()
            new_plan = plan_elastic_mesh(
                max(jax.device_count(), 1),
                model_parallel=mesh.shape.get("model", 1))
            new_mesh = make_mesh(new_plan.shape, new_plan.axes)
            bundle = build_step(cfg, cell, new_mesh, remat=False)
            state_sh = bundle.partitioner.state_shardings(
                jax.eval_shape(lambda: state))
            restore_step = ckpt.latest_step()
            if restore_step is not None:
                state = ckpt.restore(state, shardings=state_sh)
                it.close()
                step = restore_step
                it = PrefetchIterator(stream, start_step=step)
                print(f"[train] re-meshed to {new_plan.shape}, resumed at "
                      f"step {step}")
            monitor.timeout = 1e9
            monitor.heartbeat("host0")
            args.simulate_failure = -1
            continue

        step += 1
        if step % args.ckpt_every == 0:
            ckpt.save(step, state)           # async write
        if step % 5 == 0 or step == args.steps:
            print(f"[train] step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")

    ckpt.wait()
    it.close()
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
