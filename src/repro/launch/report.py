"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.report [--tag baseline]
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag: str = "baseline") -> List[Dict]:
    recs = []
    for p in sorted(ART.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag", "baseline") == tag:
            recs.append(rec)
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = [
        f"| arch | shape | mode | lower | compile | args/dev GiB | temp/dev GiB | HLO flops/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} "
            f"| {r['lower_s']:.1f}s | {r['compile_s']:.1f}s "
            f"| {fmt_bytes(m['argument_bytes'])} | {fmt_bytes(m['temp_bytes'])} "
            f"| {r['collectives']['flops']:.2e} |")
    return "\n".join(out)


def roofline_table(recs: List[Dict], mesh: str = "16x16") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = [
        "| arch | shape | t_compute | t_mem(HLO) | t_mem(model) | t_coll | dominant | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        t = r["roofline_raw"]
        ratio = r.get("model_flops_ratio")
        # roofline fraction: useful model flops time / dominant bound time
        t_model_compute = (r.get("model_flops", 0) / r["chips"]) / 197e12
        bound = max(t["t_compute_s"], r.get("t_memory_model_s", 0),
                    t["t_collective_s"])
        frac = t_model_compute / bound if bound else 0.0
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(r.get('t_memory_model_s'))} "
            f"| {fmt_s(t['t_collective_s'])} | **{t['dominant']}** "
            f"| {ratio:.2f} | {frac:.2f} |" if ratio else
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(r.get('t_memory_model_s'))} "
            f"| {fmt_s(t['t_collective_s'])} | **{t['dominant']}** | - | - |")
    return "\n".join(out)


def collective_breakdown(recs: List[Dict], mesh: str = "16x16") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: -r["collectives"].get("collective_bytes", 0))
    out = ["| arch | shape | total GB/dev | all-reduce | all-gather | reduce-scatter | all-to-all | permute |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows[:12]:
        c = r["collectives"]
        gb = lambda k: f"{c.get(k, 0)/1e9:.1f}"
        out.append(
            f"| {r['arch']} | {r['shape']} | {gb('collective_bytes')} "
            f"| {gb('coll_all-reduce')} | {gb('coll_all-gather')} "
            f"| {gb('coll_reduce-scatter')} | {gb('coll_all-to-all')} "
            f"| {gb('coll_collective-permute')} |")
    return "\n".join(out)


def pick_hillclimb_cells(recs: List[Dict]) -> Dict[str, Dict]:
    """worst roofline fraction / most collective-bound / most
    technique-representative (the biggest MoE = segment-MM workload)."""
    rows = [r for r in recs if r["mesh"] == "16x16"]

    def frac(r):
        t = r["roofline_raw"]
        t_model = (r.get("model_flops", 0) / r["chips"]) / 197e12
        bound = max(t["t_compute_s"], r.get("t_memory_model_s", 0),
                    t["t_collective_s"])
        return t_model / bound if bound else 0.0

    worst = min(rows, key=frac)
    coll = max(rows, key=lambda r: r["roofline_raw"]["t_collective_s"]
               / max(1e-12, r["roofline_raw"]["t_compute_s"]))
    moe = max((r for r in rows if r["arch"] in
               ("moonshot-v1-16b-a3b", "grok-1-314b", "jamba-v0.1-52b")),
              key=lambda r: r["roofline_raw"]["t_collective_s"])
    return {"worst_fraction": worst, "most_collective": coll,
            "technique_rep": moe}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    recs = load(args.tag)
    print(f"## Dry-run ({len(recs)} records, tag={args.tag})\n")
    for mesh in ("16x16", "2x16x16"):
        print(f"### mesh {mesh}\n")
        print(dryrun_table(recs, mesh))
        print()
    print("## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs))
    print("\n### Collective breakdown (top cells)\n")
    print(collective_breakdown(recs))
    picks = pick_hillclimb_cells(recs)
    print("\n### Hillclimb picks")
    for k, r in picks.items():
        print(f"- {k}: {r['arch']} x {r['shape']}")


if __name__ == "__main__":
    main()
