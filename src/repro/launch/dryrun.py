import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the two lines above MUST precede any jax import)
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun                # all 40 cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod    # 2x16x16 pass

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json; the roofline
report (launch/roofline.py) reads them.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from repro import configs as C
from repro.lm.config import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.launch.roofline import (
    collective_bytes_from_hlo, summarize_cost, roofline_terms, HW_V5E,
    model_flops, analytic_memory_bytes,
)

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             part_kwargs=None, tag: str = "", verbose: bool = True) -> dict:
    cfg = C.get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": n_chips,
        "mode": cell.mode, "tag": tag or "baseline",
        "params_b": cfg.param_count() / 1e9,
        "active_params_b": cfg.active_param_count() / 1e9,
    }
    t0 = time.time()
    bundle = build_step(cfg, cell, mesh, part_kwargs=part_kwargs)
    lowered = bundle.lower()
    rec["lower_s"] = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t1

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    cost = compiled.cost_analysis()
    rec["cost"] = summarize_cost(cost)
    rec["collectives"] = collective_bytes_from_hlo(compiled.as_text())
    rec["roofline_raw"] = roofline_terms(
        rec["cost"], rec["collectives"], n_chips, HW_V5E)
    # analytic (model-based) counterparts: MODEL_FLOPS ratio + memory term
    mf = model_flops(cfg, cell)
    hlo_total = rec["roofline_raw"]["total_flops"]
    rec["model_flops"] = mf
    rec["model_flops_ratio"] = mf / hlo_total if hlo_total else None
    amem = analytic_memory_bytes(cfg, cell, n_chips)
    rec["t_memory_model_s"] = amem / HW_V5E.hbm_bw
    rec["analytic_mem_bytes_per_dev"] = amem
    if verbose:
        m = rec["memory"]
        per_dev = (m["argument_bytes"] or 0) / n_chips / 2**30
        print(f"[dryrun] {arch:24s} {shape:12s} {mesh_name:8s} "
              f"lower={rec['lower_s']:6.1f}s compile={rec['compile_s']:6.1f}s "
              f"args/dev={per_dev:7.2f}GiB flops={rec['cost'].get('flops', 0):.3e}")
    return rec


def save(rec: dict):
    ART.mkdir(parents=True, exist_ok=True)
    tag = "" if rec["tag"] == "baseline" else f"__{rec['tag']}"
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
    (ART / name).write_text(json.dumps(rec, indent=2, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id")
    ap.add_argument("--shape", default=None, help="single shape cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="v-E: sequence-parallel activations")
    ap.add_argument("--attn-baseline", action="store_true",
                    help="reproduce pre-v-A attention sharding (hd fallback)")
    ap.add_argument("--moe-ep", action="store_true",
                    help="v-B: shard_map expert-parallel MoE dispatch")
    ap.add_argument("--bf16-reduce", action="store_true",
                    help="v-D: bf16 partial-sum collectives")
    ap.add_argument("--seq-shard-kv", action="store_true",
                    help="v-C: sequence-sharded decode KV cache")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else C.ARCHS
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    part_kwargs = {}
    if args.seq_shard:
        part_kwargs["seq_shard_activations"] = True
    if args.attn_baseline:
        part_kwargs["attn_head_sharding_only"] = False
    if args.moe_ep:
        part_kwargs["moe_ep"] = True
    if args.bf16_reduce:
        part_kwargs["bf16_reduce"] = True
    if args.seq_shard_kv:
        part_kwargs["seq_shard_kv_decode"] = True
    part_kwargs = part_kwargs or None

    failures = []
    for arch in archs:
        shapes = [args.shape] if args.shape else C.applicable_shapes(arch)
        for shape in shapes:
            if shape not in C.applicable_shapes(arch):
                print(f"[dryrun] SKIP {arch} {shape} (long-context needs "
                      f"sub-quadratic attention; see DESIGN.md §6)")
                continue
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, mp,
                                   part_kwargs=part_kwargs, tag=args.tag)
                    save(rec)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} {shape} mp={mp}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("[dryrun] all requested cells compiled OK")


if __name__ == "__main__":
    main()
