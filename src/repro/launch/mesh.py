"""Production meshes + elastic re-mesh planning.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod = 16x16 = 256 chips ("data","model");
multi-pod = 2x16x16 = 512 chips ("pod","data","model").

``plan_elastic_mesh`` supports fault tolerance: given the number of
*surviving* devices after failures, pick the largest factorizable mesh that
preserves the model axis (TP groups must stay intact — a TP group losing one
chip loses its shard of every weight), shrinking the data axis instead.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None):
    if devices is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    import numpy as np
    dev = np.asarray(devices)[: int(np.prod(shape))].reshape(tuple(shape))
    from jax.sharding import Mesh
    return Mesh(dev, tuple(axes))


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    used_devices: int
    dropped_devices: int

    @property
    def dp_degree(self) -> int:
        return self.used_devices // self.shape[-1]


def plan_elastic_mesh(surviving: int, model_parallel: int = 16,
                      pods: int = 1) -> ElasticPlan:
    """Largest usable mesh after failures.

    TP degree is preserved (checkpoint weight shards stay valid); the data
    axis shrinks to floor(surviving / model_parallel). Remaining chips idle
    until the failed hosts are replaced (standard elastic-DP policy).
    """
    if surviving < model_parallel:
        raise ValueError(
            f"fewer surviving devices ({surviving}) than one TP group "
            f"({model_parallel}); cannot form a mesh")
    dp = surviving // model_parallel
    used = dp * model_parallel
    if pods > 1 and dp % pods == 0:
        shape = (pods, dp // pods, model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (dp, model_parallel)
        axes = ("data", "model")
    return ElasticPlan(shape=shape, axes=axes, used_devices=used,
                       dropped_devices=surviving - used)
