"""Production meshes + elastic re-mesh planning.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod = 16x16 = 256 chips ("data","model");
multi-pod = 2x16x16 = 512 chips ("pod","data","model").

``plan_elastic_mesh`` supports fault tolerance: given the number of
*surviving* devices after failures, pick the largest factorizable mesh that
preserves the model axis (TP groups must stay intact — a TP group losing one
chip loses its shard of every weight), shrinking the data axis instead.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None):
    """Mesh over ``shape``/``axes``; with an explicit (possibly
    non-contiguous) device list it must supply at least prod(shape)
    devices — short lists used to reshape-crash with an opaque error."""
    import numpy as np
    need = int(np.prod(shape))
    if devices is None:
        if need > len(jax.devices()):
            raise ValueError(
                f"mesh shape {tuple(shape)} needs {need} devices; only "
                f"{len(jax.devices())} available")
        return jax.make_mesh(tuple(shape), tuple(axes))
    if len(devices) < need:
        raise ValueError(
            f"mesh shape {tuple(shape)} needs {need} devices; "
            f"got a list of {len(devices)}")
    dev = np.asarray(devices)[:need].reshape(tuple(shape))
    from jax.sharding import Mesh
    return Mesh(dev, tuple(axes))


def make_data_mesh(num_devices: Optional[int] = None,
                   devices: Optional[Sequence] = None):
    """1-D data-only mesh (axis ``"data"``) over any device count.

    Unlike ``make_production_mesh`` this makes no 16-wide-TP or
    pod-topology assumption: it works on whatever ``jax.devices()``
    provides — including CPU hosts forced to N devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — and accepts an
    explicit (non-contiguous, e.g. post-failure surviving) device list.
    ``num_devices=None`` uses every available device.
    """
    if devices is None:
        devices = jax.devices()
    n = len(devices) if num_devices is None else int(num_devices)
    if n <= 0:
        raise ValueError("data mesh needs at least one device")
    return make_mesh((n,), ("data",), devices=list(devices)[:n])


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    used_devices: int
    dropped_devices: int

    @property
    def dp_degree(self) -> int:
        mp = self.shape[-1] if self.axes[-1] == "model" else 1
        return self.used_devices // mp


def plan_elastic_mesh(surviving: int, model_parallel: int = 16,
                      pods: int = 1, data_only: bool = False) -> ElasticPlan:
    """Largest usable mesh after failures.

    TP degree is preserved (checkpoint weight shards stay valid); the data
    axis shrinks to floor(surviving / model_parallel). Remaining chips idle
    until the failed hosts are replaced (standard elastic-DP policy).

    ``data_only=True`` plans a 1-D ``("data",)`` mesh instead (the
    partitioned-graph executors in ``dist/``, which have no TP axis at
    all): every survivor is usable and logical graph shards refold onto
    the remaining devices (``shards_per_device = P // dp``). The default
    keeps the trailing ``"model"`` axis even at ``model_parallel=1`` —
    the LM partitioner resolves specs against that axis by name.
    """
    if surviving < model_parallel:
        raise ValueError(
            f"fewer surviving devices ({surviving}) than one TP group "
            f"({model_parallel}); cannot form a mesh")
    dp = surviving // model_parallel
    used = dp * model_parallel
    if data_only:
        if model_parallel != 1 or pods > 1:
            raise ValueError("data_only plans have no model/pod axes")
        return ElasticPlan(shape=(dp,), axes=("data",), used_devices=dp,
                           dropped_devices=surviving - dp)
    if pods > 1 and dp % pods == 0:
        shape = (pods, dp // pods, model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (dp, model_parallel)
        axes = ("data", "model")
    return ElasticPlan(shape=shape, axes=axes, used_devices=used,
                       dropped_devices=surviving - used)
