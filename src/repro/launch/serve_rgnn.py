"""Batched RGNN inference serving driver.

Request batches of seed nodes stream through the fanout sampler (prefetched
on a background thread, kernel layouts built off the accelerator path), and
a multi-layer Hector stack runs one generated layer per sampled hop through
the whole-plan compiled ``BlockExecutor``, returning per-seed logits.
Reports per-batch latency split into queue-wait (sampling + layout, when not
hidden by prefetch) and model compute, end-to-end seed throughput, and —
when the caches are enabled — sampled-block / layout cache hit rates plus
compiled-executor trace counts (``retraces_after_warmup`` pins the
steady-state zero-retrace invariant).

    PYTHONPATH=src python -m repro.launch.serve_rgnn --model rgat --reduced
    PYTHONPATH=src python -m repro.launch.serve_rgnn \
        --model hgt --dataset mutag --fanout 5,10 --batch-size 64
    # power-law repeat traffic over 4 distinct batches, all caches on:
    PYTHONPATH=src python -m repro.launch.serve_rgnn --repeat-after 4 \
        --cache-blocks 64 --cache-layouts 256
"""
from __future__ import annotations

import argparse
import contextlib
import time

import numpy as np
import jax
import jax.numpy as jnp

import hector
from repro import obs
from repro.core.graph import CPU_REDUCED_SCALES as REDUCED_SCALES
from repro.core.graph import table3_graph
from repro.sampling import SeedStream
from repro.train.engine import MODEL_PROGRAMS, parse_fanout


def serve(
    model: str = "rgat",
    dataset: str = "aifb",
    scale: float = 1.0,
    layers: int = 2,
    dim: int = 64,
    hidden: int = 64,
    classes: int = 16,
    fanouts=None,
    batch_size: int = 32,
    num_batches: int = 8,
    backend: str = "xla",
    tile: int = 32,
    node_block: int = 32,
    bucket: bool = True,
    seed: int = 0,
    sampler: str = "host",
    dp: int = 1,
    partitions=None,
    feature_store: str = "device",
    feature_budget=None,
    skew=None,
    prefetch_depth: int = 2,
    cache_blocks: int = 0,
    cache_layouts: int = 0,
    repeat_after=None,
    compiled: bool = True,
    warmup_batches=None,
    tune: str = "off",
    tune_cache=None,
    obs_mode: str = "on",
    trace_out=None,
    metrics_out=None,
    profile: bool = False,
    log=print,
):
    """Run the serving loop; returns a stats dict (used by tests/benchmarks).

    ``repeat_after`` wraps the seed stream onto that many distinct batches
    (power-law repeat traffic). ``warmup_batches`` (default: ``repeat_after``
    or 2) splits the trace accounting: compiles during warmup are expected,
    any after it count as ``retraces_after_warmup``.

    Observability: with ``obs_mode="on"`` the call runs inside an
    ``obs.scope`` — latency histograms and cache/trace counters land in a
    metrics registry whose snapshot is returned as ``stats["metrics"]``
    (and written to ``metrics_out`` if given). ``trace_out`` additionally
    enables phase tracing (``sample``/``layout``/``execute`` spans) and
    writes a Chrome-trace JSON there. ``profile=True`` runs the per-op
    plan profiler on the last served mini-batch and attaches the breakdown
    as ``stats["profile"]``. ``obs_mode="off"`` serves with observability
    fully disabled (the <2%-overhead baseline).
    """
    if warmup_batches is None:
        warmup_batches = repeat_after if repeat_after else 2
    warmup_batches = min(warmup_batches, num_batches)

    with contextlib.ExitStack() as stack:
        sc = None
        if obs_mode == "off":
            stack.enter_context(obs.disabled())
        else:
            sc = stack.enter_context(obs.scope(
                metrics=True, tracing=trace_out is not None))
        return _serve_scoped(
            sc, model, dataset, scale, layers, dim, hidden, classes,
            fanouts, batch_size, num_batches, backend, tile, node_block,
            bucket, seed, sampler, dp, partitions, feature_store,
            feature_budget, skew, prefetch_depth,
            cache_blocks, cache_layouts, repeat_after, compiled,
            warmup_batches, tune, tune_cache, trace_out, metrics_out,
            profile, log)


def _serve_scoped(
    sc, model, dataset, scale, layers, dim, hidden, classes, fanouts,
    batch_size, num_batches, backend, tile, node_block, bucket, seed,
    sampler, dp, partitions, feature_store, feature_budget, skew,
    prefetch_depth, cache_blocks, cache_layouts,
    repeat_after, compiled, warmup_batches, tune, tune_cache, trace_out,
    metrics_out, profile, log,
):

    t0 = time.perf_counter()
    graph = table3_graph(dataset, scale=scale, seed=seed)
    rng = np.random.default_rng(seed)
    # host-side table: the chosen feature store decides what (if anything)
    # becomes device-resident
    feats = rng.normal(size=(graph.num_nodes, dim)).astype(np.float32)
    t_graph = time.perf_counter() - t0

    # the unified front door: program -> plans -> compiled stack -> sampler
    # (+ tuner), one call (frontend/compile.py)
    engine = hector.compile(
        model, graph, layers=layers, dim=dim, hidden=hidden,
        classes=classes, sample=fanouts, backend=backend, tile=tile,
        node_block=node_block, bucket=bucket, seed=seed, sampler=sampler,
        dp=dp, partitions=partitions, feature_store=feature_store,
        feature_budget=feature_budget, tune=tune, tune_cache=tune_cache,
        tune_full_graph=False, log=log)
    fanouts = engine.cfg.fanouts
    log(f"[serve_rgnn] {model} on {dataset} (scale {scale}): "
        f"{graph.num_nodes} nodes, {graph.num_edges} edges, "
        f"{graph.num_etypes} etypes; fanouts={fanouts} "
        f"sampler={sampler} feature_store={feature_store}"
        + (f" skew={skew}" if skew else "")
        + f" (graph build {t_graph:.2f}s)")
    params = engine.init(jax.random.key(seed))

    stream = SeedStream(graph.num_nodes, batch_size, seed=seed,
                        num_distinct=repeat_after, zipf_alpha=skew)
    # the feature store; for the cached tier the per-ntype slot split is a
    # measured decision probed on this exact traffic (tune.feature_budget)
    store = engine.make_feature_store(feats, seed_source=stream)
    if feature_store == "cached":
        log(f"[serve_rgnn] feature cache: {store.capacity} device rows "
            f"({store.device_bytes() / 1e6:.2f} MB vs full table "
            f"{store.table_bytes / 1e6:.2f} MB), per-ntype slots "
            f"{store.slot_ptr.tolist()}")

    if engine.cfg.distributed:
        return _serve_dist(engine, graph, store, params, batch_size,
                           num_batches, repeat_after, warmup_batches, seed,
                           skew, sc, metrics_out, log)

    if tune != "off":
        # block-scale tuning on one representative (bucketed) mini-batch,
        # off the serving stream so traffic is untouched; with a warm
        # persistent cache this replays decisions with zero measurements
        warm_seeds = np.random.default_rng(seed + 1).integers(
            0, graph.num_nodes, batch_size).astype(np.int32)
        tl = engine.make_loader(lambda step: warm_seeds, num_batches=1,
                                depth=1)
        try:
            engine.tune_minibatch(params, next(tl), jnp.asarray(feats))
        finally:
            tl.close()
        ts = engine.tuner_stats
        log(f"[serve_rgnn] tune={tune}: {ts.get('measurements', 0)} "
            f"measurements, {ts.get('cache_hits', 0)} cache replays "
            f"(tile {engine.tile}, node_block {engine.node_block})")

    loader = engine.make_loader(
        stream,
        num_batches=num_batches, depth=prefetch_depth,
        cache_blocks=cache_blocks, cache_layouts=cache_layouts,
        feature_store=store,
    )

    executor = engine.block_executor
    metrics = obs.metrics()
    h_lat = metrics.histogram("serve_batch_ms")
    h_wait = metrics.histogram("serve_wait_ms")
    h_compute = metrics.histogram("serve_compute_ms")
    lat, waits, computes, preds = [], [], [], None
    edges_seen = 0
    retraces_after_warmup = 0
    traces_at_warmup = None
    dev_sampler = getattr(engine, "device_sampler", None)
    sampler_traces_at_warmup = None
    sampler_syncs_at_warmup = None
    last_mb = None
    t_serve0 = time.perf_counter()
    try:
        while True:
            t0 = time.perf_counter()
            with obs.span("wait", batch=len(lat)):
                try:
                    mb = next(loader)
                except StopIteration:
                    break
            t_wait = time.perf_counter() - t0
            if len(lat) == warmup_batches:
                traces_at_warmup = executor.trace_count
                if dev_sampler is not None:
                    sampler_traces_at_warmup = dev_sampler.trace_count
                    sampler_syncs_at_warmup = dev_sampler.count_syncs
            t0 = time.perf_counter()
            # engine.apply_blocks opens the "execute" span (with a device
            # sync inside it when tracing is on); the loader attached this
            # batch's features (mb.feats) through the store
            logits = engine.apply_blocks(params, mb, store,
                                         compiled=compiled)
            logits.block_until_ready()
            t_fwd = time.perf_counter() - t0
            lat.append(t_wait + t_fwd)
            waits.append(t_wait)
            computes.append(t_fwd)
            h_lat.observe((t_wait + t_fwd) * 1e3)
            h_wait.observe(t_wait * 1e3)
            h_compute.observe(t_fwd * 1e3)
            edges_seen += sum(gt.num_edges for gt in mb.tensors)
            preds = np.asarray(jnp.argmax(logits, axis=-1))
            last_mb = mb
            hops = "+".join(str(b.num_src) for b in mb.seq.blocks)
            log(f"[serve_rgnn] batch {mb.step}: wait {t_wait*1e3:6.1f} ms, "
                f"forward {t_fwd*1e3:6.1f} ms  (block nodes {hops})")
    finally:
        loader.close()
    t_total = time.perf_counter() - t_serve0
    if traces_at_warmup is not None:
        retraces_after_warmup = executor.trace_count - traces_at_warmup

    n = len(lat)
    if n == 0:
        raise RuntimeError("no batches served")
    lat_arr = np.asarray(lat)
    stats = {
        "batches": n,
        "batch_size": batch_size,
        "latency_ms_p50": float(np.percentile(lat_arr, 50) * 1e3),
        "latency_ms_p95": float(np.percentile(lat_arr, 95) * 1e3),
        "latency_ms_p99": float(np.percentile(lat_arr, 99) * 1e3),
        "latency_ms_mean": float(lat_arr.mean() * 1e3),
        "wait_ms_mean": float(np.mean(waits) * 1e3),
        "compute_ms_mean": float(np.mean(computes) * 1e3),
        "seeds_per_s": batch_size * n / max(t_total, 1e-9),
        "edges_per_batch": edges_seen / n,
        "last_preds": preds,
        "warmup_batches": warmup_batches,
        "executor_traces": executor.trace_count,
        "executor_cache_hits": executor.cache_hits,
        "executor_compiled": executor.num_compiled,
        "retraces_after_warmup": retraces_after_warmup,
        "sampler": loader.mode,
        "host_builds": loader.host_builds,
        "device_builds": loader.device_builds,
    }
    if dev_sampler is not None:
        stats["sampler_traces"] = dev_sampler.trace_count
        stats["sampler_retraces_after_warmup"] = (
            dev_sampler.trace_count - sampler_traces_at_warmup
            if sampler_traces_at_warmup is not None else 0)
        stats["sampler_count_syncs"] = dev_sampler.count_syncs
        stats["sampler_count_syncs_after_warmup"] = (
            dev_sampler.count_syncs - sampler_syncs_at_warmup
            if sampler_syncs_at_warmup is not None
            else dev_sampler.count_syncs)
        stats["sampler_bucket_overflows"] = dev_sampler.bucket_overflows
        stats["sampler_bucket_shrinks"] = dev_sampler.bucket_shrinks
        log(f"[serve_rgnn] device sampler: {dev_sampler.trace_count} traces "
            f"/ {dev_sampler.cache_hits} program-cache hits "
            f"({stats['sampler_retraces_after_warmup']} retraces after "
            f"warmup); {dev_sampler.count_syncs} count syncs, "
            f"{dev_sampler.bucket_shrinks} bucket shrinks, "
            f"{dev_sampler.bucket_overflows} overflows; builds host "
            f"{loader.host_builds} / device {loader.device_builds}")
    if obs.metrics_enabled():
        # registry-sourced latency percentiles (the reservoir keeps every
        # sample at this scale, so these match the array-side numbers)
        hs = metrics.histogram_summary("serve_batch_ms")
        stats["latency_ms_p50"] = hs["p50"]
        stats["latency_ms_p95"] = hs["p95"]
        stats["latency_ms_p99"] = hs["p99"]
    for k, v in engine.tuner_stats.items():
        stats[f"tune_{k}"] = v
    for name, cs in loader.cache_stats().items():
        stats[f"{name}_hits"] = cs["hits"]
        stats[f"{name}_misses"] = cs["misses"]
        stats[f"{name}_hit_rate"] = cs["hit_rate"]
    for k, v in store.stats().items():
        stats[f"feature_{k}"] = v
    if feature_store != "device":
        log(f"[serve_rgnn] feature store ({feature_store}): "
            f"{store.host_gathers} host gathers, "
            f"{store.bytes_moved / 1e6:.2f} MB moved"
            + (f", hit rate {store.hit_rate:.0%} "
               f"({store.evictions} evictions, {store.overflows} overflows)"
               if feature_store == "cached" else ""))
    log(f"[serve_rgnn] served {n} batches x {batch_size} seeds: "
        f"latency p50 {stats['latency_ms_p50']:.1f} ms / "
        f"p95 {stats['latency_ms_p95']:.1f} ms / "
        f"p99 {stats['latency_ms_p99']:.1f} ms "
        f"(wait {stats['wait_ms_mean']:.1f} + "
        f"compute {stats['compute_ms_mean']:.1f} ms avg), "
        f"throughput {stats['seeds_per_s']:.1f} seeds/s, "
        f"avg {stats['edges_per_batch']:.0f} sampled edges/batch")
    log(f"[serve_rgnn] executor: {executor.trace_count} traces / "
        f"{executor.cache_hits} cache hits "
        f"({retraces_after_warmup} retraces after warmup)"
        + "".join(f", {k.removesuffix('_hit_rate')} hit rate {v:.0%}"
                  for k, v in stats.items() if k.endswith("_hit_rate")))
    log(f"[serve_rgnn] sample predictions: {preds[:12].tolist()}")

    if profile and last_mb is not None:
        from repro.obs import profile as prof_mod
        p = engine.profile(params, last_mb, feats, warmup=1, iters=5) \
            if hasattr(engine, "profile") else \
            prof_mod.profile_minibatch(engine, params, last_mb, feats,
                                       warmup=1, iters=5)
        log("[serve_rgnn] per-op kernel breakdown (last batch):\n"
            + p.table())
        stats["profile"] = p.to_json()

    if sc is not None:
        if sc.tracer is not None:
            log("[serve_rgnn] phase table:\n" + sc.tracer.phase_table())
            if trace_out:
                sc.tracer.write(trace_out)
                log(f"[serve_rgnn] chrome trace -> {trace_out}")
        stats["metrics"] = sc.registry.snapshot()
        if metrics_out:
            sc.registry.export(metrics_out)
            log(f"[serve_rgnn] metrics snapshot -> {metrics_out}")
    return stats


def _serve_dist(engine, graph, store, params, batch_size, num_batches,
                repeat_after, warmup_batches, seed, skew, sc, metrics_out,
                log):
    """Multi-shard serving loop: route each request batch to its owner
    shards, sample per shard, run the one compiled ``shard_map`` step,
    report request-order predictions. Stats keys mirror the single-box
    loop so benchmarks/tests compare the two paths directly.

    The per-owner feature slabs are read through the feature store
    (``host_rows``), so with a host/cached store the full table never
    becomes device-resident — each shard holds only its owned rows."""
    cfg = engine.cfg
    log(f"[serve_rgnn] distributed: {cfg.num_partitions} shards over "
        f"{cfg.dp} devices\n" + engine.partition.describe())
    batcher = engine.dist_batcher
    serve_ex = engine.dist_serve_executor()
    own_feats = engine.shard_features(store)
    stream = SeedStream(graph.num_nodes, batch_size, seed=seed,
                        num_distinct=repeat_after, zipf_alpha=skew)

    lat, waits, computes, preds = [], [], [], None
    traces_at_warmup = None
    t_serve0 = time.perf_counter()
    for step in range(num_batches):
        if step == warmup_batches:
            traces_at_warmup = serve_ex.trace_count
        t0 = time.perf_counter()
        with obs.span("wait", batch=step):
            smb = batcher.build(stream.batch(step), step=step)
        t_wait = time.perf_counter() - t0
        t0 = time.perf_counter()
        with obs.span("execute", step=step):
            logits = serve_ex.run_minibatch(params, smb, own_feats)
            logits.block_until_ready()
        t_fwd = time.perf_counter() - t0
        lat.append(t_wait + t_fwd)
        waits.append(t_wait)
        computes.append(t_fwd)
        obs.metrics().histogram("serve_batch_ms").observe(
            (t_wait + t_fwd) * 1e3)
        preds = np.asarray(jnp.argmax(logits, axis=-1))
        log(f"[serve_rgnn] batch {step}: route+sample {t_wait*1e3:6.1f} ms, "
            f"forward {t_fwd*1e3:6.1f} ms")
    t_total = time.perf_counter() - t_serve0
    if traces_at_warmup is None:
        traces_at_warmup = serve_ex.trace_count

    lat_arr = np.asarray(lat)
    stats = {
        "batches": num_batches,
        "batch_size": batch_size,
        "dp": cfg.dp,
        "num_partitions": cfg.num_partitions,
        "latency_ms_p50": float(np.percentile(lat_arr, 50) * 1e3),
        "latency_ms_p95": float(np.percentile(lat_arr, 95) * 1e3),
        "latency_ms_p99": float(np.percentile(lat_arr, 99) * 1e3),
        "latency_ms_mean": float(lat_arr.mean() * 1e3),
        "wait_ms_mean": float(np.mean(waits) * 1e3),
        "compute_ms_mean": float(np.mean(computes) * 1e3),
        "seeds_per_s": batch_size * num_batches / max(t_total, 1e-9),
        "last_preds": preds,
        "warmup_batches": warmup_batches,
        "executor_traces": serve_ex.trace_count,
        "executor_cache_hits": serve_ex.cache_hits,
        "executor_compiled": serve_ex.num_compiled,
        "retraces_after_warmup": serve_ex.trace_count - traces_at_warmup,
        "host_builds": batcher.host_builds,
        "device_builds": 0,
        "sampler": "sharded",
    }
    for k, v in batcher.stats().items():
        stats[f"batcher_{k}"] = v
    for k, v in store.stats().items():
        stats[f"feature_{k}"] = v
    log(f"[serve_rgnn] served {num_batches} batches x {batch_size} seeds "
        f"on {cfg.num_partitions} shards / {cfg.dp} devices: "
        f"latency p50 {stats['latency_ms_p50']:.1f} ms "
        f"(route+sample {stats['wait_ms_mean']:.1f} + "
        f"compute {stats['compute_ms_mean']:.1f} ms avg), "
        f"{stats['retraces_after_warmup']} retraces after warmup")
    log(f"[serve_rgnn] sample predictions: {preds[:12].tolist()}")
    if sc is not None:
        stats["metrics"] = sc.registry.snapshot()
        if metrics_out:
            sc.registry.export(metrics_out)
    return stats


def serve_online(
    model: str = "rgat",
    dataset: str = "aifb",
    scale: float = 1.0,
    layers: int = 2,
    dim: int = 64,
    hidden: int = 64,
    classes: int = 16,
    fanouts=None,
    backend: str = "xla",
    tile: int = 32,
    node_block: int = 32,
    seed: int = 0,
    sampler: str = "host",
    feature_store: str = "device",
    feature_budget=None,
    skew=None,
    prefetch_depth: int = 2,
    cache_layouts: int = 64,
    rate_rps: float = 100.0,
    num_requests: int = 64,
    process: str = "poisson",
    burst_size: int = 4,
    slo_ms=1000.0,
    size_choices=(1, 2, 4, 8),
    max_batch: int = 32,
    max_wait_ms: float = 5.0,
    ladder_kind: str = "fine",
    speedup: float = 1.0,
    obs_mode: str = "on",
    trace_out=None,
    metrics_out=None,
    log=print,
):
    """Online serving: open-loop request traffic through the async
    ``ServingRuntime`` (deadline-aware coalescing, prefetch-overlapped
    execution) instead of the offline batch loop. Returns the runtime's
    stats dict — per-request latency percentiles, SLO attainment, queue
    depth, rung occupancy, and the zero-retrace counters."""
    from repro.serve import OpenLoopLoad, ServingRuntime, ladder

    with contextlib.ExitStack() as stack:
        sc = None
        if obs_mode == "off":
            stack.enter_context(obs.disabled())
        else:
            sc = stack.enter_context(obs.scope(
                metrics=True, tracing=trace_out is not None))

        t0 = time.perf_counter()
        graph = table3_graph(dataset, scale=scale, seed=seed)
        rng = np.random.default_rng(seed)
        feats = rng.normal(size=(graph.num_nodes, dim)).astype(np.float32)
        engine = hector.compile(
            model, graph, layers=layers, dim=dim, hidden=hidden,
            classes=classes, sample=fanouts, backend=backend, tile=tile,
            node_block=node_block, bucket=True, seed=seed, sampler=sampler,
            feature_store=feature_store, feature_budget=feature_budget,
            tune_full_graph=False, log=log)
        params = engine.init(jax.random.key(seed))
        store = engine.make_feature_store(feats)
        rungs = ladder(max_batch, ladder_kind)
        log(f"[serve_rgnn] online: {model} on {dataset} (scale {scale}), "
            f"ladder {rungs}, {rate_rps:g} req/s x {num_requests} "
            f"({process}), SLO {slo_ms} ms "
            f"(setup {time.perf_counter() - t0:.2f}s)")

        rt = ServingRuntime(
            engine, params, store, name=model, rungs=rungs,
            max_batch=max_batch, max_wait_ms=max_wait_ms,
            depth=prefetch_depth, cache_layouts=cache_layouts)
        try:
            rt.calibrate(log=log)
            load = OpenLoopLoad(
                graph.num_nodes, rate_rps=rate_rps,
                num_requests=num_requests, process=process,
                burst_size=burst_size, size_choices=size_choices,
                slo_ms=slo_ms, zipf_alpha=skew, seed=seed)
            t_load0 = time.perf_counter()
            submitted = load.replay(rt.submit, speedup=speedup)
            rt.drain()
            t_load = time.perf_counter() - t_load0
        finally:
            rt.close()

        stats = rt.stats()
        stats["submitted"] = submitted
        stats["requests_per_s"] = submitted / max(t_load, 1e-9)
        log(f"[serve_rgnn] online: {submitted} requests in {t_load:.2f}s "
            f"({stats['requests_per_s']:.1f} req/s): "
            f"latency p50 {stats['latency_ms_p50']:.1f} ms / "
            f"p99 {stats['latency_ms_p99']:.1f} ms, "
            f"SLO attainment {stats['slo_attainment']:.1%}, "
            f"queue depth max {stats['queue_depth_max']}, "
            f"{stats['batches']} batches "
            f"(fill {stats['batch_fill']:.0%}, rungs {stats['rung_counts']})")
        log(f"[serve_rgnn] online executor: {stats['executor_traces']} "
            f"traces, {stats['retraces_after_warmup']} retraces after "
            f"warmup, {stats['shape_floor_growths']} shape-floor growths")
        if sc is not None:
            if sc.tracer is not None and trace_out:
                sc.tracer.write(trace_out)
                log(f"[serve_rgnn] chrome trace -> {trace_out}")
            stats["metrics"] = sc.registry.snapshot()
            if metrics_out:
                sc.registry.export(metrics_out)
                log(f"[serve_rgnn] metrics snapshot -> {metrics_out}")
        return stats


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--runtime", default="loop", choices=["loop", "online"],
                    help="'loop': offline batch loop over a seed stream; "
                         "'online': open-loop request traffic through the "
                         "async serving runtime (deadline-aware coalescing, "
                         "per-request SLOs)")
    ap.add_argument("--model", default="rgat", choices=sorted(MODEL_PROGRAMS))
    ap.add_argument("--dataset", default="aifb",
                    choices=sorted(REDUCED_SCALES))
    ap.add_argument("--reduced", action="store_true",
                    help="scale the dataset for CPU tractability")
    ap.add_argument("--scale", type=float, default=None,
                    help="explicit dataset scale factor (overrides --reduced)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--fanout", default="5",
                    help="per-hop fanout, e.g. '5' or '5,10'; -1 = full")
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--num-batches", type=int, default=8)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--node-block", type=int, default=32)
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable power-of-two shape bucketing (each batch "
                         "then compiles fresh shapes)")
    ap.add_argument("--sampler", default="host", choices=["host", "device"],
                    help="'host': NumPy fanout sampling + host layout "
                         "build; 'device': jit-compiled sampling + layout "
                         "over a device-resident CSC (equivalent block "
                         "streams under one seed)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel device count: shard the graph and "
                         "serve every request batch across all shards in "
                         "one compiled shard_map step")
    ap.add_argument("--partitions", type=int, default=None,
                    help="graph shard count (default: one per --dp device; "
                         "a multiple of --dp folds extra shards onto "
                         "devices with bit-identical results)")
    ap.add_argument("--feature-store", default="device",
                    choices=["device", "host", "cached"],
                    help="where the node-feature table lives: 'device' = "
                         "full table device-resident; 'host' = host-"
                         "resident per-ntype tables, only sampled rows "
                         "shipped (inside the prefetch overlap); 'cached' "
                         "= host tier + fixed-budget device hot-row cache. "
                         "Predictions are bitwise identical across all "
                         "three")
    ap.add_argument("--feature-budget", type=int, default=None,
                    help="device hot-row count for --feature-store cached "
                         "(default: num_nodes / 4); per-ntype split is "
                         "measured from probe traffic")
    ap.add_argument("--skew", type=float, default=None, metavar="ALPHA",
                    help="Zipf exponent for the seed stream (power-law "
                         "traffic; popularity rank r drawn with p ~ "
                         "(r+1)^-ALPHA). Default: uniform")
    ap.add_argument("--cache-blocks", type=int, default=0,
                    help="LRU capacity of the sampled-block cache keyed by "
                         "(seeds, fanout); 0 disables")
    ap.add_argument("--cache-layouts", type=int, default=0,
                    help="LRU capacity of the KernelLayouts cache keyed by "
                         "block signature; 0 disables")
    ap.add_argument("--repeat-after", type=int, default=4,
                    help="wrap the seed stream onto N distinct batches "
                         "(models power-law repeat traffic — the production "
                         "serving assumption; every distinct batch compiles "
                         "during warmup, so steady state retraces zero "
                         "times). 0 = fresh random seeds every batch")
    ap.add_argument("--eager", action="store_true",
                    help="bypass the whole-plan compiled executor (op-by-op "
                         "debug path)")
    ap.add_argument("--tune", default="off",
                    choices=["off", "cached", "full"],
                    help="autotune operator variants: 'cached' replays the "
                         "persistent cache with zero measurements, 'full' "
                         "measures missing entries on-device")
    ap.add_argument("--tune-cache", default=None,
                    help="persistent tuning-cache path (default "
                         "$REPRO_TUNE_CACHE or ~/.cache/repro-tune.json)")
    ap.add_argument("--obs", default="on", choices=["on", "off"],
                    help="observability: 'on' runs inside an obs scope "
                         "(metrics registry + stats['metrics']); 'off' is "
                         "the zero-instrumentation baseline")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable phase tracing and write a Chrome-trace "
                         "JSON (load in chrome://tracing or Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot JSON here")
    ap.add_argument("--profile", action="store_true",
                    help="after serving, time every op instance of the "
                         "compiled plan individually (per-op kernel "
                         "breakdown on the last batch)")
    online = ap.add_argument_group("online runtime (--runtime online)")
    online.add_argument("--rate", type=float, default=100.0,
                        help="average request arrival rate (req/s)")
    online.add_argument("--requests", type=int, default=64,
                        help="number of requests to replay")
    online.add_argument("--arrivals", default="poisson",
                        choices=["poisson", "burst", "uniform"],
                        help="arrival process (open loop: arrivals never "
                             "wait on completions)")
    online.add_argument("--burst-size", type=int, default=4,
                        help="requests per burst for --arrivals burst")
    online.add_argument("--slo-ms", type=float, default=1000.0,
                        help="per-request latency budget; admission "
                             "rejects requests that cannot make it")
    online.add_argument("--sizes", default="1,2,4,8",
                        help="comma-separated request sizes (seeds per "
                             "request)")
    online.add_argument("--max-wait-ms", type=float, default=5.0,
                        help="coalescer hold time before dispatching a "
                             "partial batch")
    online.add_argument("--ladder", default="fine",
                        choices=["fine", "pow2"],
                        help="batch-size rung ladder: 'fine' = {2^k, "
                             "3*2^k} validated against measured latency, "
                             "'pow2' = powers of two only")
    online.add_argument("--speedup", type=float, default=1.0,
                        help="compress the arrival schedule by this factor")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.scale is not None:
        scale = args.scale
    elif args.reduced:
        scale = REDUCED_SCALES[args.dataset]
    else:
        scale = 1.0
    if args.runtime == "online":
        return serve_online(
            model=args.model, dataset=args.dataset, scale=scale,
            layers=args.layers, dim=args.dim, hidden=args.hidden,
            classes=args.classes,
            fanouts=parse_fanout(args.fanout, args.layers),
            backend=args.backend, tile=args.tile,
            node_block=args.node_block, seed=args.seed,
            sampler=args.sampler, feature_store=args.feature_store,
            feature_budget=args.feature_budget, skew=args.skew,
            cache_layouts=args.cache_layouts or 64,
            rate_rps=args.rate, num_requests=args.requests,
            process=args.arrivals, burst_size=args.burst_size,
            slo_ms=args.slo_ms,
            size_choices=tuple(int(s) for s in args.sizes.split(",")),
            max_batch=args.batch_size, max_wait_ms=args.max_wait_ms,
            ladder_kind=args.ladder, speedup=args.speedup,
            obs_mode=args.obs, metrics_out=args.metrics_out,
        )
    return serve(
        model=args.model, dataset=args.dataset, scale=scale,
        layers=args.layers, dim=args.dim, hidden=args.hidden,
        classes=args.classes,
        fanouts=parse_fanout(args.fanout, args.layers),
        batch_size=args.batch_size, num_batches=args.num_batches,
        backend=args.backend, tile=args.tile, node_block=args.node_block,
        bucket=not args.no_bucket, seed=args.seed, sampler=args.sampler,
        dp=args.dp, partitions=args.partitions,
        feature_store=args.feature_store,
        feature_budget=args.feature_budget, skew=args.skew,
        cache_blocks=args.cache_blocks, cache_layouts=args.cache_layouts,
        repeat_after=args.repeat_after or None, compiled=not args.eager,
        tune=args.tune, tune_cache=args.tune_cache,
        obs_mode=args.obs, trace_out=args.trace_out,
        metrics_out=args.metrics_out, profile=args.profile,
    )


if __name__ == "__main__":
    main()
