"""Batched serving driver: prefill a prompt batch, then decode with the
sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.lm.config import ShapeCell
from repro.launch.steps import build_step
from repro.launch.train import build_mesh_for_devices


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = C.get_reduced(args.arch) if args.reduced else C.get_config(args.arch)
    cache_len = args.prompt_len + args.gen
    mesh, plan = build_mesh_for_devices()
    print(f"[serve] {cfg.name}: mesh={plan.shape}")

    cell_p = ShapeCell("serve_prefill", cache_len, args.batch, "prefill")
    cell_d = ShapeCell("serve_decode", cache_len, args.batch, "decode")
    pre = build_step(cfg, cell_p, mesh, remat=False)
    dec = build_step(cfg, cell_d, mesh, remat=False, donate=False)
    model = pre.model

    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, cache_len)), jnp.int32)
    frontend = None
    if cfg.encoder_layers:
        frontend = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    elif cfg.frontend_tokens:
        frontend = jnp.asarray(
            rng.normal(size=(args.batch, cfg.frontend_tokens,
                             cfg.frontend_dim)), jnp.float32)

    t0 = time.time()
    fe = (frontend,) if frontend is not None else ()
    logits, caches = pre.fn(params, prompts, *fe)
    logits.block_until_ready()
    t_pre = time.time() - t0
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)]

    t0 = time.time()
    for i in range(args.gen - 1):
        idx = jnp.int32(args.prompt_len + i)
        logits, caches = dec.fn(params, tok, idx, caches, *fe)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_dec, 1e-9)
    print(f"[serve] prefill {t_pre*1e3:.0f} ms, decode {t_dec*1e3:.0f} ms "
          f"({tps:.1f} tok/s), sample row: {gen[0][:12]}")
    return gen


if __name__ == "__main__":
    main()
