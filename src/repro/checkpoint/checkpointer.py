"""Sharded, async, elastic checkpointing.

Design (1000+-node posture, CPU-testable):

* **Sharded layout**: one ``.npz`` per (host-addressable) shard group plus a
  JSON manifest (step, pytree structure, mesh shape, per-leaf specs). On a
  real cluster each host writes only its addressable shards; in this
  single-process container that degrades to one file without changing the
  code path.
* **Async**: ``save()`` snapshots device arrays to host memory synchronously
  (cheap) and writes to disk on a background thread — the train loop never
  blocks on IO. ``wait()`` joins before the next save or at shutdown.
* **Atomic**: writes go to ``step_<N>.tmp/`` then ``os.replace`` to
  ``step_<N>/``; a crash mid-write never corrupts the latest checkpoint.
* **Elastic restore**: ``restore(..., mesh=new_mesh, shardings=new)`` loads
  host arrays and re-places them under a *different* mesh (survivor meshes
  from runtime/fault.py), which is what elastic re-scaling needs.
* **Retention**: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot to host, then write asynchronously."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]   # device->host snapshot
        treedef_repr = jax.tree.map(lambda _: 0, tree)

        def write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "shard_000.npz",
                         **{f"leaf_{i}": a for i, a in enumerate(host)})
                manifest = {
                    "step": step,
                    "num_leaves": len(host),
                    "dtypes": [str(a.dtype) for a in host],
                    "shapes": [list(a.shape) for a in host],
                }
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._structure = treedef_repr
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self):
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``. With ``shardings`` (a
        matching pytree of NamedSharding) arrays are placed onto the target
        mesh — including a *different* mesh than the one that saved
        (elastic re-scaling)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "shard_000.npz")
        leaves, treedef = jax.tree.flatten(like)
        host = [data[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            placed = [jax.device_put(a, s) for a, s in zip(host, sh_leaves)]
        else:
            placed = host
        return treedef.unflatten(placed)
