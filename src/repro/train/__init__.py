"""RGNN training subsystem: the shared execution engine (graph + stack +
sampler + loader wiring used by both serving and training) and the
trainers that run neighbor-sampled / full-graph SGD as single compiled
steps behind the executor compile cache."""
from repro.train.engine import (  # noqa: F401
    MODEL_PROGRAMS,
    EngineConfig,
    RGNNEngine,
    parse_fanout,
)
from repro.train.trainer import (  # noqa: F401
    FullGraphTrainer,
    SampledTrainer,
)
