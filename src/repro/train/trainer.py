"""RGNN trainers on the compiled whole-plan executors.

``SampledTrainer`` is the paper's training story made servable-scale:
neighbor-sampled SGD where the *entire* step — block-sequence forward
through the gather-fused kernels, per-seed cross-entropy, backward through
the ``custom_vjp`` kernel templates, AdamW update — is one jitted callable
(``core.executor.BlockTrainExecutor``) behind the signature compile cache.
Shape-bucketed mini-batches therefore retrace zero times after warmup;
trace counters expose that invariant to tests and the ``train_sampled``
benchmark.

``FullGraphTrainer`` is the dense baseline on ``StackTrainExecutor``: one
full-graph optimizer step per call. With full-neighborhood fanout the
sampled step reproduces its loss and gradients exactly (the training
analogue of the forward-equivalence invariant), which the parity tests pin
down.

Both trainers checkpoint through ``repro.checkpoint.Checkpointer`` and can
resume mid-epoch bit-deterministically: the seed stream and the sampler are
pure functions of the global step, so a resumed run replays the exact
remaining batches.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.checkpoint import Checkpointer
from repro.core import executor
from repro.optim import AdamW, TrainState
from repro.sampling import EpochSeedStream, SeedStream, build_minibatch
from repro.train.engine import RGNNEngine


def _quiet(*_a, **_k):
    pass


class FullGraphTrainer:
    """Full-graph SGD over the compiled ``StackTrainExecutor`` step (the
    only ``execute_plan`` consumer is the executor's traced body)."""

    def __init__(self, engine: RGNNEngine, feats, labels, train_ids,
                 *, opt: Optional[AdamW] = None, log=print):
        from repro.feats import is_feature_store
        self.engine = engine
        self.opt = opt or AdamW(learning_rate=3e-3, weight_decay=0.01)
        # full-graph execution needs the whole table device-resident; a
        # tiered store hands it over explicitly (this path defeats tiering
        # by design — it exists for eval/parity, not steady-state training)
        self.feats = (feats.full_table() if is_feature_store(feats)
                      else jnp.asarray(feats))
        self.labels = np.asarray(labels)
        self.train_ids = np.asarray(train_ids, dtype=np.int32)
        self.log = log or _quiet
        self.step_exec = executor.StackTrainExecutor(
            engine.plans, self.opt, backend=engine.cfg.backend,
            activation=engine.cfg.activation, decisions=engine.decisions)
        self._idx = jnp.asarray(self.train_ids)
        self._labels_train = jnp.asarray(self.labels[self.train_ids])

    def init_state(self, params) -> TrainState:
        return self.opt.init(params)

    def step(self, state: TrainState):
        return self.step_exec.grad_and_update(
            state, self.engine.gt, self.engine.layouts, self._idx,
            self._labels_train, {"feature": self.feats})

    def train(self, state: TrainState, steps: int, log_every: int = 0):
        losses: List[float] = []
        for i in range(steps):
            state, metrics = self.step(state)
            losses.append(float(metrics["loss"]))
            if log_every and (i + 1) % log_every == 0:
                self.log(f"[train_full] step {i+1:4d} loss {losses[-1]:.4f} "
                         f"acc {float(metrics['accuracy']):.2%}")
        return state, losses

    def evaluate(self, params, ids=None) -> Dict[str, float]:
        ids = self.train_ids if ids is None else np.asarray(ids, np.int32)
        m = self.step_exec.evaluate(
            params, self.engine.gt, self.engine.layouts, jnp.asarray(ids),
            jnp.asarray(self.labels[ids]), {"feature": self.feats})
        return {k: float(v) for k, v in m.items()}


class SampledTrainer:
    """Neighbor-sampled SGD on the compiled block executor.

    The loop: an ``EpochSeedStream`` shuffles the train IDs without
    replacement each epoch; the prefetching ``MiniBatchLoader`` samples
    blocks and builds kernel layouts on a background thread (epoch-keyed,
    so no stale block replay); each dequeued ``MiniBatch`` runs one
    compiled ``grad_and_update`` step. Periodic evaluation runs full-graph
    (via the compiled stack step) and/or sampled validation; checkpoints
    save ``(global step, TrainState)`` and resume mid-epoch.
    """

    def __init__(
        self,
        engine: RGNNEngine,
        feats,
        labels,
        train_ids,
        val_ids=None,
        *,
        opt: Optional[AdamW] = None,
        ckpt_dir: Optional[str] = None,
        cache_layouts: int = 128,
        prefetch_depth: int = 2,
        log=print,
    ):
        from repro.feats import is_feature_store
        self.engine = engine
        self.opt = opt or AdamW(learning_rate=3e-3, weight_decay=0.01)
        # ``feats`` may be a raw [N, d] table or a repro.feats store; the
        # sampled path only ever touches per-batch rows through it, so with
        # a host/cached store the full table never becomes device-resident
        # here (only the lazy full-graph evaluator materializes it)
        self.feats = feats if is_feature_store(feats) else jnp.asarray(feats)
        self.labels = np.asarray(labels)
        self.train_ids = np.asarray(train_ids, dtype=np.int32)
        # an empty val split means "no validation", not a zero-row eval
        self.val_ids = (np.asarray(val_ids, dtype=np.int32)
                        if val_ids is not None and len(val_ids) else None)
        self.cache_layouts = cache_layouts
        self.prefetch_depth = prefetch_depth
        self.log = log or _quiet
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        # shared with the hector.compile facade: same opt -> same compiled
        # step (engine.train_executor caches per optimizer instance)
        self.step_exec = engine.train_executor(self.opt)
        self._full = None

    @property
    def full(self) -> FullGraphTrainer:
        """Full-graph evaluator, built lazily: it materializes the whole
        feature table on device (via ``full_table`` for tiered stores), so
        a pure sampled run with a host/cached store never pays that
        footprint unless evaluation is actually requested."""
        if self._full is None:
            self._full = FullGraphTrainer(
                self.engine, self.feats, self.labels, self.train_ids,
                opt=self.opt, log=self.log)
        return self._full

    # ------------------------------------------------------------------
    def init_state(self, params) -> TrainState:
        return self.opt.init(params)

    def resume(self, state: TrainState):
        """Restore the latest checkpoint (if any) into ``state``'s
        structure; returns ``(state, start_step)``."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return state, 0
        step = self.ckpt.latest_step()
        return self.ckpt.restore(state), step

    # ------------------------------------------------------------------
    def train(
        self,
        state: TrainState,
        *,
        epochs: int = 1,
        batch_size: int = 32,
        stream_seed: Optional[int] = None,
        start_step: int = 0,
        ckpt_every: int = 0,
        eval_every_epochs: int = 0,
        warmup_epochs: int = 1,
        log_every: int = 0,
        skew: Optional[float] = None,
    ):
        """Run ``epochs`` of neighbor-sampled SGD; returns
        ``(state, stats)``. ``start_step`` (a global step, e.g. from
        ``resume``) may land mid-epoch — the stream replays the exact
        remaining batches of that epoch.

        ``skew`` switches the seed stream to Zipf-skewed sampling *with*
        replacement over the train ids (``SeedStream(zipf_alpha=)``) —
        the power-law traffic model for cache studies. An "epoch" is then
        nominal (``len(train_ids) // batch_size`` steps), and neighborhoods
        still resample freshly each step (the sampler is keyed by the
        global step)."""
        sseed = self.engine.cfg.seed if stream_seed is None else stream_seed
        if skew is not None:
            stream = SeedStream(ids=self.train_ids, batch_size=batch_size,
                                seed=sseed, zipf_alpha=skew)
            bpe = max(1, len(self.train_ids) // stream.batch_size)
        else:
            stream = EpochSeedStream(self.train_ids, batch_size, seed=sseed)
            bpe = stream.batches_per_epoch
        total_steps = epochs * bpe
        if start_step >= total_steps:
            raise ValueError(f"start_step {start_step} beyond "
                             f"{epochs} epochs x {bpe} batches")
        # warmup is counted from this run's first step: a resumed run has a
        # fresh executor whose first-time bucket compiles are expected, not
        # retraces
        warmup_steps = start_step + min(warmup_epochs * bpe,
                                        total_steps - start_step)

        from repro.feats import gather_input, is_feature_store
        loader = self.engine.make_loader(
            stream, start_step=start_step,
            num_batches=total_steps - start_step, depth=self.prefetch_depth,
            cache_blocks=0, cache_layouts=self.cache_layouts,
            feature_store=self.feats if is_feature_store(self.feats)
            else None)

        ex = self.step_exec
        losses: List[float] = []
        accs: List[float] = []
        step_times: List[float] = []
        evals: List[Dict] = []
        traces_at_warmup = None
        t_train0 = time.perf_counter()
        try:
            for mb in loader:
                step = mb.step
                if traces_at_warmup is None and step >= warmup_steps:
                    traces_at_warmup = ex.trace_count
                labels_b = jnp.asarray(mb.seq.slice_labels(self.labels))
                # loader-attached mb.feats (tiered store, gathered inside
                # the prefetch overlap) win; raw tables gather here
                feats_b = gather_input(self.feats, mb)
                t0 = time.perf_counter()
                # the fused compiled step is one dispatch; forward/backward/
                # optimizer attribution needs obs.profile.profile_train_step
                with obs.span("train_step", step=step):
                    state, metrics = ex.grad_and_update(
                        state, mb, labels_b, feats_b)
                    loss = float(metrics["loss"])   # syncs the step
                dt = time.perf_counter() - t0
                step_times.append(dt)
                obs.metrics().histogram("train_step_ms").observe(dt * 1e3)
                losses.append(loss)
                accs.append(float(metrics["accuracy"]))
                if log_every and (step + 1) % log_every == 0:
                    self.log(f"[train_rgnn] step {step+1:5d} "
                             f"loss {loss:.4f} acc {accs[-1]:.2%} "
                             f"({step_times[-1]*1e3:.1f} ms)")
                if self.ckpt is not None and ckpt_every \
                        and (step + 1) % ckpt_every == 0:
                    self.ckpt.save(step + 1, state)
                epoch_done = (step + 1) % bpe == 0
                if epoch_done:
                    epoch = (step + 1) // bpe
                    span = losses[-min(len(losses), bpe):]
                    self.log(f"[train_rgnn] epoch {epoch}/{epochs}: "
                             f"mean loss {np.mean(span):.4f}")
                    if eval_every_epochs and epoch % eval_every_epochs == 0:
                        evals.append(self._periodic_eval(state, epoch))
        finally:
            loader.close()
        t_total = time.perf_counter() - t_train0
        if traces_at_warmup is None:
            traces_at_warmup = ex.trace_count
        if self.ckpt is not None:
            self.ckpt.wait()

        n = len(losses)
        stats = {
            "steps": n,
            "start_step": start_step,
            "batches_per_epoch": bpe,
            "epochs": epochs,
            "batch_size": stream.batch_size,
            "losses": losses,
            "accuracies": accs,
            "final_loss": losses[-1] if losses else float("nan"),
            "step_ms_p50": float(np.percentile(step_times, 50) * 1e3)
            if step_times else float("nan"),
            "step_ms_p99": float(np.percentile(step_times, 99) * 1e3)
            if step_times else float("nan"),
            "seeds_per_s": stream.batch_size * n / max(t_total, 1e-9),
            "executor_traces": ex.trace_count,
            "executor_cache_hits": ex.cache_hits,
            "executor_compiled": ex.num_compiled,
            "retraces_after_warmup": ex.trace_count - traces_at_warmup,
            "warmup_steps": warmup_steps,
            "evals": evals,
        }
        for name, cs in loader.cache_stats().items():
            stats[f"{name}_hits"] = cs["hits"]
            stats[f"{name}_misses"] = cs["misses"]
            stats[f"{name}_hit_rate"] = cs["hit_rate"]
        if is_feature_store(self.feats):
            for k, v in self.feats.stats().items():
                stats[f"feature_{k}"] = v
        return state, stats

    # ------------------------------------------------------------------
    def _periodic_eval(self, state: TrainState, epoch: int) -> Dict:
        out = {"epoch": epoch}
        ids = self.val_ids if self.val_ids is not None else self.train_ids
        split = "val" if self.val_ids is not None else "train"
        full = self.full.evaluate(state.params, ids)
        out[f"full_{split}"] = full
        sampled = self.evaluate_sampled(state.params, ids, epoch=epoch)
        out[f"sampled_{split}"] = sampled
        self.log(f"[train_rgnn]   eval@{epoch}: full-graph {split} "
                 f"loss {full['loss']:.4f} acc {full['accuracy']:.2%} | "
                 f"sampled loss {sampled['loss']:.4f} "
                 f"acc {sampled['accuracy']:.2%}")
        return out

    def evaluate_sampled(self, params, ids, *, batch_size: int = 64,
                         epoch: int = 0) -> Dict[str, float]:
        """Sampled-forward accuracy/loss over ``ids`` using the engine's
        fanout config (batched, in id order, fresh neighborhoods)."""
        import dataclasses

        from repro.feats import is_feature_store
        ids = np.asarray(ids, dtype=np.int32)
        cfg = self.engine.cfg
        tot_loss, tot_acc, nb = 0.0, 0.0, 0
        for lo in range(0, len(ids), batch_size):
            chunk = ids[lo:lo + batch_size]
            seq = self.engine.sampler.sample(chunk, batch_index=lo,
                                             epoch=epoch)
            mb = build_minibatch(seq, step=lo, tile=cfg.tile,
                                 node_block=cfg.node_block, bucket=cfg.bucket)
            if is_feature_store(self.feats):
                # read-only host gather: periodic eval may run while the
                # loader's producer thread owns the store's cache state
                # (stores are single-writer), so don't mutate it here
                mb = dataclasses.replace(mb, feats={
                    "feature": jnp.asarray(
                        self.feats.host_rows(np.asarray(mb.input_ids)))})
            logits = self.engine.forward_minibatch(params, mb, self.feats)
            loss, acc = executor.softmax_xent(
                logits, jnp.asarray(self.labels[chunk]))
            tot_loss += float(loss) * len(chunk)
            tot_acc += float(acc) * len(chunk)
            nb += len(chunk)
        return {"loss": tot_loss / max(nb, 1),
                "accuracy": tot_acc / max(nb, 1)}
