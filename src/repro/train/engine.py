"""Shared RGNN execution engine: graph + stack + sampler + loader wiring.

``launch/serve_rgnn.py`` used to assemble this pipeline inline (model
programs -> ``HectorStack`` -> ``FanoutSampler`` -> ``MiniBatchLoader``);
the trainer needs the identical stack, so the wiring lives here once and
both drivers build an ``RGNNEngine``. The engine owns everything that is a
pure function of (graph, model config): the lowered per-layer plans, the
compiled block executor with its compile cache, the full-graph tensors and
kernel layouts, and the fanout sampler. Traffic-dependent pieces — seed
streams, loaders, optimizer state — are created per driver via
``make_loader`` and the ``train/trainer.py`` classes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.graph import HeteroGraph
from repro.core.module import HectorStack
from repro.models import (hgt_program, rgat_program, rgcn_cat_program,
                          rgcn_program)
from repro.sampling import DeviceSampler, FanoutSampler, MiniBatchLoader

MODEL_PROGRAMS = {"rgcn": rgcn_program, "rgat": rgat_program,
                  "hgt": hgt_program, "rgcn_cat": rgcn_cat_program}


def parse_fanout(spec: str, layers: int) -> List[int]:
    """Parse a ``--fanout`` CLI spec: one int, or one per layer, comma
    separated; ``-1`` means the full neighborhood."""
    parts = [int(p) for p in spec.split(",")]
    if len(parts) == 1:
        parts = parts * layers
    if len(parts) != layers:
        raise ValueError(
            f"--fanout needs 1 or {layers} comma-separated ints, got {spec!r}"
        )
    return parts


@dataclasses.dataclass
class EngineConfig:
    """Model/compilation configuration shared by serving and training.

    ``model`` is a registry name (``MODEL_PROGRAMS``), a DSL-authored
    ``frontend.ModelSpec``, or any ``prog_fn(in_dim, out_dim) -> Program``
    — the ``hector.compile`` facade passes whichever the user handed it.

    ``tune`` selects the autotuning mode (``repro.tune``): ``off`` keeps the
    static lowering defaults, ``cached`` replays persisted decisions with
    zero measurements, ``full`` measures whatever the persistent cache
    (``tune_cache``, default ``~/.cache/repro-tune.json``) is missing. The
    tuner may override ``tile``/``node_block`` with its measured layout
    decision.
    """

    model: Union[str, Callable] = "rgat"
    layers: int = 2
    dim: int = 64
    hidden: int = 64
    classes: int = 16
    fanouts: Optional[Sequence] = None   # default: [5] * layers
    backend: str = "xla"
    tile: int = 32
    node_block: int = 32
    bucket: bool = True
    activation: str = "relu"
    seed: int = 0
    # "host": NumPy FanoutSampler + host layout build; "device": jit-compiled
    # sampling + layout over a device-resident CSC (same counter-based
    # selection, so both produce equivalent block streams under one seed)
    sampler: str = "host"
    # data-parallel execution: ``dp`` devices over a ``partitions``-way
    # edge-cut partition of the graph (default: one shard per device).
    # ``partitions`` may exceed ``dp`` — extra shards fold onto devices
    # (elastic shrink) with bit-identical results for any dp | partitions.
    dp: int = 1
    partitions: Optional[int] = None
    # where the node-feature table lives (repro.feats): "device" keeps the
    # full table device-resident (pre-tiering behavior), "host" keeps it in
    # per-ntype host arrays and ships only sampled rows, "cached" fronts the
    # host tier with a fixed-budget device hot-row cache. All three produce
    # bitwise-identical predictions/losses.
    feature_store: str = "device"
    # device hot-row count for feature_store="cached" (default: table/4)
    feature_budget: Optional[int] = None
    tune: str = "off"                    # off | cached | full
    tune_cache: Optional[str] = None     # persistent decision cache path
    # False for block-path-only callers (serving): keeps the materialization
    # decisions (they shape the shared lowered plans) but skips the
    # full-graph layout/op measurements serving traffic never queries
    tune_full_graph: bool = True

    def __post_init__(self):
        if isinstance(self.model, str):
            if self.model not in MODEL_PROGRAMS:
                raise ValueError(f"unknown model {self.model!r}; "
                                 f"have {sorted(MODEL_PROGRAMS)}")
        elif not callable(self.model):
            raise ValueError(
                f"model must be a registry name or a program factory "
                f"(@hector.model / prog_fn); got {type(self.model).__name__}")
        if self.tune not in ("off", "cached", "full"):
            raise ValueError(f"tune={self.tune!r}; pick off/cached/full")
        if self.sampler not in ("host", "device"):
            raise ValueError(f"sampler={self.sampler!r}; pick host/device")
        if self.feature_store not in ("device", "host", "cached"):
            raise ValueError(f"feature_store={self.feature_store!r}; "
                             f"pick device/host/cached")
        self.fanouts = list(self.fanouts) if self.fanouts is not None \
            else [5] * self.layers
        if len(self.fanouts) != self.layers:
            raise ValueError("one fanout per layer required")
        if self.dp < 1:
            raise ValueError("dp must be >= 1")
        if self.partitions is not None and self.partitions % self.dp:
            raise ValueError(
                f"partitions={self.partitions} must be a multiple of "
                f"dp={self.dp} (shards fold evenly onto devices)")

    @property
    def num_partitions(self) -> int:
        """Graph shards P (defaults to one per data-parallel device)."""
        return self.partitions if self.partitions is not None else self.dp

    @property
    def distributed(self) -> bool:
        return self.num_partitions > 1 or self.dp > 1

    @property
    def dims(self) -> List[int]:
        return [self.dim] + [self.hidden] * (self.layers - 1) + [self.classes]

    @property
    def model_name(self) -> str:
        if isinstance(self.model, str):
            return self.model
        return getattr(self.model, "name", None) \
            or getattr(self.model, "__name__", "custom")


class RGNNEngine:
    """One multi-layer RGNN compiled for one graph, ready for both
    execution modes: full-graph (``PlanExecutor`` per layer /
    ``StackTrainExecutor``) and sampled mini-batch (``BlockExecutor`` /
    ``BlockTrainExecutor``), sharing lowered plans and parameters."""

    def __init__(self, graph: HeteroGraph, cfg: EngineConfig, log=None):
        self.graph = graph
        self.cfg = cfg
        prog_fn = MODEL_PROGRAMS[cfg.model] if isinstance(cfg.model, str) \
            else cfg.model
        dims = cfg.dims
        programs = [prog_fn(dims[i], dims[i + 1]) for i in range(cfg.layers)]

        # autotuning: measured (or cache-replayed) per-op variants, per-var
        # materialization, and the kernel-layout tile — all folded into the
        # stack build below. The effective tile can differ from cfg.tile.
        self.tuner = None
        self.decisions = None
        compact_vars = None
        self.tile, self.node_block = cfg.tile, cfg.node_block
        if cfg.tune != "off":
            from repro.tune.tuner import Tuner  # lazy: pulls in codegen
            self.tuner = Tuner(mode=cfg.tune, cache_path=cfg.tune_cache,
                               log=log)
            report = self.tuner.tune_stack(
                programs, graph, backend=cfg.backend, tile=cfg.tile,
                node_block=cfg.node_block, feat_dims=dims[:-1],
                seed=cfg.seed, tune_layout=cfg.tune_full_graph,
                tune_ops=cfg.tune_full_graph)
            self.decisions = report.decisions
            compact_vars = report.compact_vars
            self.tile, self.node_block = report.tile, report.node_block

        # jit=True so the full-graph path runs through the compiled
        # PlanExecutor, not the op-by-op debug loop
        self.stack = HectorStack(
            programs, graph, backend=cfg.backend, tile=self.tile,
            node_block=self.node_block, activation=cfg.activation, jit=True,
            compact_vars=compact_vars, decisions=self.decisions,
        )
        self.sampler = FanoutSampler(graph, cfg.fanouts, seed=cfg.seed)
        # the device pipeline: uploads the CSC once at engine build; shares
        # the host sampler's seed so both paths draw the same edge streams
        self.device_sampler = None
        if cfg.sampler == "device":
            # blocks keep the configured tile (see make_loader), so the
            # device layouts match what the host pipeline would have built
            self.device_sampler = DeviceSampler(
                graph, cfg.fanouts, seed=cfg.seed,
                tile=cfg.tile, node_block=cfg.node_block,
                backend=cfg.backend)
        # compiled sampled-train-step executors, one per optimizer instance
        # (shared by the hector.compile facade and SampledTrainer so the
        # same (plans, opt) pair never compiles twice)
        self._train_execs = {}

        # data-parallel pieces: an edge-cut partition, the cross-shard
        # batcher, and a 1-D data mesh over the first ``dp`` devices. Built
        # eagerly (cheap host work) so config errors surface at compile
        # time, not on the first training step.
        self.partition = None
        self.dist_batcher = None
        self.data_mesh = None
        self._dist_execs = {}
        if cfg.distributed:
            from repro.dist import ShardedBatcher, partition_graph
            from repro.launch.mesh import make_data_mesh
            self.partition = partition_graph(graph, cfg.num_partitions)
            self.dist_batcher = ShardedBatcher(
                self.partition, cfg.fanouts, seed=cfg.seed,
                tile=cfg.tile, node_block=cfg.node_block)
            self.data_mesh = make_data_mesh(cfg.dp)

    # ------------------------------------------------------------------
    @property
    def plans(self):
        return self.stack.plans

    @property
    def block_executor(self):
        return self.stack.block_executor

    @property
    def gt(self):
        """Full-graph tensors (shared across layers)."""
        return self.stack.layers[0].gt

    @property
    def layouts(self):
        """Full-graph kernel layouts (shared across layers)."""
        return self.stack.layers[0].layouts

    def init_params(self, key: jax.Array):
        return self.stack.init(key)

    def train_executor(self, opt):
        """The compiled sampled SGD step (``BlockTrainExecutor``) for this
        engine's plans and ``opt``. Cached per optimizer instance (bounded:
        oldest entries evicted, so optimizer sweeps cannot grow memory
        without bound); a decision-table swap after (re)tuning is
        propagated instead of compiling a second executor."""
        from repro.core import executor
        ex = self._train_execs.get(id(opt))
        if ex is None:
            ex = executor.BlockTrainExecutor(
                self.plans, opt, backend=self.cfg.backend,
                activation=self.cfg.activation, decisions=self.decisions)
            self._train_execs[id(opt)] = ex
            while len(self._train_execs) > 4:   # insertion-ordered
                self._train_execs.pop(next(iter(self._train_execs)))
        if ex.decisions is not self.decisions:
            ex.set_decisions(self.decisions)
        return ex

    # ------------------------------------------------------------------
    # data-parallel surface (cfg.dp / cfg.partitions)
    # ------------------------------------------------------------------
    def _require_dist(self):
        if self.partition is None:
            raise ValueError(
                "distributed execution needs dp > 1 or partitions > 1 in "
                "the EngineConfig (e.g. hector.compile(..., dp=4))")

    def shard_features(self, feats) -> jnp.ndarray:
        """Per-owner resident feature slabs ``[P, n_own, d]`` (device-put
        once; the compiled steps all-gather them for halo access).

        ``feats`` may be a raw ``[N, d]`` table or a ``repro.feats`` store:
        with a store, each shard's slab is read through ``host_rows`` — the
        full table is never materialized on device, so shards hold only
        their owned rows (+ whatever the store keeps hot)."""
        self._require_dist()
        from repro.feats import is_feature_store
        if is_feature_store(feats):
            part = self.partition
            out = np.zeros((part.num_parts, part.max_owned, feats.dim),
                           dtype=feats.dtype)
            for p in range(part.num_parts):
                lo, hi = int(part.bounds[p]), int(part.bounds[p + 1])
                out[p, : hi - lo] = feats.host_rows(
                    np.arange(lo, hi, dtype=np.int64))
            return jnp.asarray(out)
        return jnp.asarray(self.partition.shard_features(np.asarray(feats)))

    def dist_serve_executor(self):
        """The compiled multi-shard inference step (cached)."""
        self._require_dist()
        ex = self._dist_execs.get("serve")
        if ex is None:
            from repro.dist import ShardedServeExecutor
            ex = ShardedServeExecutor(
                self.plans, self.data_mesh, backend=self.cfg.backend,
                activation=self.cfg.activation, decisions=self.decisions)
            self._dist_execs["serve"] = ex
        if ex.decisions is not self.decisions:
            ex.set_decisions(self.decisions)
        return ex

    def dist_train_executor(self, opt):
        """The compiled multi-shard SGD step for ``opt`` (cached per
        optimizer instance, like ``train_executor``)."""
        self._require_dist()
        ex = self._dist_execs.get(id(opt))
        if ex is None:
            from repro.dist import ShardedTrainExecutor
            ex = ShardedTrainExecutor(
                self.plans, opt, self.data_mesh, backend=self.cfg.backend,
                activation=self.cfg.activation, decisions=self.decisions)
            self._dist_execs[id(opt)] = ex
            while len(self._dist_execs) > 5:   # never evict the serve step
                self._dist_execs.pop(next(
                    k for k in self._dist_execs if k != "serve"))
        if ex.decisions is not self.decisions:
            ex.set_decisions(self.decisions)
        return ex

    # ------------------------------------------------------------------
    def make_feature_store(self, feats, *, seed_source=None,
                           probe_batches: int = 4):
        """Build the ``repro.feats`` store this config asks for
        (``cfg.feature_store`` / ``cfg.feature_budget``).

        For the cached tier, the per-ntype slot split is a *measured*
        decision when ``seed_source`` is given: ``tune.feature_budget``
        probes a few seed batches through the host sampler and splits the
        budget by observed per-ntype input-row traffic instead of raw
        populations (skewed hetero traffic rarely matches populations)."""
        from repro.feats import make_feature_store
        kind = self.cfg.feature_store
        split = None
        if kind == "cached" and seed_source is not None:
            from repro.tune.feature_budget import measured_split
            budget = self.cfg.feature_budget
            if budget is None:
                budget = max(1, self.graph.num_nodes // 4)
            split, _report = measured_split(
                self.graph, self.sampler, seed_source, budget,
                probe_batches=probe_batches)
        return make_feature_store(feats, self.graph, kind=kind,
                                  budget=self.cfg.feature_budget,
                                  split=split)

    # ------------------------------------------------------------------
    def make_loader(
        self,
        seed_source: Union[object, Callable[[int], np.ndarray]],
        *,
        num_batches: Optional[int] = None,
        start_step: int = 0,
        depth: int = 2,
        cache_blocks: int = 0,
        cache_layouts: int = 0,
        feature_store=None,
        shape_floors=None,
    ) -> MiniBatchLoader:
        """A prefetching loader over this engine's sampler/layout config.

        Blocks keep the *configured* tile (not the tuned full-graph layout
        tile): the layout decision is measured at full-graph scale and does
        not transfer to sampled-block shapes — the block-scale op variants
        are instead tuned against these layouts via ``tune_minibatch``.

        With ``cfg.sampler == "device"`` the loader gets the
        ``DeviceSampler`` and switches to the threadless async-dispatch
        prefetch (sampling + layout as enqueued device work)."""
        active = self.device_sampler if self.device_sampler is not None \
            else self.sampler
        return MiniBatchLoader(
            active, seed_source,
            tile=self.cfg.tile, node_block=self.cfg.node_block,
            bucket=self.cfg.bucket, depth=depth, start_step=start_step,
            num_batches=num_batches, cache_blocks=cache_blocks,
            cache_layouts=cache_layouts, feature_store=feature_store,
            shape_floors=shape_floors,
        )

    # ------------------------------------------------------------------
    def tune_minibatch(self, params, mb, global_feats) -> None:
        """Extend the decision table with block-scale op variants measured
        (or cache-replayed) on one representative ``MiniBatch``. Bucketed
        block shapes make the decisions valid for steady-state traffic; the
        executors pick them up via the decision-table fingerprint in their
        compile-cache keys."""
        if self.tuner is None:
            return
        self.tuner.tune_block_sequence(
            self.plans, params, mb, global_feats,
            backend=self.cfg.backend, activation=self.cfg.activation)

    @property
    def tuner_stats(self) -> dict:
        return dict(self.tuner.stats) if self.tuner is not None else {}

    # ------------------------------------------------------------------
    def forward_minibatch(self, params, mb, global_feats,
                          compiled: bool = True) -> jnp.ndarray:
        """Sampled forward: per-seed outputs for a ``MiniBatch``.

        ``global_feats`` may be the raw device table *or* any
        ``repro.feats`` store; loader-attached ``mb.feats`` win either
        way (the prefetch overlap already paid for that gather)."""
        from repro.feats import gather_input
        with obs.span("execute", step=mb.step) as sp:
            out = self.stack.apply_blocks(
                params, mb, compiled=compiled,
                feats=gather_input(global_feats, mb))
            return sp.sync(out)

    def forward_full(self, params, feats: jnp.ndarray) -> jnp.ndarray:
        """Full-graph forward (compiled per layer via ``PlanExecutor``)."""
        with obs.span("execute", mode="full_graph") as sp:
            return sp.sync(self.stack.apply(params, {"feature": feats}))
