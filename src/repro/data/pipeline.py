"""Sharded synthetic data pipeline with host-side prefetch.

At 1000+-node scale every host feeds only its addressable slice of the
global batch; here the pipeline produces globally-consistent synthetic token
streams (seeded per step, so restarts are deterministic and elastic re-mesh
reproduces the exact stream) and prefetches batches on a background thread
so the accelerator step never waits on host RNG.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.lm.config import LMConfig, ShapeCell


class SyntheticLMStream:
    """Deterministic synthetic LM batches: step -> {tokens, targets}.

    Zipf-ish unigram distribution (realistic softmax load), shifted-copy
    targets. ``batch(step)`` is a pure function of (seed, step) — the
    property fault-tolerance tests rely on.
    """

    def __init__(self, cfg: LMConfig, cell: ShapeCell, seed: int = 0):
        self.cfg, self.cell, self.seed = cfg, cell, seed
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.cell.global_batch, self.cell.seq_len
        toks = rng.choice(self.cfg.vocab_size, size=(b, s + 1),
                          p=self._p).astype(np.int32)
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.cfg.encoder_layers:
            out["frontend"] = rng.normal(
                size=(b, self.cfg.encoder_seq, self.cfg.d_model)
            ).astype(np.float32)
        elif self.cfg.frontend_tokens:
            out["frontend"] = rng.normal(
                size=(b, self.cfg.frontend_tokens, self.cfg.frontend_dim)
            ).astype(np.float32)
        return out


class PrefetchIterator:
    """Background-thread prefetch of ``depth`` batches (host-side overlap)."""

    def __init__(self, stream: SyntheticLMStream, start_step: int = 0,
                 depth: int = 2):
        self.stream = stream
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.stream.batch(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
