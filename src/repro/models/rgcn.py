"""RGCN layer (Schlichtkrull et al.) in Hector inter-operator IR.

Formula (paper Eq. 1):
    h_v' = σ( h_v W_0 + Σ_r Σ_{u∈N_v^r} (1/c_{v,r}) h_u W_r )

We use the in-degree normalizer (DGL's default 'right' norm) folded into the
mean-reduce of the aggregation. The whole layer is 6 IR statements — the
paper's 51-LoC-for-3-models data point is reproduced in
benchmarks/loc_report.py.
"""
from repro.core.ir import inter_op as I


def rgcn_program(in_dim: int, out_dim: int, activation: str = "relu") -> I.Program:
    W_r = I.Weight("W_rel", (in_dim, out_dim), indexed_by="etype")
    W_0 = I.Weight("W_self", (in_dim, out_dim), indexed_by=None)
    stmts = [
        # ① message generation: typed linear on each edge (GEMM template)
        I.EdgeCompute("msg", I.TypedLinear(I.SrcFeature("feature"), W_r)),
        # ② node aggregation with 1/c_{v} normalizer (traversal template)
        I.NodeAggregate("h_agg", msg="msg", reduce="mean"),
        # virtual self-loop
        I.NodeCompute("h_self", I.Linear(I.NodeFeature("feature"), W_0)),
        I.NodeCompute(
            "h_out",
            I.Unary(activation,
                    I.Binary("add", I.NodeVar("h_agg"), I.NodeVar("h_self"))),
        ),
    ]
    return I.Program(stmts=stmts, outputs=["h_out"], name="rgcn")
