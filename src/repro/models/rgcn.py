"""RGCN layer (Schlichtkrull et al.) in the Hector authoring DSL.

Formula (paper Eq. 1):
    h_v' = σ( h_v W_0 + Σ_r Σ_{u∈N_v^r} (1/c_{v,r}) h_u W_r )

We use the in-degree normalizer (DGL's default 'right' norm) folded into the
mean-reduce of the aggregation. The traced program is statement-for-statement
identical to the hand-assembled IR this module used to build (pinned by
tests/test_frontend.py); the paper's 51-LoC-for-3-models data point is
reproduced in benchmarks/loc_report.py.
"""
from repro import frontend as hector
from repro.core.ir import inter_op as I


@hector.model
def rgcn(g, e, n, in_dim, out_dim, activation="relu"):
    W_r = g.weight("W_rel", (in_dim, out_dim), indexed_by="etype")
    W_0 = g.weight("W_self", (in_dim, out_dim))
    e["msg"] = e.src["feature"] @ W_r
    n["h_agg"] = hector.aggregate(e["msg"], reduce="mean")
    n["h_self"] = n["feature"] @ W_0
    n["h_out"] = hector.unary(activation, n["h_agg"] + n["h_self"])
    return n["h_out"]


def rgcn_program(in_dim: int, out_dim: int,
                 activation: str = "relu") -> I.Program:
    """Thin wrapper: trace the DSL model into inter-operator IR."""
    return rgcn(in_dim, out_dim, activation=activation)
