"""Vanilla baseline implementations (the systems Hector is compared against).

These reproduce the inefficiencies the paper profiles in §2.3 / Fig. 4 so the
fig8/table5 benchmarks have a faithful comparison point **with identical
numerics** (same parameter pytrees as the HectorModule plans):

* ``typed_linear_replicated``  — materializes the [E, d_in, d_out] per-edge
  weight tensor (PyG FastRGCNConv / bmm pattern): the "huge temporary weight
  tensor" of §2.3.
* ``typed_linear_per_type_loop`` — one dense GEMM *per relation* with masked
  scatter (DGL HeteroConv python-loop pattern; serialized small kernels).
* full vanilla RGCN / RGAT / HGT forwards built from those pieces
  (vanilla materialization everywhere, no reordering, no compaction).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.graph import GraphTensors
from repro.kernels import ref as R


def typed_linear_replicated(x: jnp.ndarray, w: jnp.ndarray,
                            types: jnp.ndarray) -> jnp.ndarray:
    """bmm with replicated weights: W'[i] = W[T[i]] (the §2.3 anti-pattern)."""
    w_rep = w[types]                       # [M, d_in, d_out]  (materialized!)
    return jnp.einsum("mk,mkn->mn", x, w_rep)


def typed_linear_per_type_loop(x: jnp.ndarray, w: jnp.ndarray,
                               types: jnp.ndarray) -> jnp.ndarray:
    """Per-relation GEMM + mask (serialized small kernels)."""
    out = jnp.zeros((x.shape[0], w.shape[-1]), x.dtype)
    for r in range(w.shape[0]):  # python loop == serial kernel launches
        mask = (types == r)[:, None]
        out = out + jnp.where(mask, x @ w[r], 0.0)
    return out


def _maybe_loop(x, w, types, per_type_loop: bool):
    if per_type_loop:
        return typed_linear_per_type_loop(x, w, types)
    return typed_linear_replicated(x, w, types)


# ---------------------------------------------------------------------------
# full vanilla model forwards (match HectorModule numerics)
# ---------------------------------------------------------------------------
def rgcn_vanilla(params: Dict, gt: GraphTensors, feats: Dict,
                 activation: str = "relu", per_type_loop: bool = False):
    x = feats["feature"]
    msg = _maybe_loop(x[gt.src], params["W_rel"], gt.etype, per_type_loop)
    agg = compat.segment_sum(msg, gt.dst, gt.num_nodes)
    deg = (gt.dst_ptr[1:] - gt.dst_ptr[:-1]).astype(agg.dtype)
    agg = agg / jnp.maximum(deg, 1.0)[:, None]
    h = agg + x @ params["W_self"]
    act = {"relu": jax.nn.relu, "tanh": jnp.tanh}[activation]
    return {"h_out": act(h)}


def rgcn_cat_vanilla(params: Dict, gt: GraphTensors, feats: Dict,
                     activation: str = "relu", per_type_loop: bool = False):
    """Concat-combine RGCN variant (models/zoo.py): concat(agg, self) @ W_out."""
    x = feats["feature"]
    msg = _maybe_loop(x[gt.src], params["W_rel"], gt.etype, per_type_loop)
    agg = compat.segment_sum(msg, gt.dst, gt.num_nodes)
    deg = (gt.dst_ptr[1:] - gt.dst_ptr[:-1]).astype(agg.dtype)
    agg = agg / jnp.maximum(deg, 1.0)[:, None]
    h = jnp.concatenate([agg, x @ params["W_self"]], axis=-1)
    h = h @ params["W_out"]
    act = {"relu": jax.nn.relu, "tanh": jnp.tanh}[activation]
    return {"h_out": act(h)}


def rgat_vanilla(params: Dict, gt: GraphTensors, feats: Dict,
                 slope: float = 0.01, per_type_loop: bool = False):
    x = feats["feature"]
    hs = _maybe_loop(x[gt.src], params["W_rel"], gt.etype, per_type_loop)
    ht = _maybe_loop(x[gt.dst], params["W_rel"], gt.etype, per_type_loop)
    atts = jnp.sum(hs * params["w_att_src"][gt.etype], axis=-1)
    attt = jnp.sum(ht * params["w_att_dst"][gt.etype], axis=-1)
    raw = atts + attt
    raw = jnp.where(raw > 0, raw, slope * raw)
    att = R.edge_softmax_ref(raw, gt.dst, gt.num_nodes)
    out = compat.segment_sum(att[:, None] * hs, gt.dst,
                             gt.num_nodes)
    return {"h_out": out}


def hgt_vanilla(params: Dict, gt: GraphTensors, feats: Dict,
                per_type_loop: bool = False):
    x = feats["feature"]
    d = params["W_K"].shape[-1]
    kk = _maybe_loop(x, params["W_K"], gt.node_type, per_type_loop)
    qq = _maybe_loop(x, params["W_Q"], gt.node_type, per_type_loop)
    vv = _maybe_loop(x, params["W_V"], gt.node_type, per_type_loop)
    katt = _maybe_loop(kk[gt.src], params["W_att"], gt.etype, per_type_loop)
    msg = _maybe_loop(vv[gt.src], params["W_msg"], gt.etype, per_type_loop)
    raw = jnp.sum(katt * qq[gt.dst], axis=-1) / jnp.sqrt(jnp.float32(d))
    att = R.edge_softmax_ref(raw, gt.dst, gt.num_nodes)
    out = compat.segment_sum(att[:, None] * msg, gt.dst,
                             gt.num_nodes)
    return {"h_out": out}


VANILLA = {"rgcn": rgcn_vanilla, "rgat": rgat_vanilla, "hgt": hgt_vanilla,
           "rgcn_cat": rgcn_cat_vanilla}
