from repro.models.rgcn import rgcn, rgcn_program          # noqa: F401
from repro.models.rgat import rgat, rgat_program          # noqa: F401
from repro.models.hgt import hgt, hgt_program             # noqa: F401
from repro.models.zoo import rgcn_cat, rgcn_cat_program   # noqa: F401

# the DSL ModelSpecs, keyed as the drivers' --model flag expects
DSL_MODELS = {"rgcn": rgcn, "rgat": rgat, "hgt": hgt, "rgcn_cat": rgcn_cat}
