from repro.models.rgcn import rgcn_program  # noqa: F401
from repro.models.rgat import rgat_program  # noqa: F401
from repro.models.hgt import hgt_program    # noqa: F401
