"""Single-headed HGT layer in the Hector authoring DSL (paper Fig. 2).

    k_n  = h_n W_K[τ(n)]          (nodewise typed linear, ntype segments)
    q_n  = h_n W_Q[τ(n)]
    v_n  = h_n W_V[τ(n)]
    katt = k_src W_A[τ(e)]        (edgewise typed linear -> COMPACT: the
                                   msg_HGT example of §3.2.2)
    msg  = v_src W_M[τ(e)]        (COMPACT)
    att  = softmax_dst( (katt · q_dst) / sqrt(d) )
    h_v' = Σ_e att_e · msg_e

The traced program is statement-for-statement identical to the
hand-assembled IR this module used to build (pinned by
tests/test_frontend.py).
"""
import math

from repro import frontend as hector
from repro.core.ir import inter_op as I


@hector.model
def hgt(g, e, n, in_dim, out_dim):
    W_K = g.weight("W_K", (in_dim, out_dim), indexed_by="ntype")
    W_Q = g.weight("W_Q", (in_dim, out_dim), indexed_by="ntype")
    W_V = g.weight("W_V", (in_dim, out_dim), indexed_by="ntype")
    W_A = g.weight("W_att", (out_dim, out_dim), indexed_by="etype")
    W_M = g.weight("W_msg", (out_dim, out_dim), indexed_by="etype")
    n["kk"] = n["feature"] @ W_K
    n["qq"] = n["feature"] @ W_Q
    n["vv"] = n["feature"] @ W_V
    e["katt"] = e.src["kk"] @ W_A
    e["msg"] = e.src["vv"] @ W_M
    e["att_raw"] = hector.dot(e["katt"], e.dst["qq"]) * (1.0 / math.sqrt(out_dim))
    e["att"] = hector.edge_softmax(e["att_raw"])
    n["h_out"] = hector.aggregate(e["msg"], scale=e["att"])
    return n["h_out"]


def hgt_program(in_dim: int, out_dim: int) -> I.Program:
    """Thin wrapper: trace the DSL model into inter-operator IR."""
    return hgt(in_dim, out_dim)
