"""Single-headed HGT layer in Hector inter-operator IR (paper Fig. 2).

    k_n  = h_n W_K[τ(n)]          (nodewise typed linear, ntype segments)
    q_n  = h_n W_Q[τ(n)]
    v_n  = h_n W_V[τ(n)]
    katt = k_src W_A[τ(e)]        (edgewise typed linear -> COMPACT: the
                                   msg_HGT example of §3.2.2)
    msg  = v_src W_M[τ(e)]        (COMPACT)
    att  = softmax_dst( (katt · q_dst) / sqrt(d) )
    h_v' = Σ_e att_e · msg_e
"""
import math

from repro.core.ir import inter_op as I


def hgt_program(in_dim: int, out_dim: int) -> I.Program:
    W_K = I.Weight("W_K", (in_dim, out_dim), indexed_by="ntype")
    W_Q = I.Weight("W_Q", (in_dim, out_dim), indexed_by="ntype")
    W_V = I.Weight("W_V", (in_dim, out_dim), indexed_by="ntype")
    W_A = I.Weight("W_att", (out_dim, out_dim), indexed_by="etype")
    W_M = I.Weight("W_msg", (out_dim, out_dim), indexed_by="etype")
    inv_sqrt_d = 1.0 / math.sqrt(out_dim)
    stmts = [
        I.NodeCompute("kk", I.TypedLinear(I.NodeFeature("feature"), W_K)),
        I.NodeCompute("qq", I.TypedLinear(I.NodeFeature("feature"), W_Q)),
        I.NodeCompute("vv", I.TypedLinear(I.NodeFeature("feature"), W_V)),
        I.EdgeCompute("katt", I.TypedLinear(I.SrcFeature("kk"), W_A)),
        I.EdgeCompute("msg", I.TypedLinear(I.SrcFeature("vv"), W_M)),
        I.EdgeCompute(
            "att_raw",
            I.Binary("mul",
                     I.DotProduct(I.EdgeVar("katt"), I.DstFeature("qq")),
                     I.Scalar(inv_sqrt_d)),
        ),
        I.EdgeSoftmax("att", "att_raw"),
        I.NodeAggregate("h_out", msg="msg", scale="att"),
    ]
    return I.Program(stmts=stmts, outputs=["h_out"], name="hgt")
