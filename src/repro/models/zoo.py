"""DSL-authored model variants beyond the paper's three (the "new
scenarios" the frontend unlocks without touching the compiler).

``rgcn_cat`` — a concat-style RGCN: instead of *summing* the relational
aggregate and the self representation, it concatenates them and mixes with
a learned output projection (the GraphSAGE-style combine). Exercises DSL
surface the paper models do not touch — ``hector.concat`` plus an untyped
linear over a produced node var — and still lowers entirely onto the
GEMM/traversal templates (zero fallbacks, pinned by tests).
"""
from repro import frontend as hector
from repro.core.ir import inter_op as I


@hector.model
def rgcn_cat(g, e, n, in_dim, out_dim, activation="relu"):
    W_r = g.weight("W_rel", (in_dim, out_dim), indexed_by="etype")
    W_0 = g.weight("W_self", (in_dim, out_dim))
    W_o = g.weight("W_out", (2 * out_dim, out_dim))
    e["msg"] = e.src["feature"] @ W_r
    n["h_agg"] = hector.aggregate(e["msg"], reduce="mean")
    n["h_self"] = n["feature"] @ W_0
    n["h_cat"] = hector.concat(n["h_agg"], n["h_self"])
    n["h_mix"] = n["h_cat"] @ W_o
    n["h_out"] = hector.unary(activation, n["h_mix"])
    return n["h_out"]


def rgcn_cat_program(in_dim: int, out_dim: int,
                     activation: str = "relu") -> I.Program:
    """Thin wrapper: trace the DSL model into inter-operator IR."""
    return rgcn_cat(in_dim, out_dim, activation=activation)
