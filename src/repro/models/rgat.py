"""Single-headed RGAT layer in the Hector authoring DSL (paper Listing 1).

    hs    = h_src W_r                    (edgewise typed linear -> compactable)
    atts  = hs · w_s[r]                  (reordering -> h_src (W_r w_s^T))
    attt  = (h_dst W_r) · w_t[r]         (reordering, dst side)
    att   = edge_softmax(leaky_relu(atts + attt))
    h_v'  = Σ_e att_e · hs_e             (fused traversal aggregation)

The traced program is statement-for-statement identical to the
hand-assembled IR this module used to build (pinned by
tests/test_frontend.py).
"""
from repro import frontend as hector
from repro.core.ir import inter_op as I


@hector.model
def rgat(g, e, n, in_dim, out_dim, slope=0.01):
    W = g.weight("W_rel", (in_dim, out_dim), indexed_by="etype")
    w_s = g.weight("w_att_src", (out_dim,), indexed_by="etype")
    w_t = g.weight("w_att_dst", (out_dim,), indexed_by="etype")
    e["hs"] = e.src["feature"] @ W
    e["atts"] = hector.dot(e["hs"], w_s)
    e["attt"] = hector.dot(e.dst["feature"] @ W, w_t)
    e["att_raw"] = hector.leaky_relu(e["atts"] + e["attt"], slope)
    e["att"] = hector.edge_softmax(e["att_raw"])
    n["h_out"] = hector.aggregate(e["hs"], scale=e["att"])
    return n["h_out"]


def rgat_program(in_dim: int, out_dim: int, slope: float = 0.01) -> I.Program:
    """Thin wrapper: trace the DSL model into inter-operator IR."""
    return rgat(in_dim, out_dim, slope=slope)
