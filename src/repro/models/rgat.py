"""Single-headed RGAT layer in Hector inter-operator IR (paper Listing 1).

    hs    = h_src W_r                    (edgewise typed linear -> compactable)
    atts  = hs · w_s[r]                  (reordering -> h_src (W_r w_s^T))
    attt  = (h_dst W_r) · w_t[r]         (reordering, dst side)
    att   = edge_softmax(leaky_relu(atts + attt))
    h_v'  = Σ_e att_e · hs_e             (fused traversal aggregation)
"""
from repro.core.ir import inter_op as I


def rgat_program(in_dim: int, out_dim: int, slope: float = 0.01) -> I.Program:
    W = I.Weight("W_rel", (in_dim, out_dim), indexed_by="etype")
    w_s = I.Weight("w_att_src", (out_dim,), indexed_by="etype")
    w_t = I.Weight("w_att_dst", (out_dim,), indexed_by="etype")
    stmts = [
        I.EdgeCompute("hs", I.TypedLinear(I.SrcFeature("feature"), W)),
        I.EdgeCompute("atts", I.DotProduct(I.EdgeVar("hs"), w_s)),
        I.EdgeCompute(
            "attt",
            I.DotProduct(I.TypedLinear(I.DstFeature("feature"), W), w_t),
        ),
        I.EdgeCompute(
            "att_raw",
            I.Unary("leaky_relu",
                    I.Binary("add", I.EdgeVar("atts"), I.EdgeVar("attt")),
                    alpha=slope),
        ),
        I.EdgeSoftmax("att", "att_raw"),
        I.NodeAggregate("h_out", msg="hs", scale="att"),
    ]
    return I.Program(stmts=stmts, outputs=["h_out"], name="rgat")
