"""Version-compat shims for the installed jax.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
namespace around jax 0.4.34, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma``. Model code imports ``shard_map`` from here
and always passes ``check_vma=...``; the wrapper renames the kwarg when the
installed jax still uses the old spelling.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.4.34 exports shard_map at top level
    from jax import shard_map as _shard_map_impl
except ImportError:  # older jaxlib: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map_impl).parameters
    else "check_rep"
)


def shard_map(f, **kw):
    """shard_map with the check kwarg renamed for the installed jax."""
    if "check_vma" in kw and _CHECK_KW != "check_vma":
        kw[_CHECK_KW] = kw.pop("check_vma")
    return _shard_map_impl(f, **kw)
