"""Version-compat shims for the installed jax.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
namespace around jax 0.4.34, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma``. Model code imports ``shard_map`` from here
and always passes ``check_vma=...``; the wrapper renames the kwarg when the
installed jax still uses the old spelling.

``segment_sum`` / ``segment_max``: the ``jax.ops`` namespace is deprecated
and slated for removal; kernel/reference code imports the segment reductions
from here. When ``jax.ops`` still provides them we use it, otherwise we fall
back to the equivalent ``jax.lax`` scatter ops (``.at[].add`` / ``.at[].max``
lower to ``lax.scatter_add`` / ``lax.scatter_max``).
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp

try:  # jax >= 0.4.34 exports shard_map at top level
    from jax import shard_map as _shard_map_impl
except ImportError:  # older jaxlib: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map_impl).parameters
    else "check_rep"
)


def shard_map(f, **kw):
    """shard_map with the check kwarg renamed for the installed jax."""
    if "check_vma" in kw and _CHECK_KW != "check_vma":
        kw[_CHECK_KW] = kw.pop("check_vma")
    return _shard_map_impl(f, **kw)


# ---------------------------------------------------------------------------
# segment reductions (jax.ops is deprecated; fall back to lax scatter ops)
# ---------------------------------------------------------------------------
def _segment_sum_scatter(data, segment_ids, num_segments):
    shape = (num_segments,) + data.shape[1:]
    return jnp.zeros(shape, data.dtype).at[segment_ids].add(data)


def _segment_max_scatter(data, segment_ids, num_segments):
    shape = (num_segments,) + data.shape[1:]
    init = jnp.full(shape, -jnp.inf, data.dtype)
    return init.at[segment_ids].max(data)


if hasattr(getattr(jax, "ops", None), "segment_sum"):
    def segment_sum(data, segment_ids, num_segments):
        """sum of ``data`` rows per segment id -> [num_segments, ...]."""
        return jax.ops.segment_sum(data, segment_ids,
                                   num_segments=num_segments)
else:  # pragma: no cover - exercised only on jax without jax.ops
    segment_sum = _segment_sum_scatter

if hasattr(getattr(jax, "ops", None), "segment_max"):
    def segment_max(data, segment_ids, num_segments):
        """max of ``data`` rows per segment id; empty segments -> -inf."""
        return jax.ops.segment_max(data, segment_ids,
                                   num_segments=num_segments)
else:  # pragma: no cover - exercised only on jax without jax.ops
    segment_max = _segment_max_scatter
