"""Whole-plan compiled executors with an explicit compile cache.

The op-by-op ``codegen.execute_plan`` loop is the generated code's *meaning*;
running it from Python per batch leaves two costs on the serving hot path:
Python dispatch per op, and — without a stable jit entry point — a retrace
whenever shapes wobble. The executors here close a lowered plan (or a stack
of per-hop plans) over one traced function, jit it with the graph tensors,
kernel layouts, and features as **run-time pytree arguments**, and front it
with an explicit compile cache keyed by the argument signature (pytree
structure + leaf shapes/dtypes — i.e. the bucketed layout shapes).

Because sampled blocks are shape-bucketed (sampling/bucketing.py), the
signature set is small and steady-state serving reuses one compiled
executable per bucket: zero retraces, zero Python op dispatch. Cache hits,
misses, and actual traces are counted so tests and the serve_cached
benchmark can assert the steady state.

Input features are donated to the compiled call on accelerator backends
(they are freshly gathered per batch, so the executable may reuse their
buffers for outputs); donation is skipped on CPU where XLA does not
implement it.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.core import codegen


def signature(args) -> tuple:
    """Hashable compile-cache key: pytree structure + leaf shapes/dtypes.

    The treedef carries every static field (graph sizes, layout tile
    metadata), the leaves carry the bucketed array shapes — together exactly
    the information that determines the compiled executable.
    """
    leaves, treedef = jax.tree_util.tree_flatten(args)
    return treedef, tuple(
        (jnp.shape(l), jnp.result_type(l).name) for l in leaves)


def _donation_supported() -> bool:
    return jax.default_backend() not in ("cpu",)


class _CachedExecutor:
    """Shared machinery: explicit signature -> jitted-callable cache."""

    def __init__(self, donate_feats: bool, feats_argnum: int):
        self._cache: Dict[tuple, object] = {}
        self._donate = donate_feats and _donation_supported()
        self._feats_argnum = feats_argnum
        self.cache_hits = 0
        self.cache_misses = 0
        self.trace_count = 0   # incremented inside the traced fn: counts
        #                        actual (re)traces, not cache bookkeeping

    def _traced(self, *args):
        raise NotImplementedError

    def _call(self, *args):
        key = signature(args)
        fn = self._cache.get(key)
        if fn is None:
            self.cache_misses += 1
            donate = (self._feats_argnum,) if self._donate else ()
            fn = jax.jit(self._traced, donate_argnums=donate)
            self._cache[key] = fn
        else:
            self.cache_hits += 1
        return fn(*args)

    @property
    def num_compiled(self) -> int:
        return len(self._cache)

    def cache_stats(self) -> Dict[str, int]:
        return {
            "compile_cache_hits": self.cache_hits,
            "compile_cache_misses": self.cache_misses,
            "trace_count": self.trace_count,
            "num_compiled": self.num_compiled,
        }


class PlanExecutor(_CachedExecutor):
    """Compiled full-graph forward for one lowered plan.

    ``gt``/``kl`` are arguments (not closure state), so one executor serves
    any graph whose signature matches — and distinct graphs simply occupy
    distinct cache entries.

    Donation defaults off here: full-graph callers typically reuse the same
    feature arrays across calls, so their buffers are not ours to consume
    (unlike the per-batch gathered features of ``BlockExecutor``).
    """

    def __init__(self, plan, backend: str = "xla",
                 donate_feats: bool = False):
        super().__init__(donate_feats, feats_argnum=3)
        self.plan = plan
        self.backend = backend

    def _traced(self, params, gt, kl, feats):
        self.trace_count += 1
        return codegen.execute_plan(self.plan, params, gt, feats, kl,
                                    self.backend)

    def __call__(self, params, gt, kl, feats) -> Dict[str, jnp.ndarray]:
        return self._call(params, gt, kl, feats)


class BlockExecutor(_CachedExecutor):
    """Compiled sampled-minibatch forward for a stack of per-hop plans.

    One jitted callable covers the *entire* block sequence — every hop's
    GEMM/traversal kernels, inter-hop frontier narrowing, activations, and
    the final seed gather — so steady-state serving is a single compiled
    dispatch per batch.
    """

    def __init__(self, plans: Sequence, backend: str = "xla",
                 activation: str = "relu", donate_feats: bool = True):
        super().__init__(donate_feats, feats_argnum=5)
        self.plans = list(plans)
        self.backend = backend
        self.activation = activation

    def _traced(self, params, gts, kls, dst_locals, seed_perm, feats):
        self.trace_count += 1
        return codegen.execute_block_sequence(
            self.plans, params, gts, kls, dst_locals, seed_perm, feats,
            backend=self.backend, activation=self.activation)

    def __call__(self, params: Sequence[Dict[str, jnp.ndarray]],
                 gts: List, kls: List, dst_locals: List,
                 seed_perm, feats: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        return self._call(list(params), list(gts), list(kls),
                          list(dst_locals), seed_perm, feats)

    def run_minibatch(self, params, mb, global_feats) -> jnp.ndarray:
        """Convenience entry over a ``sampling.MiniBatch``."""
        feats = {"feature": global_feats[mb.input_ids]}
        return self(params, mb.tensors, mb.layouts, mb.dst_locals,
                    mb.seed_perm, feats)
