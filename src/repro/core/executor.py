"""Whole-plan compiled executors with an explicit compile cache.

The op-by-op ``codegen.execute_plan`` loop is the generated code's *meaning*;
running it from Python per batch leaves two costs on the serving hot path:
Python dispatch per op, and — without a stable jit entry point — a retrace
whenever shapes wobble. The executors here close a lowered plan (or a stack
of per-hop plans) over one traced function, jit it with the graph tensors,
kernel layouts, and features as **run-time pytree arguments**, and front it
with an explicit compile cache keyed by the argument signature (pytree
structure + leaf shapes/dtypes — i.e. the bucketed layout shapes).

Because sampled blocks are shape-bucketed (sampling/bucketing.py), the
signature set is small and steady-state serving reuses one compiled
executable per bucket: zero retraces, zero Python op dispatch. Cache hits,
misses, and actual traces are counted so tests and the serve_cached
benchmark can assert the steady state.

Input features are donated to the compiled call on accelerator backends
(they are freshly gathered per batch, so the executable may reuse their
buffers for outputs); donation is skipped on CPU where XLA does not
implement it.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import codegen


def signature(args) -> tuple:
    """Hashable compile-cache key: pytree structure + leaf shapes/dtypes.

    The treedef carries every static field (graph sizes, layout tile
    metadata), the leaves carry the bucketed array shapes — together exactly
    the information that determines the compiled executable.
    """
    leaves, treedef = jax.tree_util.tree_flatten(args)
    return treedef, tuple(
        (jnp.shape(l), jnp.result_type(l).name) for l in leaves)


def _donation_supported() -> bool:
    return jax.default_backend() not in ("cpu",)


class _CachedExecutor:
    """Shared machinery: explicit signature -> jitted-callable cache.

    ``decisions`` (a ``tune.TuningDecisions`` table, or None) is closed over
    by the traced function AND its fingerprint joins the cache key: swapping
    in a new table after (re)tuning can never reuse an executable compiled
    for the old variants.
    """

    def __init__(self, donate: bool, donate_argnums: Sequence[int],
                 decisions=None, static_key: tuple = ()):
        self._cache: Dict[tuple, object] = {}
        self._donate = donate and _donation_supported()
        self._donate_argnums = tuple(donate_argnums)
        # plan fingerprint(s): distinct lowered plans can never share a
        # compiled executable even if their argument signatures collide
        self._static_key = tuple(static_key)
        self.decisions = decisions
        self.cache_hits = 0
        self.cache_misses = 0
        self.trace_count = 0   # incremented inside the traced fn: counts
        #                        actual (re)traces, not cache bookkeeping

    def set_decisions(self, decisions) -> None:
        """Install a (new) tuning-decision table; subsequent calls compile
        fresh entries under its fingerprint."""
        self.decisions = decisions

    def _traced(self, *args):
        raise NotImplementedError

    def _count_trace(self) -> None:
        """Called from inside the traced functions: counts actual
        (re)traces. Runs at trace time on the host — never inside the
        compiled executable — so the obs mirror adds no per-call cost."""
        self.trace_count += 1
        obs.metrics().counter("executor_traces",
                              executor=type(self).__name__).inc()

    def _call(self, *args):
        fp = self.decisions.fingerprint() if self.decisions is not None \
            else None
        key = (self._static_key, fp) + signature(args)
        fn = self._cache.get(key)
        if fn is None:
            self.cache_misses += 1
            obs.metrics().counter("executor_cache_misses",
                                  executor=type(self).__name__).inc()
            donate = self._donate_argnums if self._donate else ()
            fn = jax.jit(self._traced, donate_argnums=donate)
            self._cache[key] = fn
        else:
            self.cache_hits += 1
            obs.metrics().counter("executor_cache_hits",
                                  executor=type(self).__name__).inc()
        return fn(*args)

    @property
    def num_compiled(self) -> int:
        return len(self._cache)

    def cache_stats(self) -> Dict[str, int]:
        return {
            "compile_cache_hits": self.cache_hits,
            "compile_cache_misses": self.cache_misses,
            "trace_count": self.trace_count,
            "num_compiled": self.num_compiled,
        }


class PlanExecutor(_CachedExecutor):
    """Compiled full-graph forward for one lowered plan.

    ``gt``/``kl`` are arguments (not closure state), so one executor serves
    any graph whose signature matches — and distinct graphs simply occupy
    distinct cache entries.

    Donation defaults off here: full-graph callers typically reuse the same
    feature arrays across calls, so their buffers are not ours to consume
    (unlike the per-batch gathered features of ``BlockExecutor``).
    """

    def __init__(self, plan, backend: str = "xla",
                 donate_feats: bool = False, decisions=None):
        super().__init__(donate_feats, donate_argnums=(3,),
                         decisions=decisions,
                         static_key=(plan.fingerprint(),))
        self.plan = plan
        self.backend = backend

    def _traced(self, params, gt, kl, feats):
        self._count_trace()
        return codegen.execute_plan(self.plan, params, gt, feats, kl,
                                    self.backend, self.decisions)

    def __call__(self, params, gt, kl, feats) -> Dict[str, jnp.ndarray]:
        return self._call(params, gt, kl, feats)


class BlockExecutor(_CachedExecutor):
    """Compiled sampled-minibatch forward for a stack of per-hop plans.

    One jitted callable covers the *entire* block sequence — every hop's
    GEMM/traversal kernels, inter-hop frontier narrowing, activations, and
    the final seed gather — so steady-state serving is a single compiled
    dispatch per batch.
    """

    def __init__(self, plans: Sequence, backend: str = "xla",
                 activation: str = "relu", donate_feats: bool = True,
                 decisions=None):
        super().__init__(donate_feats, donate_argnums=(5,),
                         decisions=decisions,
                         static_key=tuple(p.fingerprint() for p in plans))
        self.plans = list(plans)
        self.backend = backend
        self.activation = activation

    def _traced(self, params, gts, kls, dst_locals, seed_perm, feats):
        self._count_trace()
        return codegen.execute_block_sequence(
            self.plans, params, gts, kls, dst_locals, seed_perm, feats,
            backend=self.backend, activation=self.activation,
            decisions=self.decisions)

    def __call__(self, params: Sequence[Dict[str, jnp.ndarray]],
                 gts: List, kls: List, dst_locals: List,
                 seed_perm, feats: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        return self._call(list(params), list(gts), list(kls),
                          list(dst_locals), seed_perm, feats)

    def run_minibatch(self, params, mb, global_feats=None, *,
                      feats=None) -> jnp.ndarray:
        """Convenience entry over a ``sampling.MiniBatch``.

        Input-feature precedence: an explicit ``feats`` pytree, then the
        loader-attached ``mb.feats`` (pre-gathered by a tiered feature
        store inside the prefetch overlap), then an on-device gather from
        ``global_feats``. The chosen buffers are donated."""
        if feats is None:
            feats = getattr(mb, "feats", None)
        if feats is None:
            feats = {"feature": global_feats[mb.input_ids]}
        return self(params, mb.tensors, mb.layouts, mb.dst_locals,
                    mb.seed_perm, feats)


# ---------------------------------------------------------------------------
# compiled training steps
# ---------------------------------------------------------------------------
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray):
    """Mean cross-entropy + accuracy over [rows, classes] logits and int
    labels; the per-seed training objective (one row per seed/node)."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                   .astype(jnp.float32))
    return jnp.mean(nll), acc


class BlockTrainExecutor(_CachedExecutor):
    """Compiled neighbor-sampled SGD step over a stack of per-hop plans.

    One jitted callable covers the whole step: block-sequence forward (every
    hop's kernels), per-seed cross-entropy on the gathered seed rows,
    backward through the gather-fused ``custom_vjp`` kernels, and the
    optimizer update — behind the same signature compile cache as the
    forward executors, so shape-bucketed mini-batches retrace zero times
    after warmup.

    The optimizer state is donated on accelerator backends (its buffers are
    consumed by the update — callers must not reuse the old state), as are
    the per-batch gathered features.
    """

    def __init__(self, plans: Sequence, opt, backend: str = "xla",
                 activation: str = "relu", donate_state: bool = True,
                 decisions=None):
        # argnums in _traced order: 0=state, 6=feats
        super().__init__(donate_state, donate_argnums=(0, 6),
                         decisions=decisions,
                         static_key=tuple(p.fingerprint() for p in plans))
        self.plans = list(plans)
        self.opt = opt
        self.backend = backend
        self.activation = activation

    def _traced(self, state, gts, kls, dst_locals, seed_perm, labels, feats):
        self._count_trace()

        def loss_fn(params):
            logits = codegen.execute_block_sequence(
                self.plans, params, gts, kls, dst_locals, seed_perm, feats,
                backend=self.backend, activation=self.activation,
                decisions=self.decisions)
            return softmax_xent(logits, labels)

        (loss, acc), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        new_state = self.opt.update(grads, state)
        return new_state, {"loss": loss, "accuracy": acc}

    def grad_and_update(self, state, mb, labels, feats):
        """One optimizer step over a ``sampling.MiniBatch``-shaped bundle.

        ``labels`` must be aligned with the requested seed order (use
        ``BlockSequence.slice_labels``); ``feats`` is the per-batch gathered
        feature dict for the first block's node set. Returns
        ``(new_state, {"loss", "accuracy"})``.
        """
        return self._call(state, list(mb.tensors), list(mb.layouts),
                          list(mb.dst_locals), mb.seed_perm, labels, feats)


class StackTrainExecutor(_CachedExecutor):
    """Compiled full-graph SGD step over a multi-layer stack — the training
    analogue of ``PlanExecutor``: layer-by-layer forward over the shared
    graph tensors/layouts, cross-entropy on the ``idx`` node rows, backward
    and optimizer update in one jitted callable.

    Serves as the parity baseline for the sampled trainer (full-fanout
    sampled steps must reproduce its loss and gradients) and as the
    periodic full-graph evaluator.
    """

    def __init__(self, plans: Sequence, opt, backend: str = "xla",
                 activation: str = "relu", donate_state: bool = True,
                 decisions=None):
        super().__init__(donate_state, donate_argnums=(0,),
                         decisions=decisions,
                         static_key=tuple(p.fingerprint() for p in plans))
        self.plans = list(plans)
        self.opt = opt
        self.backend = backend
        self.activation = activation
        self._eval_fn = None

    def _forward(self, params, gt, kl, feats):
        act = codegen._ACTIVATIONS[self.activation]
        cur = dict(feats)
        h = None
        last = len(self.plans) - 1
        for i, (plan, p) in enumerate(zip(self.plans, params)):
            out = codegen.execute_plan(plan, p, gt, cur, kl, self.backend,
                                       self.decisions)
            h = out[plan.outputs[0]]
            if i < last:
                cur = {"feature": act(h)}
        return h

    def _traced(self, state, gt, kl, idx, labels, feats):
        self._count_trace()

        def loss_fn(params):
            h = self._forward(params, gt, kl, feats)
            return softmax_xent(h[idx], labels)

        (loss, acc), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        new_state = self.opt.update(grads, state)
        return new_state, {"loss": loss, "accuracy": acc}

    def grad_and_update(self, state, gt, kl, idx, labels, feats):
        """One full-graph optimizer step; loss is taken over the ``idx``
        node rows (the training split)."""
        return self._call(state, gt, kl, idx, labels, feats)

    def set_decisions(self, decisions) -> None:
        super().set_decisions(decisions)
        self._eval_fn = None   # compiled under the old decision table

    # -- compiled evaluation (no update) ---------------------------------
    def _traced_eval(self, params, gt, kl, idx, labels, feats):
        h = self._forward(params, gt, kl, feats)
        return softmax_xent(h[idx], labels)

    def evaluate(self, params, gt, kl, idx, labels, feats):
        """Full-graph loss/accuracy on the ``idx`` rows (jitted once —
        full-graph shapes are static)."""
        if self._eval_fn is None:
            self._eval_fn = jax.jit(self._traced_eval)
        loss, acc = self._eval_fn(params, gt, kl, idx, labels, feats)
        return {"loss": loss, "accuracy": acc}
