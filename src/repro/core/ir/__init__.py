from repro.core.ir import inter_op, intra_op, passes  # noqa: F401
